# Build / verification entry points.
#
#   make verify   — the tier-1 gate: release build + tests, then advisory
#                   fmt + clippy (advisory until the whole tree is
#                   rustfmt-clean; the `-` prefix keeps them non-fatal so
#                   lint drift cannot mask a real build/test regression).
#   make bench    — decode-latency bench incl. the online-drain flatness
#                   profile (writes results/bench_decode.json).
#   make artifacts — AOT-lower the JAX model to HLO text (needs python/jax;
#                   without it the runtime serves via its native backend).

CARGO ?= cargo

.PHONY: verify build test test-concurrency test-session-soak test-scalar fmt-check clippy clippy-kernel bench bench-smoke artifacts clean

verify: build test
	-$(MAKE) fmt-check
	-$(MAKE) clippy

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Serialized concurrency/invariants suite for the maintenance worker and
# the double-buffered index swap, including the reclaim soak (async
# worker on/off); `timeout` fails fast on a deadlock.
test-concurrency:
	timeout 900 $(CARGO) test -q --test maintenance_concurrency -- --test-threads=1

# Serialized spill/resume soak: park/resume churn over many sessions with
# every finished turn forced to disk (session-persistence acceptance
# gate); `timeout` fails fast on a wedged restore or registry.
test-session-soak:
	timeout 900 $(CARGO) test -q --test session_soak -- --test-threads=1

# Full suite with SIMD force-disabled: the scalar fallback must keep every
# platform green (the kernel dispatch acceptance gate).
test-scalar:
	RA_KERNEL=scalar $(CARGO) test -q

fmt-check:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --workspace --all-targets

# Clippy is ENFORCED (not advisory) for rust/src/kernel: the module is
# annotated #[deny(clippy::all)] in lib.rs, so any kernel lint fails this
# target while the rest of the tree stays advisory via `clippy` above.
clippy-kernel:
	$(CARGO) clippy --lib

bench:
	$(CARGO) bench --bench decode_latency

# Tiny-geometry bench run: asserts BENCH_decode.json is produced and the
# runtime kernel dispatch selected a real backend (CI gate).
bench-smoke:
	$(CARGO) bench --bench decode_latency -- smoke

artifacts:
	cd python && python -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf results
