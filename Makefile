# Build / verification entry points.
#
#   make verify   — the tier-1 gate: release build + tests, then advisory
#                   fmt + clippy (advisory until the whole tree is
#                   rustfmt-clean; the `-` prefix keeps them non-fatal so
#                   lint drift cannot mask a real build/test regression).
#   make bench    — decode-latency bench incl. the online-drain flatness
#                   profile (writes results/bench_decode.json).
#   make artifacts — AOT-lower the JAX model to HLO text (needs python/jax;
#                   without it the runtime serves via its native backend).

CARGO ?= cargo

.PHONY: verify build test test-concurrency fmt-check clippy bench artifacts clean

verify: build test
	-$(MAKE) fmt-check
	-$(MAKE) clippy

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Serialized concurrency/invariants suite for the maintenance worker and
# the double-buffered index swap, including the reclaim soak (async
# worker on/off); `timeout` fails fast on a deadlock.
test-concurrency:
	timeout 900 $(CARGO) test -q --test maintenance_concurrency -- --test-threads=1

fmt-check:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --workspace --all-targets

bench:
	$(CARGO) bench --bench decode_latency

artifacts:
	cd python && python -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf results
