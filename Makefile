# Build / verification entry points.
#
#   make verify   — the tier-1 gate: release build + tests, then ENFORCED
#                   fmt + clippy + the repo invariant linter (a lint
#                   failure is a red build, same as a test failure).
#   make lint     — the repo invariant linter (cargo xtask lint): SAFETY
#                   comments on every unsafe, no std::sync::atomic/RwLock
#                   outside the util::sync facade, Ordering::Relaxed only
#                   in allowlisted counter files, no unwrap/expect in the
#                   serving-path modules.
#   make test-faults — deterministic fault-injection matrix (the
#                   `failpoints` feature): injected IO errors, partial
#                   writes, and panics at every instrumented site must
#                   degrade cleanly (see docs/robustness.md).
#   make loom     — exhaustive model checking of the publish/swap
#                   protocols (tests/loom_models.rs) under the vendored
#                   loom checker; the sync facade swaps to instrumented
#                   primitives via --cfg loom.
#   make miri     — nightly-only: the codec + quantization unit tests
#                   under Miri (UB detection on the byte-twiddling code).
#   make tsan     — nightly-only: the maintenance concurrency suite under
#                   ThreadSanitizer (catches the ordering bugs loom's
#                   sequentially-consistent model cannot).
#   make bench    — decode-latency bench incl. the online-drain flatness
#                   profile (writes results/bench_decode.json).
#   make artifacts — AOT-lower the JAX model to HLO text (needs python/jax;
#                   without it the runtime serves via its native backend).

CARGO ?= cargo

.PHONY: verify build test test-concurrency test-session-soak test-faults test-scalar fmt-check clippy clippy-kernel lint loom miri tsan bench bench-smoke artifacts clean

verify: build test
	$(MAKE) fmt-check
	$(MAKE) clippy
	$(MAKE) lint

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Serialized concurrency/invariants suite for the maintenance worker and
# the double-buffered index swap, including the reclaim soak (async
# worker on/off); `timeout` fails fast on a deadlock.
test-concurrency:
	timeout 900 $(CARGO) test -q --test maintenance_concurrency -- --test-threads=1

# Serialized spill/resume soak: park/resume churn over many sessions with
# every finished turn forced to disk (session-persistence acceptance
# gate); `timeout` fails fast on a wedged restore or registry.
test-session-soak:
	timeout 900 $(CARGO) test -q --test session_soak -- --test-threads=1

# Deterministic fault-injection matrix (tests/fault_injection.rs) under
# the `failpoints` feature: every instrumented spill/codec/maintenance/
# wave/worker site is driven with injected errors and panics, including
# the worker-kill → respawn → durable-recovery path. The failpoint
# registry is process-global, hence serialized; `timeout` fails fast if
# a "contained" fault actually wedges the replica (see
# docs/robustness.md).
test-faults:
	timeout 900 $(CARGO) test -q --features failpoints --test fault_injection -- --test-threads=1

# Full suite with SIMD force-disabled: the scalar fallback must keep every
# platform green (the kernel dispatch acceptance gate).
test-scalar:
	RA_KERNEL=scalar $(CARGO) test -q

# Enforced for the first-party packages; the vendored dependency
# snapshots under rust/vendor are exempt (reformatting them would only
# add diff noise against their upstreams).
fmt-check:
	$(CARGO) fmt -p retrieval_attention -p xtask -- --check

# Enforced lint gate: the bug-shaped bundles (correctness / suspicious /
# perf) are denied crate-wide via attributes in lib.rs; the -D flags here
# extend the same policy to xtask, whose sources carry no such
# attributes. Scoped to the first-party packages — the vendored crates
# are dependency snapshots, not code this gate should churn.
clippy:
	$(CARGO) clippy -p retrieval_attention -p xtask --all-targets -- -D clippy::correctness -D clippy::suspicious -D clippy::perf

# The kernel module is stricter still: #[deny(clippy::all)] in lib.rs, so
# any kernel lint (style included) fails this target.
clippy-kernel:
	$(CARGO) clippy --lib

# Repo invariant linter (xtask/src/lint.rs). Also enforced as a unit test
# (xtask/tests/lint_fixtures.rs::tree_is_lint_clean), so plain
# `cargo test` catches violations even when this target is skipped.
lint:
	$(CARGO) xtask lint

# Model checking: the sync facade (rust/src/util/sync.rs) swaps Mutex /
# RwLock / atomics for the vendored loom checker's instrumented twins
# under --cfg loom, and tests/loom_models.rs explores every interleaving
# of the publish/swap protocols up to the preemption bound. The timeout
# converts a schedule-space blowup into a red build instead of a hang;
# LOOM_MAX_PREEMPTIONS / LOOM_MAX_ITERS tune the search (see
# docs/concurrency.md).
loom:
	RUSTFLAGS="--cfg loom" timeout 1800 $(CARGO) test -q --release --test loom_models

# Miri over the pure byte-twiddling hot spots (snapshot codec, quantized
# scan tier): UB detection on the unsafe-free but pointer-heavy code.
# Scoped to unit-test filters — whole-suite Miri is hours, these minutes.
# -Zmiri-disable-isolation lets the codec tests touch tempfiles. Requires
# a nightly toolchain with the miri component (CI installs it; locally:
# rustup toolchain install nightly --component miri).
miri:
	RA_KERNEL=scalar MIRIFLAGS="-Zmiri-disable-isolation" timeout 3600 $(CARGO) +nightly miri test -q --lib store::codec::
	RA_KERNEL=scalar MIRIFLAGS="-Zmiri-disable-isolation" timeout 3600 $(CARGO) +nightly miri test -q --lib kernel::quant::

# ThreadSanitizer over the maintenance concurrency suite: loom models
# interleavings under sequential consistency, TSan checks the *orderings*
# (a wrong Relaxed shows up here). Needs nightly + rust-src (build-std
# instruments libstd too, or TSan false-positives on runtime internals).
tsan:
	RA_KERNEL=scalar RUSTFLAGS="-Zsanitizer=thread" timeout 3600 $(CARGO) +nightly test -q -Zbuild-std --target x86_64-unknown-linux-gnu --test maintenance_concurrency -- --test-threads=1

bench:
	$(CARGO) bench --bench decode_latency

# Tiny-geometry bench run: asserts BENCH_decode.json is produced and the
# runtime kernel dispatch selected a real backend (CI gate).
bench-smoke:
	$(CARGO) bench --bench decode_latency -- smoke

artifacts:
	cd python && python -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf results
