//! Latency metrics: histograms, counters, and the per-phase decode
//! breakdown of Table 5 (vector search / attention / other).
//!
//! These are the *per-request / per-bench* value types. The process-wide
//! always-on view (named counters, gauges, bounded log-bucketed
//! histograms, spans, the flight recorder) lives in [`crate::telemetry`];
//! phase timing itself moved there too ([`crate::telemetry::Stopwatch`]),
//! so one mechanism feeds both the breakdown slots below and the span
//! trees.

use std::time::Duration;

/// Streaming latency recorder with percentile queries. Stores raw samples
/// (decode benchmarks record at most a few hundred thousand points).
#[derive(Clone, Debug, Default)]
pub struct LatencyHistogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        self.samples.push(d.as_secs_f64());
        self.sorted = false;
    }

    pub fn record_secs(&mut self, s: f64) {
        self.samples.push(s);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Percentile in [0, 100] by nearest-rank.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.samples.len() - 1) as f64).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// The decode-phase breakdown reported in Table 5, plus the online
/// index-maintenance phase (overflow drains into the ANN index).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseBreakdown {
    /// Vector-index search time (s).
    pub search: f64,
    /// Attention compute time, host + device (s).
    pub attention: f64,
    /// Online index maintenance: overflow drains + graph repair (s).
    pub maintenance: f64,
    /// Everything else (projections, FFN, sampling, bookkeeping) (s).
    pub other: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.search + self.attention + self.maintenance + self.other
    }

    /// Fraction of the step spent in vector search — the paper's headline
    /// breakdown number (34.0% for RetrievalAttention vs 86.6% for Flat).
    pub fn search_share(&self) -> f64 {
        if self.total() == 0.0 {
            0.0
        } else {
            self.search / self.total()
        }
    }

    /// Fraction of the step spent maintaining the online index.
    pub fn maintenance_share(&self) -> f64 {
        if self.total() == 0.0 {
            0.0
        } else {
            self.maintenance / self.total()
        }
    }

    pub fn add(&mut self, o: &PhaseBreakdown) {
        self.search += o.search;
        self.attention += o.attention;
        self.maintenance += o.maintenance;
        self.other += o.other;
    }

    pub fn scale(&self, f: f64) -> PhaseBreakdown {
        PhaseBreakdown {
            search: self.search * f,
            attention: self.attention * f,
            maintenance: self.maintenance * f,
            other: self.other * f,
        }
    }
}

/// Replica-level wave-scheduler counters, owned by the worker loop. A
/// request snapshots these at admission and takes deltas at retirement,
/// which is how per-request wave occupancy and replica throughput land in
/// [`crate::coordinator::RequestMetrics`] without any shared state.
#[derive(Clone, Copy, Debug, Default)]
pub struct WaveTelemetry {
    /// Decode waves executed since the worker started.
    pub waves: u64,
    /// Total (session, wave) schedule slots filled across all waves.
    pub scheduled_total: u64,
    /// Tokens emitted across all resident sessions.
    pub tokens_emitted: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut h = LatencyHistogram::default();
        for i in 1..=100 {
            h.record_secs(i as f64);
        }
        // Nearest-rank on 1..=100: p50 -> index round(0.5*99)=50 -> 51.
        assert_eq!(h.p50(), 51.0);
        assert_eq!(h.p99(), 99.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn breakdown_shares() {
        let b = PhaseBreakdown { search: 0.34, attention: 0.4, maintenance: 0.1, other: 0.16 };
        assert!((b.total() - 1.0).abs() < 1e-12);
        assert!((b.search_share() - 0.34).abs() < 1e-12);
        assert!((b.maintenance_share() - 0.1).abs() < 1e-12);
        let doubled = b.scale(2.0);
        assert!((doubled.maintenance - 0.2).abs() < 1e-12);
        let mut acc = PhaseBreakdown::default();
        acc.add(&b);
        acc.add(&b);
        assert!((acc.maintenance - 0.2).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_accumulates_like_the_old_phase_timer() {
        let mut slot = 0.0;
        let t = crate::telemetry::Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        let s = t.stop_into(&mut slot);
        assert!(slot >= 0.004);
        assert!((slot - s).abs() < 1e-15, "returns what it accumulated");
    }
}
