//! SnapKV (Li et al. 2024): keep only the tokens that the *last window of
//! prompt queries* found important, plus that window itself.
//!
//! The host set is therefore **fixed before decoding starts** — the paper's
//! point (§4.2) is that this static choice breaks on tasks whose critical
//! tokens shift per decode query (Retr.KV drops to ~0.5%).

use super::{HostRetriever, Retrieval, RetrieverInputs};
use crate::tensor::argtopk;

/// Fixed top-budget token set scored by the observation window.
pub struct SnapKvRetriever {
    ids: Vec<u32>,
}

/// Observation window: the last N prompt queries vote on key importance.
const OBS_WINDOW: usize = 64;
/// Budget of host tokens kept: the paper's SnapKV keeps ~2K of 128K
/// (≈1.6%); we keep the same *fraction* of the host corpus, floored so
/// tiny test corpora still retain something.
fn budget(n: usize) -> usize {
    (n / 64).clamp(32, 2048)
}

impl SnapKvRetriever {
    pub fn build(inp: &RetrieverInputs<'_>) -> Self {
        let keys = inp.host_keys();
        let host_ids = inp.host_ids();
        let n = keys.rows();
        let nq = inp.prefill_queries.rows();
        let obs = nq.min(OBS_WINDOW);
        if n == 0 || obs == 0 {
            return SnapKvRetriever { ids: Vec::new() };
        }
        // Accumulate softmax-weighted votes from the observation window.
        let mut votes = vec![0.0f32; n];
        for qi in nq - obs..nq {
            let q = inp.prefill_queries.row(qi);
            let mut scores: Vec<f32> =
                (0..n).map(|i| crate::tensor::dot(q, keys.row(i)) * inp.scale).collect();
            crate::tensor::softmax_inplace(&mut scores);
            for (v, s) in votes.iter_mut().zip(scores.iter()) {
                *v += s;
            }
        }
        let keep = argtopk(&votes, budget(n).min(n));
        let mut ids: Vec<u32> = keep.into_iter().map(|dense| host_ids.ids[dense]).collect();
        ids.sort_unstable();
        SnapKvRetriever { ids }
    }

    pub fn kept(&self) -> usize {
        self.ids.len()
    }
}

impl HostRetriever for SnapKvRetriever {
    fn retrieve(&self, _q: &[f32], _k: usize) -> Retrieval {
        // Static: the same set for every decode query, zero scan cost.
        Retrieval { ids: self.ids.clone(), scanned: 0 }
    }

    fn name(&self) -> &'static str {
        "SnapKV"
    }

    fn memory_bytes(&self) -> usize {
        self.ids.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::test_inputs;
    use crate::config::RetrievalConfig;
    use crate::index::KeyStore;

    #[test]
    fn keeps_tokens_hot_for_window_queries() {
        let (keys, ids, queries) = test_inputs(2000, 16, 11);
        let cfg = RetrievalConfig::default();
        // Plant a key every observation-window query votes for: it must
        // survive the budget cut.
        let mut planted = keys.to_matrix();
        let hot: Vec<f32> = crate::tensor::col_mean(&queries).iter().map(|v| v * 3.0).collect();
        planted.row_mut(777).copy_from_slice(&hot);
        let inp2 = RetrieverInputs::from_parts(
            KeyStore::from_matrix(planted),
            ids.clone(),
            &queries,
            0.25,
            &cfg,
            0,
        );
        let r = SnapKvRetriever::build(&inp2);
        assert!(r.kept() > 0 && r.kept() <= budget(2000));
        let out = r.retrieve(queries.row(0), 100);
        assert!(out.ids.contains(&ids[777]), "hot token evicted");
        assert_eq!(out.scanned, 0);
    }

    #[test]
    fn static_across_queries() {
        let (keys, ids, queries) = test_inputs(500, 8, 12);
        let cfg = RetrievalConfig::default();
        let inp = RetrieverInputs::from_parts(keys, ids, &queries, 0.35, &cfg, 0);
        let r = SnapKvRetriever::build(&inp);
        let a = r.retrieve(&[1.0; 8], 10);
        let b = r.retrieve(&[-1.0; 8], 10);
        assert_eq!(a.ids, b.ids, "SnapKV must be query-independent");
    }
}
