//! Quest (Tang et al. 2024): query-aware page criticality via per-page
//! min/max key bounds.
//!
//! The host KV is paged (16 tokens); each page stores the element-wise min
//! and max of its keys. For a query q, the page's criticality bound is
//! `Σ_d max(q_d·min_d, q_d·max_d)` — an upper bound on any inner product
//! within the page. The top pages by bound are attended in full.

use super::{HostRetriever, IdMap, Retrieval, RetrieverInputs};
use crate::tensor::{argtopk, Matrix};
use std::sync::Arc;

/// Tokens per page (Quest's default).
const PAGE: usize = 16;

pub struct QuestRetriever {
    ids: Arc<IdMap>,
    /// Per page: (min vector, max vector), dense row range.
    mins: Matrix,
    maxs: Matrix,
    pages: Vec<(u32, u32)>,
}

impl QuestRetriever {
    pub fn build(inp: &RetrieverInputs<'_>) -> Self {
        let keys = inp.host_keys();
        let n = keys.rows();
        let d = keys.cols();
        let npages = n.div_ceil(PAGE);
        let mut mins = Matrix::zeros(npages, d);
        let mut maxs = Matrix::zeros(npages, d);
        let mut pages = Vec::with_capacity(npages);
        for p in 0..npages {
            let lo = p * PAGE;
            let hi = (lo + PAGE).min(n);
            let min_row = mins.row_mut(p);
            min_row.fill(f32::INFINITY);
            for i in lo..hi {
                for (m, &v) in min_row.iter_mut().zip(keys.row(i)) {
                    *m = m.min(v);
                }
            }
            let max_row = maxs.row_mut(p);
            max_row.fill(f32::NEG_INFINITY);
            for i in lo..hi {
                for (m, &v) in max_row.iter_mut().zip(keys.row(i)) {
                    *m = m.max(v);
                }
            }
            pages.push((lo as u32, hi as u32));
        }
        QuestRetriever { ids: inp.host_ids(), mins, maxs, pages }
    }

    /// The paper's criticality bound for one page.
    fn bound(&self, p: usize, q: &[f32]) -> f32 {
        let min = self.mins.row(p);
        let max = self.maxs.row(p);
        let mut s = 0.0f32;
        for ((&qd, &lo), &hi) in q.iter().zip(min).zip(max) {
            s += (qd * lo).max(qd * hi);
        }
        s
    }

    pub fn page_count(&self) -> usize {
        self.pages.len()
    }
}

impl HostRetriever for QuestRetriever {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval {
        if self.pages.is_empty() {
            return Retrieval::default();
        }
        let bounds: Vec<f32> = (0..self.pages.len()).map(|p| self.bound(p, q)).collect();
        let want_pages = k.div_ceil(PAGE).max(1);
        let top = argtopk(&bounds, want_pages.min(self.pages.len()));
        let mut ids = Vec::with_capacity(want_pages * PAGE);
        for p in top {
            let (lo, hi) = self.pages[p];
            for dense in lo..hi {
                ids.push(self.ids.ids[dense as usize]);
            }
        }
        // Scanned = page metadata comparisons (2 vectors per page).
        Retrieval { ids, scanned: 2 * self.pages.len() }
    }

    fn name(&self) -> &'static str {
        "Quest"
    }

    fn memory_bytes(&self) -> usize {
        (self.mins.as_slice().len() + self.maxs.as_slice().len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::test_inputs;
    use crate::config::RetrievalConfig;
    use crate::index::KeyStore;

    fn build(n: usize, seed: u64) -> (QuestRetriever, KeyStore, Vec<u32>) {
        let (keys, ids, queries) = test_inputs(n, 16, seed);
        let cfg = RetrievalConfig::default();
        let inp =
            RetrieverInputs::from_parts(keys.clone(), ids.clone(), &queries, 0.25, &cfg, seed);
        (QuestRetriever::build(&inp), keys, ids)
    }

    #[test]
    fn bound_dominates_inner_products() {
        // The min/max bound must upper-bound every key's inner product in
        // the page — the property Quest's correctness rests on.
        let (r, keys, _) = build(320, 8);
        let q: Vec<f32> = (0..16).map(|i| ((i * 7) as f32).sin()).collect();
        for (p, &(lo, hi)) in r.pages.iter().enumerate() {
            let b = r.bound(p, &q);
            for dense in lo..hi {
                let ip = crate::tensor::dot(&q, keys.row(dense as usize));
                assert!(b >= ip - 1e-4, "page {p} bound {b} < ip {ip}");
            }
        }
    }

    #[test]
    fn retrieves_page_containing_dominant_key() {
        // Quest's bound is loose on random data, so guarantee retrieval by
        // planting a key whose inner product dominates every other page's
        // bound — then its page *must* be in the top pages.
        let (_, base_keys, _) = build(640, 9);
        let mut keys = base_keys.to_matrix();
        let strong: Vec<f32> = (0..16).map(|i| if i % 2 == 0 { 8.0 } else { -8.0 }).collect();
        keys.row_mut(345).copy_from_slice(&strong);
        let ids: Vec<u32> = (0..640u32).collect();
        let queries = Matrix::from_fn(4, 16, |_, _| 0.1);
        let cfg = RetrievalConfig::default();
        let inp =
            RetrieverInputs::from_parts(KeyStore::from_matrix(keys), ids, &queries, 0.25, &cfg, 9);
        let r = QuestRetriever::build(&inp);
        let out = r.retrieve(&strong, 64);
        assert!(out.ids.contains(&345), "dominant key's page not retrieved");
    }

    #[test]
    fn page_count() {
        let (r, _, _) = build(100, 10);
        assert_eq!(r.page_count(), 7); // ceil(100/16)
    }
}
