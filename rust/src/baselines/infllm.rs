//! InfLLM (Xiao et al. 2024a): block-organised host KV with representative
//! vectors.
//!
//! The host tokens are split into contiguous blocks; each block elects a
//! few *representative* keys (the ones with the highest attention received
//! from within the block's own context — approximated here by key norm,
//! the usual proxy). A decode query scores every representative and
//! retrieves the full top blocks. The paper's critique (§4.2): block
//! granularity + lossy representatives miss needle-sized critical tokens
//! (Retr.KV ≈ 0.5%).

use super::{HostRetriever, IdMap, Retrieval, RetrieverInputs};
use crate::index::KeyStore;
use crate::tensor::{argtopk, dot};
use std::sync::Arc;

/// Tokens per block (InfLLM's default granularity).
const BLOCK: usize = 128;
/// Representatives per block.
const REPS: usize = 4;

pub struct InfLlmRetriever {
    keys: KeyStore,
    ids: Arc<IdMap>,
    /// Representative dense-row indices per block.
    reps: Vec<[u32; REPS]>,
    /// Dense row range per block.
    blocks: Vec<(u32, u32)>,
}

impl InfLlmRetriever {
    pub fn build(inp: &RetrieverInputs<'_>) -> Self {
        let keys = inp.host_keys();
        let n = keys.rows();
        let nblocks = n.div_ceil(BLOCK);
        let mut reps = Vec::with_capacity(nblocks);
        let mut blocks = Vec::with_capacity(nblocks);
        for b in 0..nblocks {
            let lo = b * BLOCK;
            let hi = (lo + BLOCK).min(n);
            // Representative selection: top-REPS keys by norm within the
            // block (proxy for "receives most attention").
            let norms: Vec<f32> = (lo..hi).map(|i| crate::tensor::norm(keys.row(i))).collect();
            let top = argtopk(&norms, REPS.min(hi - lo));
            let mut r = [0u32; REPS];
            for (slot, &t) in r.iter_mut().zip(top.iter().cycle().take(REPS)) {
                *slot = (lo + t) as u32;
            }
            reps.push(r);
            blocks.push((lo as u32, hi as u32));
        }
        InfLlmRetriever { keys, ids: inp.host_ids(), reps, blocks }
    }

    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }
}

impl HostRetriever for InfLlmRetriever {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval {
        if self.blocks.is_empty() {
            return Retrieval::default();
        }
        // Score each block by its best representative.
        let scores: Vec<f32> = self
            .reps
            .iter()
            .map(|r| {
                r.iter()
                    .map(|&i| dot(q, self.keys.row(i as usize)))
                    .fold(f32::NEG_INFINITY, f32::max)
            })
            .collect();
        let want_blocks = k.div_ceil(BLOCK).max(1);
        let top = argtopk(&scores, want_blocks.min(self.blocks.len()));
        let mut ids = Vec::with_capacity(want_blocks * BLOCK);
        for b in top {
            let (lo, hi) = self.blocks[b];
            for dense in lo..hi {
                ids.push(self.ids.ids[dense as usize]);
            }
        }
        // Scanned = representative comparisons (the retrieval cost driver).
        Retrieval { ids, scanned: self.reps.len() * REPS }
    }

    fn name(&self) -> &'static str {
        "InfLLM"
    }

    fn memory_bytes(&self) -> usize {
        self.reps.len() * (REPS * 4 + 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::test_inputs;
    use crate::config::RetrievalConfig;

    fn build(n: usize, seed: u64) -> (InfLlmRetriever, KeyStore, Vec<u32>) {
        let (keys, ids, queries) = test_inputs(n, 16, seed);
        let cfg = RetrievalConfig::default();
        let inp =
            RetrieverInputs::from_parts(keys.clone(), ids.clone(), &queries, 0.25, &cfg, seed);
        (InfLlmRetriever::build(&inp), keys, ids)
    }

    #[test]
    fn retrieves_whole_blocks() {
        let (r, _, _) = build(1000, 5);
        assert_eq!(r.block_count(), 8);
        let out = r.retrieve(&[0.5; 16], 100);
        // 100-token budget -> 1 block of 128 (or the 104-token tail block).
        assert!(out.ids.len() >= 100, "got {}", out.ids.len());
        assert!(out.scanned <= 8 * REPS);
    }

    #[test]
    fn block_with_best_rep_wins() {
        let (r, keys, ids) = build(512, 6);
        // Query aligned with the strongest rep of some block: that block's
        // tokens must be retrieved.
        let rep_dense = r.reps[2][0] as usize;
        let q: Vec<f32> = keys.row(rep_dense).iter().map(|&v| v * 3.0).collect();
        let out = r.retrieve(&q, BLOCK);
        assert!(out.ids.contains(&ids[rep_dense]));
    }

    #[test]
    fn empty_corpus() {
        let (r, _, _) = build(0, 7);
        let out = r.retrieve(&[0.0; 16], 10);
        assert!(out.ids.is_empty());
    }
}
