//! Host-side retrieval policies: the paper's method and every baseline.
//!
//! The engine decomposes each decode step's attention into the device set
//! `W` (static pattern, always attended) and a host set chosen per query.
//! Each method is a [`HostRetriever`] deciding that host set:
//!
//! | Method              | Host set                                          |
//! |---------------------|---------------------------------------------------|
//! | FullAttention/vLLM  | every host token (exact)                          |
//! | StreamingLLM        | ∅ (device static pattern only)                    |
//! | SnapKV              | fixed set scored by the last prompt window        |
//! | InfLLM              | top blocks by representative-key score            |
//! | Quest               | top pages by min/max criticality bound            |
//! | InfiniGen           | top-k under a low-rank score speculation          |
//! | Flat                | exact KNN over host keys                          |
//! | IVF                 | IVF index search                                  |
//! | HNSW                | HNSW index search (ablation)                      |
//! | RetrievalAttention  | attention-aware RoarGraph search                  |
//!
//! Retrievers are built once per (layer, query-head) at prefill. The
//! GQA group's **shared state** ([`GroupShared`]) holds the single
//! segmented key copy and the single dense→absolute id map of Appendix C
//! — one per group, not one per query head.
//!
//! Index-backed retrievers are **double-buffered** ([`IndexRetriever`]):
//! decode-time searches snapshot the front index with one `Arc` clone and
//! run entirely lock-free from there, while the maintenance worker mutates
//! a private back buffer and publishes it with a generation-counted swap
//! (left/right buffering with an op-replay log, so neither buffer is ever
//! rebuilt from scratch). A reader can therefore never observe a
//! partially-applied insert or remove.

pub mod infinigen;
pub mod infllm;
pub mod quest;
pub mod snapkv;

use crate::config::{Method, RetrievalConfig};
use crate::index::{
    flat::FlatIndex,
    hnsw::{HnswIndex, HnswParams},
    ivf::IvfIndex,
    roargraph::{RoarGraph, RoarParams},
    search_rerank, InsertContext, KeyStore, RemapPlan, SearchParams, VectorIndex,
};
use crate::tensor::Matrix;
use crate::util::swap::Published;
use crate::util::sync::{yield_now, Arc, AtomicBool, Mutex, Ordering};

/// Retries of the retrieve front/map pairing loop before each voluntary
/// yield, and spins reclaiming the spare buffer before falling back to a
/// clone. Tiny under loom so the model checker reaches the yield and
/// clone-fallback arms within a handful of scheduling points.
#[cfg(not(loom))]
const RETRIEVE_SPINS_BEFORE_YIELD: u32 = 64;
#[cfg(loom)]
const RETRIEVE_SPINS_BEFORE_YIELD: u32 = 1;
#[cfg(not(loom))]
const RECLAIM_SPINS_BEFORE_CLONE: u32 = 1_000;
#[cfg(loom)]
const RECLAIM_SPINS_BEFORE_CLONE: u32 = 2;

/// Result of one host retrieval: *absolute* token ids + scan count.
#[derive(Clone, Debug, Default)]
pub struct Retrieval {
    pub ids: Vec<u32>,
    pub scanned: usize,
}

/// A generation-stamped dense→absolute id map. Dense ids are only
/// meaningful within one **store generation**: a reclamation epoch
/// renumbers them, bumps the generation, and stamps every index front it
/// republishes — so a reader always pairs an index snapshot with the map
/// of the *same* generation (see [`GroupShared::map_for_generation`]).
/// Within a generation the map only ever grows by appends, so any map at
/// least as new as an index front maps every dense id the front returns.
pub struct IdMap {
    /// Generation this map belongs to (bumps on every reclamation remap).
    pub store_gen: u64,
    /// Dense row -> absolute token id, ascending.
    pub ids: Vec<u32>,
}

impl std::ops::Deref for IdMap {
    type Target = [u32];

    fn deref(&self) -> &[u32] {
        &self.ids
    }
}

/// The published map state: the current generation's map plus — only for
/// the duration of a reclamation epoch — the previous generation's, so
/// decode readers still holding a pre-remap index front keep a correct
/// pairing instead of spinning while the worker republishes every head.
struct MapPair {
    cur: Arc<IdMap>,
    prev: Option<Arc<IdMap>>,
}

/// Per-GQA-group shared retrieval state (Appendix C, "Minimize the CPU
/// Memory Usage"): ONE segmented dense key copy and ONE dense→absolute id
/// map, shared by every query head of the group. Both are published with
/// generation-counted swaps; within a store generation the id map is
/// always published *before* any index front that references its new
/// rows, and across generations the reclamation epoch publishes
/// map → store → per-head fronts (retaining the previous map until every
/// front is republished), so a reader holding an index snapshot can
/// always map every dense id it can ever return.
pub struct GroupShared {
    /// Segmented dense key store (`Arc`'d chunks; drains append O(batch),
    /// reclamation epochs swap in a compacted store that actually shrinks).
    pub store: Published<KeyStore>,
    /// Generation-stamped dense→absolute maps (current + epoch-transient
    /// previous).
    maps: Published<MapPair>,
    /// Set once an extend breaks the ascending order — possible only when
    /// a truncate-then-redrain session legally re-appends an absolute id.
    /// Reverse lookups then fall back from binary search to a one-shot
    /// hash map (where the later dense slot wins; the earlier one is
    /// already tombstoned).
    ///
    /// Release/Acquire, not Relaxed: the flag's `true` must not become
    /// visible before the unsorted map publish it describes — a reader
    /// that Acquire-loads `false` after the Release store would otherwise
    /// binary-search a map that is no longer ascending. (The map publish
    /// itself is also fenced by `Published`, but the flag must carry its
    /// own edge so the pairing never depends on which load happens first.)
    unsorted: AtomicBool,
}

impl GroupShared {
    pub fn new(store: KeyStore, ids: Vec<u32>) -> Arc<GroupShared> {
        debug_assert_eq!(store.rows(), ids.len());
        Arc::new(GroupShared {
            store: Published::new(store),
            maps: Published::new(MapPair {
                cur: Arc::new(IdMap { store_gen: 0, ids }),
                prev: None,
            }),
            unsorted: AtomicBool::new(false),
        })
    }

    /// Rebuild a group from snapshotted parts under an explicit store
    /// generation (session persistence): the restored index fronts carry
    /// the generation they were saved with, so the map must come back
    /// stamped identically or every post-restore search would spin in
    /// [`GroupShared::map_for_generation`]. The `unsorted` reverse-lookup
    /// flag is recomputed from the ids rather than persisted.
    pub fn restore(store: KeyStore, ids: Vec<u32>, store_gen: u64) -> Arc<GroupShared> {
        // `>=`, not `==`: store-less groups (Full/StreamingLLM heads never
        // read keys) legitimately grow the map past the store on drains.
        debug_assert!(ids.len() >= store.rows());
        let unsorted = ids.windows(2).any(|w| w[1] <= w[0]);
        Arc::new(GroupShared {
            store: Published::new(store),
            maps: Published::new(MapPair {
                cur: Arc::new(IdMap { store_gen, ids }),
                prev: None,
            }),
            unsorted: AtomicBool::new(unsorted),
        })
    }

    /// Copy-on-write fork: a new group sharing the current store's chunks
    /// by `Arc` and the current id map wholesale (maps are immutable once
    /// published). The fork and the original then diverge through their
    /// own `Published` slots — neither's drains/reclaims can touch the
    /// other. The epoch-transient `prev` map is never carried over: the
    /// caller forks only quiesced sessions (maintenance flushed), so no
    /// reader of the fork can hold a pre-remap front.
    pub fn fork(&self) -> Arc<GroupShared> {
        let maps = self.maps.load();
        Arc::new(GroupShared {
            store: Published::new(self.keys()),
            maps: Published::new(MapPair { cur: maps.cur.clone(), prev: None }),
            unsorted: AtomicBool::new(self.unsorted.load(Ordering::Acquire)),
        })
    }

    /// Snapshot the current key store (cheap: chunk-table clone).
    pub fn keys(&self) -> KeyStore {
        (*self.store.load()).clone()
    }

    /// Snapshot the current generation's dense→absolute id map.
    pub fn id_map(&self) -> Arc<IdMap> {
        self.maps.load().cur.clone()
    }

    /// The map belonging to store generation `gen`: the current one, or —
    /// mid-reclamation — the retained previous one. `None` means the
    /// caller's index snapshot predates the retained window (a newer
    /// front is already published; reload and retry).
    pub fn map_for_generation(&self, gen: u64) -> Option<Arc<IdMap>> {
        let maps = self.maps.load();
        if maps.cur.store_gen == gen {
            return Some(maps.cur.clone());
        }
        match &maps.prev {
            Some(p) if p.store_gen == gen => Some(p.clone()),
            _ => None,
        }
    }

    /// Current store generation (bumps once per reclamation epoch).
    pub fn store_generation(&self) -> u64 {
        self.maps.load().cur.store_gen
    }

    /// Grow the group state for a drained batch: the id map is extended
    /// and published first, then (when some head actually reads keys) the
    /// store gains one segment. Returns the store the inserts must use.
    pub fn extend(&self, rows: Matrix, new_ids: &[u32], grow_store: bool) -> KeyStore {
        let maps = self.maps.load();
        let mut ids = maps.cur.ids.clone();
        let boundary_ok = match (ids.last(), new_ids.first()) {
            (Some(&last), Some(&first)) => first > last,
            _ => true,
        };
        if !boundary_ok || new_ids.windows(2).any(|w| w[1] <= w[0]) {
            self.unsorted.store(true, Ordering::Release);
        }
        ids.extend_from_slice(new_ids);
        self.maps.publish(Arc::new(MapPair {
            cur: Arc::new(IdMap { store_gen: maps.cur.store_gen, ids }),
            prev: maps.prev.clone(),
        }));
        if grow_store {
            let grown = self.store.load().append_rows(rows);
            self.store.publish(Arc::new(grown.clone()));
            grown
        } else {
            self.keys()
        }
    }

    /// Open a reclamation epoch: publish the compacted map under the new
    /// generation (retaining the pre-remap map as `prev` for readers
    /// whose index fronts have not been republished yet), then the
    /// compacted store. The caller (the maintenance worker's
    /// `Job::Compact`) then remaps every head's index front and finally
    /// calls [`GroupShared::finish_remap`] to release the old map.
    pub fn publish_remap(&self, new_ids: Vec<u32>, new_store: KeyStore, gen: u64) {
        debug_assert_eq!(new_store.rows(), new_ids.len());
        let maps = self.maps.load();
        debug_assert!(gen > maps.cur.store_gen, "remap must bump the generation");
        self.maps.publish(Arc::new(MapPair {
            cur: Arc::new(IdMap { store_gen: gen, ids: new_ids }),
            prev: Some(maps.cur.clone()),
        }));
        self.store.publish(Arc::new(new_store));
    }

    /// Close the reclamation epoch: every head's front now carries the
    /// new generation, so the retained previous map can be dropped (this
    /// is the moment the old map's memory is actually released).
    pub fn finish_remap(&self) {
        let maps = self.maps.load();
        if maps.prev.is_some() {
            self.maps.publish(Arc::new(MapPair { cur: maps.cur.clone(), prev: None }));
        }
    }

    /// Heap bytes of the shared id map(s) (counted once per group; the
    /// epoch-transient previous map is charged while retained).
    pub fn map_bytes(&self) -> usize {
        let maps = self.maps.load();
        (maps.cur.ids.len() + maps.prev.as_ref().map(|p| p.ids.len()).unwrap_or(0)) * 4
    }

    /// Heap bytes of the shared key store — f32 payload, chunk table, and
    /// any quantized scan-tier mirrors — counted once per group
    /// (Appendix C's single-copy layout).
    pub fn store_bytes(&self) -> usize {
        let store = self.store.load();
        store.rows() * store.cols() * 4 + store.table_bytes() + store.quant_bytes()
    }

    /// Resolve absolute token ids to dense slots against the current map —
    /// ONCE per *group*, so an eviction/truncation batch does not pay the
    /// reverse lookup per query head. While the map is ascending (the
    /// common case: it only ever appends increasing ids, and reclamation
    /// keeps an ascending subsequence ascending), each id resolves by
    /// allocation-free binary search; after a truncate-then-redrain has
    /// broken the order, a one-shot hash map takes over (the later dense
    /// slot wins; the earlier one is already tombstoned). Unknown ids are
    /// skipped.
    pub fn dense_ids_for(&self, absolute_ids: &[u32]) -> Vec<u32> {
        let ids = self.id_map();
        if !self.unsorted.load(Ordering::Acquire) {
            return absolute_ids
                .iter()
                .filter_map(|a| ids.binary_search(a).ok().map(|d| d as u32))
                .collect();
        }
        let reverse: std::collections::HashMap<u32, u32> =
            ids.iter().enumerate().map(|(d, &a)| (a, d as u32)).collect();
        absolute_ids.iter().filter_map(|a| reverse.get(a).copied()).collect()
    }
}

/// A per-(layer, query-head) host retrieval policy.
pub trait HostRetriever: Send + Sync {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval;
    fn name(&self) -> &'static str;
    /// Index/metadata heap bytes (memory accounting). The group-shared id
    /// map and key store are *excluded* — they are counted once per group
    /// via [`GroupShared::map_bytes`], not once per head.
    fn memory_bytes(&self) -> usize {
        0
    }
    /// InfiniGen's defining trick: layer *l*'s critical tokens are
    /// speculated from layer *l−1*'s query (so the prefetch can overlap
    /// with layer l−1's compute). The engine passes the previous layer's
    /// query to retrievers that return true — and this speculation
    /// mismatch is exactly the accuracy gap Table 2 shows for InfiniGen.
    fn speculates_from_previous_layer(&self) -> bool {
        false
    }

    /// Whether [`HostRetriever::insert_batch`] can succeed. The engine only
    /// drains a cache's overflow buffer when every query head of the GQA
    /// group accepts inserts.
    fn supports_insert(&self) -> bool {
        false
    }

    /// True when this retriever "accepts" inserts by dropping the tokens
    /// (StreamingLLM semantics). Callers use this to (a) refuse
    /// discard-drains for sessions whose method promises exact attention,
    /// and (b) skip growing the shared key store for data nobody reads.
    fn discards_inserts(&self) -> bool {
        false
    }

    /// Whether [`HostRetriever::insert_batch`] actually reads `store`.
    /// When every head of a group returns false the caller may skip the
    /// store grow entirely (AllRetriever only tracks ids; EmptyRetriever
    /// reads nothing).
    fn needs_store(&self) -> bool {
        true
    }

    /// Fold newly decoded host tokens into the searchable set.
    ///
    /// `store` is the grown segmented key store shared by the whole GQA
    /// group: rows `[0, store.rows() - ids.len())` are unchanged from the
    /// previous drain, the final `ids.len()` rows are the new key vectors,
    /// and `ids` carries their absolute token ids. The caller must already
    /// have published `ids` into the group's shared map (see
    /// [`GroupShared::extend`]). Takes `&self` — index retrievers apply
    /// the op to their private back buffer and publish it with an atomic
    /// swap, so decode-time searches stay un-blocked.
    ///
    /// Returns `false` when unsupported (fixed-set baselines): the caller
    /// keeps those tokens in the linearly-scanned overflow buffer.
    fn insert_batch(&self, store: &KeyStore, ids: &[u32], ctx: &InsertContext<'_>) -> bool {
        let _ = (store, ids, ctx);
        false
    }

    /// Whether [`HostRetriever::remove_batch`] can succeed.
    fn supports_remove(&self) -> bool {
        false
    }

    /// Tombstone the given *absolute* token ids (eviction / truncation):
    /// they must never be retrieved again. Dense ids stay stable — the
    /// shared map is never rewritten. Returns `false` when unsupported.
    fn remove_batch(&self, absolute_ids: &[u32]) -> bool {
        let _ = absolute_ids;
        false
    }

    /// Pre-mapped variant of [`HostRetriever::remove_batch`]: the caller
    /// resolved dense slots against the group map once (via
    /// [`GroupShared::dense_ids_for`]) for the whole GQA group.
    fn remove_dense(&self, dense_ids: &[u32]) -> bool {
        let _ = dense_ids;
        false
    }

    /// Tombstoned-but-unreclaimed index slots (tombstone-ratio metric).
    fn tombstones(&self) -> usize {
        0
    }

    /// Whether this head can participate in a reclamation epoch (the
    /// generation-based dense-id remap that physically frees tombstoned
    /// rows). Only index-backed retrievers over remap-capable families
    /// return true.
    fn supports_reclaim(&self) -> bool {
        false
    }

    /// Dense ids currently tombstoned in this head's front, ascending.
    /// The reclamation planner builds the group's old→new renumbering
    /// from the FIRST head's set (heads of one group receive the
    /// identical remove stream).
    fn dense_dead_ids(&self) -> Vec<u32> {
        Vec::new()
    }

    /// `(live, tombstoned)` from ONE front snapshot. The engine's reclaim
    /// trigger polls this on the decode path, so it must not cost two
    /// separate front loads (`indexed_len` + `tombstones` each take the
    /// published-slot read lock). `None` for index-less policies.
    fn reclaim_counts(&self) -> Option<(usize, usize)> {
        None
    }

    /// Apply a reclamation epoch's remap to this head's index. Goes
    /// through the same double-buffered op path as inserts/removes: the
    /// republished front carries the plan's store generation, so decode
    /// readers pair it with the matching id map. Returns `false` when
    /// unsupported.
    fn apply_remap(&self, plan: &Arc<RemapPlan>) -> bool {
        let _ = plan;
        false
    }

    /// Live searchable vectors for index-backed retrievers; `None` for
    /// policies without an index.
    fn indexed_len(&self) -> Option<usize> {
        None
    }

    /// Front-buffer generation: bumps on every double-buffered swap.
    fn index_generation(&self) -> u64 {
        0
    }

    /// Whether this head can serialize itself into a session snapshot.
    /// When any head of a session returns false the snapshot records the
    /// KV + group state only and the restore path rebuilds the retrievers
    /// (the fixed-set baselines' builds are cheap; the four index families
    /// all persist structurally and never rebuild).
    fn supports_save(&self) -> bool {
        false
    }

    /// Serialize this head's retrieval state (tag + structure, excluding
    /// the group-shared store/map, which the snapshot writes once per GQA
    /// group). Inverse: [`restore_retriever`].
    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        let _ = w;
        anyhow::bail!("{}: retriever persistence unsupported", self.name())
    }

    /// Copy-on-write fork of this head against an already-forked group
    /// (see [`GroupShared::fork`]). Index-backed heads share their
    /// published front `Arc` — zero copy at fork time; the first
    /// maintenance op on either side clones before mutating. `None` means
    /// the policy cannot fork cheaply and the caller falls back to a full
    /// retriever rebuild.
    fn fork_with_group(&self, group: Arc<GroupShared>) -> Option<Box<dyn HostRetriever>> {
        let _ = group;
        None
    }
}

/// Snapshot head tags (on-disk format constants — append-only).
const RETRIEVER_INDEX: u8 = 1;
const RETRIEVER_EMPTY: u8 = 2;
const RETRIEVER_ALL: u8 = 3;
const RETRIEVER_STREAMING: u8 = 4;

/// Restore one head from a snapshot stream: the inverse of
/// [`HostRetriever::save_state`], dispatched on the head tag. `group` is
/// the (layer, kv-head) group the head belongs to, already restored.
pub fn restore_retriever(
    r: &mut crate::store::codec::SnapReader<'_>,
    group: Arc<GroupShared>,
) -> anyhow::Result<Box<dyn HostRetriever>> {
    match r.u8()? {
        RETRIEVER_EMPTY => Ok(Box::new(EmptyRetriever)),
        RETRIEVER_ALL => Ok(Box::new(AllRetriever { group })),
        RETRIEVER_STREAMING => {
            let sinks = r.usize()?;
            let window = r.usize()?;
            Ok(Box::new(StreamingRetriever::new(group, sinks, window)))
        }
        RETRIEVER_INDEX => {
            let family = r.u8()?;
            let store_gen = r.u64()?;
            let rerank = r.usize()?;
            let ef = r.usize()?;
            let nprobe = r.usize()?;
            let label = match family {
                crate::index::FAMILY_FLAT => "Flat",
                crate::index::FAMILY_IVF => "IVF",
                crate::index::FAMILY_HNSW => "HNSW",
                crate::index::FAMILY_ROAR => "RetrievalAttention",
                other => anyhow::bail!("unknown index family tag {other} in head snapshot"),
            };
            let index = crate::index::load_index(family, group.keys(), r)?;
            Ok(Box::new(
                IndexRetriever {
                    front: Published::new(FrontIndex { index, store_gen }),
                    back: Mutex::new(BackBuffer { spare: None, pending: Vec::new() }),
                    group,
                    params: SearchParams { ef, nprobe },
                    rerank,
                    label,
                }
            ))
        }
        other => anyhow::bail!("unknown retriever tag {other} in snapshot"),
    }
}

/// Everything a retriever constructor may need.
pub struct RetrieverInputs<'a> {
    /// The GQA group's shared key store + id map.
    pub group: Arc<GroupShared>,
    /// This query head's prefill queries (training data for RoarGraph and
    /// scoring data for SnapKV).
    pub prefill_queries: &'a Matrix,
    /// Attention softmax scale (1/sqrt(d_h)).
    pub scale: f32,
    pub cfg: &'a RetrievalConfig,
    pub seed: u64,
}

impl<'a> RetrieverInputs<'a> {
    /// Convenience for tests/experiments: wrap a standalone key store +
    /// id list into a fresh (unshared) group. The configured quantized
    /// scan tier is applied here exactly as the engine applies it at
    /// prefill-build time — a `retrieval.quant` setting must never be
    /// silently ignored by one construction path.
    pub fn from_parts(
        keys: KeyStore,
        ids: Vec<u32>,
        prefill_queries: &'a Matrix,
        scale: f32,
        cfg: &'a RetrievalConfig,
        seed: u64,
    ) -> RetrieverInputs<'a> {
        RetrieverInputs {
            group: GroupShared::new(keys.with_quant(cfg.quant.mode), ids),
            prefill_queries,
            scale,
            cfg,
            seed,
        }
    }

    /// Snapshot of the group's dense key store.
    pub fn host_keys(&self) -> KeyStore {
        self.group.keys()
    }

    /// Snapshot of the group's dense→absolute id map at build time (the
    /// fixed-set baselines keep this shared `Arc`; index-backed
    /// retrievers track the live generation-stamped map instead).
    pub fn host_ids(&self) -> Arc<IdMap> {
        self.group.id_map()
    }
}

/// Build the retriever for a method.
pub fn build_retriever(method: Method, inp: RetrieverInputs<'_>) -> Box<dyn HostRetriever> {
    let index_retriever = |index: Box<dyn VectorIndex>, label: &'static str| {
        Box::new(
            IndexRetriever::new(
                index,
                inp.group.clone(),
                SearchParams { ef: inp.cfg.ef, nprobe: inp.cfg.nprobe },
                label,
            )
            .with_rerank(inp.cfg.quant.rerank),
        )
    };
    match method {
        Method::StreamingLlm => Box::new(EmptyRetriever),
        Method::Full | Method::VllmLike => Box::new(AllRetriever { group: inp.group.clone() }),
        Method::SnapKv => Box::new(snapkv::SnapKvRetriever::build(&inp)),
        Method::InfLlm => Box::new(infllm::InfLlmRetriever::build(&inp)),
        Method::Quest => Box::new(quest::QuestRetriever::build(&inp)),
        Method::InfiniGen => Box::new(infinigen::InfiniGenRetriever::build(&inp)),
        Method::Flat => index_retriever(Box::new(FlatIndex::new(inp.host_keys())), "Flat"),
        Method::Ivf => {
            index_retriever(Box::new(IvfIndex::build(inp.host_keys(), None, inp.seed)), "IVF")
        }
        Method::Hnsw => index_retriever(
            Box::new(HnswIndex::build(
                inp.host_keys(),
                HnswParams { m: inp.cfg.m, ef_construction: inp.cfg.ef.max(64), seed: inp.seed },
            )),
            "HNSW",
        ),
        Method::RetrievalAttention => index_retriever(
            Box::new(RoarGraph::build(
                inp.host_keys(),
                inp.prefill_queries,
                RoarParams {
                    kb: inp.cfg.kb,
                    m: inp.cfg.m,
                    repair_sample: 256,
                    rebuild_threshold: inp.cfg.maintenance.rebuild_threshold.max(1),
                },
            )),
            "RetrievalAttention",
        ),
    }
}

/// Policy-aware builder: a query head assigned the streaming tier by the
/// per-head policy layer ([`crate::policy`]) gets the index-free
/// [`StreamingRetriever`] instead of the method's ANN index. Only the
/// index-backed methods participate — the fixed-set baselines already
/// embody a per-method policy of their own, and replacing them would
/// change *their* semantics rather than specialize ours.
pub fn build_retriever_for_policy(
    method: Method,
    inp: RetrieverInputs<'_>,
    policy: crate::policy::HeadPolicy,
) -> Box<dyn HostRetriever> {
    if method.index_backed() {
        if let crate::policy::HeadPolicy::Streaming { sinks, window } = policy {
            return Box::new(StreamingRetriever::new(inp.group.clone(), sinks, window));
        }
    }
    build_retriever(method, inp)
}

/// The streaming-head tier (DuoAttention): a constant-length host set —
/// the group's first `sinks` and last `window` tokens — read straight off
/// the shared id map. No index, no search, no per-head state beyond two
/// lengths:
///
/// * **Maintenance**: inserts/removals/remaps are trivially "applied"
///   (the group-level map publish already did everything this head reads),
///   so a streaming head never blocks a mixed GQA group's drains,
///   evictions, or reclamation epochs — and holds no dense ids that a
///   compaction would have to renumber ([`HostRetriever::reclaim_counts`]
///   is `None`, taking the head out of the epoch trigger entirely).
/// * **Unlike [`EmptyRetriever`]** it does NOT discard inserts: the
///   tokens stay live for the group's retrieval heads; this head merely
///   chooses to read only the window. `discards_inserts` stays false so
///   exact-method drain gating is unaffected.
/// * **Reads the latest map generation** on every retrieve, so eviction
///   and reclamation never strand it (retired ids inside the window are
///   filtered by the engine's retired-id mask like any retrieved id).
pub struct StreamingRetriever {
    group: Arc<GroupShared>,
    sinks: usize,
    window: usize,
}

impl StreamingRetriever {
    pub fn new(group: Arc<GroupShared>, sinks: usize, window: usize) -> StreamingRetriever {
        StreamingRetriever { group, sinks, window }
    }
}

impl HostRetriever for StreamingRetriever {
    /// The constant-length sink+window set; ignores the query entirely
    /// and scores nothing (`scanned = 0`).
    fn retrieve(&self, _q: &[f32], _k: usize) -> Retrieval {
        let map = self.group.id_map();
        let n = map.len();
        if n <= self.sinks + self.window {
            return Retrieval { ids: map.ids.clone(), scanned: 0 };
        }
        let mut ids = Vec::with_capacity(self.sinks + self.window);
        ids.extend_from_slice(&map.ids[..self.sinks]);
        ids.extend_from_slice(&map.ids[n - self.window..]);
        Retrieval { ids, scanned: 0 }
    }

    fn name(&self) -> &'static str {
        "Streaming"
    }

    fn supports_insert(&self) -> bool {
        true
    }

    fn needs_store(&self) -> bool {
        false
    }

    /// The group-level drain already published the grown id map; the
    /// window slides forward by construction.
    fn insert_batch(&self, _store: &KeyStore, _ids: &[u32], _ctx: &InsertContext<'_>) -> bool {
        true
    }

    fn supports_remove(&self) -> bool {
        true
    }

    fn remove_batch(&self, _absolute_ids: &[u32]) -> bool {
        true
    }

    fn remove_dense(&self, _dense_ids: &[u32]) -> bool {
        true
    }

    fn supports_reclaim(&self) -> bool {
        true
    }

    /// No dense state: a remap is complete the moment the group publishes
    /// the new map, which the next retrieve reads.
    fn apply_remap(&self, _plan: &Arc<RemapPlan>) -> bool {
        true
    }

    fn supports_save(&self) -> bool {
        true
    }

    /// The host set is a view over the group map (written once per
    /// group); only the tag and the two window lengths are head-local —
    /// this is exactly the "snapshots omit index state for streaming
    /// heads" saving.
    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        w.u8(RETRIEVER_STREAMING)?;
        w.usize(self.sinks)?;
        w.usize(self.window)
    }

    fn fork_with_group(&self, group: Arc<GroupShared>) -> Option<Box<dyn HostRetriever>> {
        Some(Box::new(StreamingRetriever { group, sinks: self.sinks, window: self.window }))
    }
}

/// StreamingLLM: no host tokens at all. Inserts are "accepted" by
/// discarding — StreamingLLM's whole definition is that tokens outside
/// sink+window are dropped, so a drained overflow token simply ceases to
/// be attended. Removal is trivially supported (nothing is indexed).
pub struct EmptyRetriever;

impl HostRetriever for EmptyRetriever {
    fn retrieve(&self, _q: &[f32], _k: usize) -> Retrieval {
        Retrieval::default()
    }

    fn name(&self) -> &'static str {
        "StreamingLLM"
    }

    fn supports_insert(&self) -> bool {
        true
    }

    fn discards_inserts(&self) -> bool {
        true
    }

    fn needs_store(&self) -> bool {
        false
    }

    fn insert_batch(&self, _store: &KeyStore, _ids: &[u32], _ctx: &InsertContext<'_>) -> bool {
        true
    }

    fn supports_remove(&self) -> bool {
        true
    }

    fn remove_batch(&self, _absolute_ids: &[u32]) -> bool {
        true
    }

    fn remove_dense(&self, _dense_ids: &[u32]) -> bool {
        true
    }

    fn supports_save(&self) -> bool {
        true
    }

    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        w.u8(RETRIEVER_EMPTY)
    }

    fn fork_with_group(&self, _group: Arc<GroupShared>) -> Option<Box<dyn HostRetriever>> {
        Some(Box::new(EmptyRetriever))
    }
}

/// Full attention: every host token, no scan savings. The host set is the
/// group's shared id map — online drains keep it complete (and exact) for
/// arbitrarily long generations without a per-head copy.
pub struct AllRetriever {
    group: Arc<GroupShared>,
}

impl HostRetriever for AllRetriever {
    fn retrieve(&self, _q: &[f32], _k: usize) -> Retrieval {
        let map = self.group.id_map();
        Retrieval { ids: map.ids.clone(), scanned: map.len() }
    }

    fn name(&self) -> &'static str {
        "FullAttention"
    }

    fn supports_insert(&self) -> bool {
        true
    }

    fn needs_store(&self) -> bool {
        false
    }

    /// The group-level drain already published the grown id map; nothing
    /// head-local to do.
    fn insert_batch(&self, _store: &KeyStore, _ids: &[u32], _ctx: &InsertContext<'_>) -> bool {
        true
    }

    fn supports_save(&self) -> bool {
        true
    }

    /// The host set IS the group map, which the snapshot writes once per
    /// group — only the tag is head-local.
    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        w.u8(RETRIEVER_ALL)
    }

    fn fork_with_group(&self, group: Arc<GroupShared>) -> Option<Box<dyn HostRetriever>> {
        Some(Box::new(AllRetriever { group }))
    }
}

/// One index operation, as recorded in the double-buffer replay log.
enum IndexOp {
    Insert { store: KeyStore, count: usize, queries: Option<Matrix> },
    Remove { dense: Vec<u32> },
    /// Reclamation epoch: dense-id renumber + compacted-store adoption
    /// under a bumped store generation.
    Remap { plan: Arc<RemapPlan> },
}

/// The published front: the searchable index plus the store generation it
/// was built against. Dense ids are only meaningful within a generation,
/// so the stamp rides the same atomic publish as the index — a reader can
/// never pair a front with the wrong generation's id map.
struct FrontIndex {
    index: Box<dyn VectorIndex>,
    store_gen: u64,
}

fn apply_op(front: &mut FrontIndex, op: &IndexOp) -> bool {
    match op {
        IndexOp::Insert { store, count, queries } => {
            let old = front.index.len();
            if store.rows() != old + count {
                // Contract violation (caller's store is out of sync):
                // refuse rather than corrupt the dense↔absolute mapping.
                return false;
            }
            let ctx = InsertContext { recent_queries: queries.as_ref() };
            front.index.insert_batch(store.clone(), old..store.rows(), &ctx)
        }
        IndexOp::Remove { dense } => front.index.remove_batch(dense),
        IndexOp::Remap { plan } => {
            if front.index.remap_dense(plan) {
                front.store_gen = plan.store_gen;
                true
            } else {
                false
            }
        }
    }
}

/// The back buffer of the left/right scheme: the previously displaced
/// front plus the ops applied to the current front but not yet replayed
/// onto it.
struct BackBuffer {
    spare: Option<Arc<FrontIndex>>,
    pending: Vec<IndexOp>,
}

/// Any [`VectorIndex`] adapted to absolute ids, double-buffered for the
/// off-thread maintenance worker.
///
/// * **Read path** (decode): one `Arc` clone of the front index + one of
///   the group id map; the whole search then runs without any lock. The
///   id map is always at least as new as the index front (publish order),
///   so every dense id the search returns is mapped.
/// * **Write path** (worker): ops go through [`IndexRetriever::apply`] —
///   reclaim the spare buffer (the old front, once its readers drain),
///   replay the op log, apply the new op, publish with a generation bump,
///   and keep the displaced front as the next spare. Both buffers evolve
///   through the identical op sequence, so neither is ever rebuilt.
pub struct IndexRetriever {
    front: Published<FrontIndex>,
    back: Mutex<BackBuffer>,
    group: Arc<GroupShared>,
    params: SearchParams,
    /// Exact re-rank pool multiplier (`retrieval.quant.rerank`): searches
    /// over a quantized scan tier fetch `rerank × k` candidates and keep
    /// the exact top-k after f32 re-scoring. No-op on f32 stores.
    rerank: usize,
    label: &'static str,
}

impl IndexRetriever {
    pub fn new(
        index: Box<dyn VectorIndex>,
        group: Arc<GroupShared>,
        params: SearchParams,
        label: &'static str,
    ) -> IndexRetriever {
        let store_gen = group.store_generation();
        IndexRetriever {
            front: Published::new(FrontIndex { index, store_gen }),
            back: Mutex::new(BackBuffer { spare: None, pending: Vec::new() }),
            group,
            params,
            rerank: crate::config::QuantConfig::default().rerank,
            label,
        }
    }

    /// Override the exact re-rank pool multiplier (builder style).
    pub fn with_rerank(mut self, rerank: usize) -> IndexRetriever {
        self.rerank = rerank;
        self
    }

    /// Run `f` against the current front index (diagnostics).
    pub fn with_index<R>(&self, f: impl FnOnce(&dyn VectorIndex) -> R) -> R {
        let front = self.front.load();
        f(front.index.as_ref())
    }

    /// Left/right apply: see the type docs. Serialised by the back mutex;
    /// readers are never blocked (they hold only `Arc` snapshots).
    fn apply(&self, op: IndexOp) -> bool {
        // Poisoning is deliberately FATAL here, unlike `Published`'s
        // recover-and-continue: a panic inside a previous apply can leave
        // the spare/op-log pair mid-replay, and replaying a half-applied
        // log would corrupt the index. (Readers are unaffected either way
        // — they only touch the published front.)
        let mut back = self.back.lock().expect("back buffer poisoned");
        let mut front: FrontIndex = match back.spare.take() {
            Some(mut arc) => {
                // Reclaim exclusive ownership once in-flight readers of
                // the old front drop their snapshots. Searches are short,
                // so a brief yield loop almost always wins; a straggler
                // (e.g. a slow diagnostic holding the snapshot) triggers
                // the clone fallback instead of pinning a core.
                let mut spins = 0u32;
                loop {
                    match Arc::try_unwrap(arc) {
                        Ok(b) => break b,
                        Err(again) => {
                            if spins >= RECLAIM_SPINS_BEFORE_CLONE {
                                break FrontIndex {
                                    index: again.index.clone_index(),
                                    store_gen: again.store_gen,
                                };
                            }
                            arc = again;
                            spins += 1;
                            yield_now();
                        }
                    }
                }
            }
            // First op ever: split one clone off the front.
            None => {
                let f = self.front.load();
                FrontIndex { index: f.index.clone_index(), store_gen: f.store_gen }
            }
        };
        for prev in back.pending.drain(..) {
            let ok = apply_op(&mut front, &prev);
            debug_assert!(ok, "op replay diverged on the spare buffer");
        }
        if !apply_op(&mut front, &op) {
            // Refused: the spare is now exactly caught up with the front.
            back.spare = Some(Arc::new(front));
            return false;
        }
        let old = self.front.publish(Arc::new(front));
        back.spare = Some(old);
        back.pending.push(op);
        true
    }
}

impl HostRetriever for IndexRetriever {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval {
        // Pair the front with the id map of the SAME store generation.
        // Within a generation, snapshot order (index, then ids) is the
        // reverse of publish order (ids, then index): the map can only be
        // newer than the front, so every dense id is mapped. Across a
        // reclamation epoch the previous generation's map is retained
        // until every front is republished, so a same-generation map
        // exists for any front we can load; the retry only fires in the
        // instant a *second* epoch has already retired our generation —
        // by then the republished front is visible, so it terminates.
        let mut spins = 0u32;
        loop {
            let front = self.front.load();
            let Some(ids) = self.group.map_for_generation(front.store_gen) else {
                spins += 1;
                if spins >= RETRIEVE_SPINS_BEFORE_YIELD {
                    // Facade yield: under loom this is the voluntary hand-off
                    // that lets the republishing worker run.
                    yield_now();
                }
                continue;
            };
            debug_assert!(ids.len() >= front.index.len(), "id map behind the index front");
            // Quantized fronts re-rank the top `rerank × k` pool against
            // their own (same-generation) f32 keys; exact fronts search
            // plainly. Either way the dense ids map below.
            let r = search_rerank(front.index.as_ref(), q, k, self.rerank, &self.params);
            return Retrieval {
                ids: r.ids.iter().map(|&dense| ids.ids[dense as usize]).collect(),
                scanned: r.scanned,
            };
        }
    }

    fn name(&self) -> &'static str {
        self.label
    }

    fn memory_bytes(&self) -> usize {
        self.front.load().index.memory_bytes()
    }

    fn supports_insert(&self) -> bool {
        self.front.load().index.supports_insert()
    }

    fn insert_batch(&self, store: &KeyStore, ids: &[u32], ctx: &InsertContext<'_>) -> bool {
        let queries = ctx.recent_queries.filter(|m| m.rows() > 0).cloned();
        self.apply(IndexOp::Insert { store: store.clone(), count: ids.len(), queries })
    }

    fn supports_remove(&self) -> bool {
        self.front.load().index.supports_remove()
    }

    fn remove_batch(&self, absolute_ids: &[u32]) -> bool {
        if !self.supports_remove() {
            return false;
        }
        self.remove_dense(&self.group.dense_ids_for(absolute_ids))
    }

    fn remove_dense(&self, dense_ids: &[u32]) -> bool {
        if !self.supports_remove() {
            return false;
        }
        if dense_ids.is_empty() {
            return true;
        }
        self.apply(IndexOp::Remove { dense: dense_ids.to_vec() })
    }

    fn tombstones(&self) -> usize {
        self.front.load().index.tombstones()
    }

    fn supports_reclaim(&self) -> bool {
        self.front.load().index.supports_remap()
    }

    fn dense_dead_ids(&self) -> Vec<u32> {
        self.front.load().index.dead_ids()
    }

    fn reclaim_counts(&self) -> Option<(usize, usize)> {
        let front = self.front.load();
        Some((front.index.live_len(), front.index.tombstones()))
    }

    fn apply_remap(&self, plan: &Arc<RemapPlan>) -> bool {
        if !self.supports_reclaim() {
            return false;
        }
        self.apply(IndexOp::Remap { plan: plan.clone() })
    }

    fn indexed_len(&self) -> Option<usize> {
        Some(self.front.load().index.live_len())
    }

    fn index_generation(&self) -> u64 {
        self.front.generation()
    }

    fn supports_save(&self) -> bool {
        self.front.load().index.supports_save()
    }

    /// Persist the head: tag, family, the generation stamp the restored
    /// front must carry, the search knobs, then the family's structure.
    /// The caller quiesced maintenance first, so the front is the only
    /// truth (the spare buffer replays to it deterministically).
    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        let front = self.front.load();
        w.u8(RETRIEVER_INDEX)?;
        w.u8(front.index.family_tag())?;
        w.u64(front.store_gen)?;
        w.usize(self.rerank)?;
        w.usize(self.params.ef)?;
        w.usize(self.params.nprobe)?;
        front.index.save_state(w)
    }

    /// Copy-on-write fork: the fork's front IS the base's published front
    /// `Arc` — nothing is copied at fork time. Both sides keep applying
    /// maintenance through their own back buffers, whose first op clones
    /// the index before mutating (the `Arc` is never mutated in place:
    /// `apply` only writes to exclusively-owned buffers), so the shared
    /// frozen state diverges lazily on first write.
    fn fork_with_group(&self, group: Arc<GroupShared>) -> Option<Box<dyn HostRetriever>> {
        Some(Box::new(IndexRetriever {
            front: Published::from_arc(self.front.load()),
            back: Mutex::new(BackBuffer { spare: None, pending: Vec::new() }),
            group,
            params: self.params,
            rerank: self.rerank,
            label: self.label,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub(crate) fn test_inputs(n: usize, d: usize, seed: u64) -> (KeyStore, Vec<u32>, Matrix) {
        let mut rng = Rng::seed_from(seed);
        let keys = KeyStore::from_matrix(Matrix::from_fn(n, d, |_, _| rng.normal()));
        // Absolute ids offset by the sink size (host tokens start past it).
        let ids: Vec<u32> = (0..n as u32).map(|i| i + 128).collect();
        let queries =
            Matrix::from_fn(64, d, |_, c| rng.normal() + if c < d / 4 { 1.5 } else { 0.0 });
        (keys, ids, queries)
    }

    #[test]
    fn empty_retriever_is_empty() {
        let r = EmptyRetriever.retrieve(&[1.0, 2.0], 10);
        assert!(r.ids.is_empty());
        assert_eq!(r.scanned, 0);
    }

    #[test]
    fn all_retriever_returns_everything() {
        let (keys, ids, _) = test_inputs(50, 8, 1);
        let r = AllRetriever { group: GroupShared::new(keys, ids) };
        let out = r.retrieve(&[0.0; 8], 5);
        assert_eq!(out.ids.len(), 50);
        assert_eq!(out.scanned, 50);
    }

    #[test]
    fn every_method_builds_and_retrieves() {
        let (keys, ids, queries) = test_inputs(512, 16, 2);
        let cfg = RetrievalConfig::default();
        for method in Method::ALL {
            let inp =
                RetrieverInputs::from_parts(keys.clone(), ids.clone(), &queries, 0.25, &cfg, 3);
            let r = build_retriever(method, inp);
            let q: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
            let out = r.retrieve(&q, 20);
            // All ids must be valid absolute ids.
            for id in &out.ids {
                assert!(ids.contains(id), "{}: bogus id {id}", r.name());
            }
            if !matches!(method, Method::StreamingLlm) {
                assert!(!out.ids.is_empty(), "{}: empty retrieval", r.name());
            }
        }
    }

    #[test]
    fn index_retriever_maps_dense_to_absolute() {
        let (keys, ids, _) = test_inputs(100, 8, 4);
        let group = GroupShared::new(keys.clone(), ids.clone());
        let r = IndexRetriever::new(
            Box::new(FlatIndex::new(keys.clone())),
            group,
            SearchParams::default(),
            "Flat",
        );
        let q: Vec<f32> = keys.row(7).to_vec();
        let out = r.retrieve(&q, 1);
        assert_eq!(out.ids, vec![ids[7]]);
    }

    #[test]
    fn index_retriever_insert_extends_mapping_and_generation() {
        let (keys, ids, _) = test_inputs(64, 8, 6);
        let group = GroupShared::new(keys.clone(), ids.clone());
        let r = IndexRetriever::new(
            Box::new(FlatIndex::new(keys.clone())),
            group.clone(),
            SearchParams::default(),
            "Flat",
        );
        assert!(r.supports_insert());
        assert_eq!(r.index_generation(), 0);
        // Grow the shared store by two rows with fresh absolute ids — the
        // group-level extend first, then the head-level insert.
        let mut batch = Matrix::zeros(0, 8);
        batch.push_row(&[5.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        batch.push_row(&[0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let grown = group.extend(batch, &[900, 901], true);
        let ctx = InsertContext::none();
        assert!(r.insert_batch(&grown, &[900, 901], &ctx));
        assert_eq!(r.index_generation(), 1);
        let out = r.retrieve(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], 1);
        assert_eq!(out.ids, vec![900], "inserted token must map to its absolute id");
        // Out-of-sync store is refused and does not bump the front.
        assert!(!r.insert_batch(&grown, &[902], &ctx), "stale store must be rejected");
        assert_eq!(r.index_generation(), 1);
        // The next in-sync op still works (the spare buffer recovered).
        let grown2 = group.extend(
            Matrix::from_vec(1, 8, vec![0.0, 0.0, 7.0, 0.0, 0.0, 0.0, 0.0, 0.0]),
            &[903],
            true,
        );
        assert!(r.insert_batch(&grown2, &[903], &ctx));
        assert_eq!(r.index_generation(), 2);
        let out = r.retrieve(&[0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0], 1);
        assert_eq!(out.ids, vec![903]);
    }

    #[test]
    fn index_retriever_remove_tombstones_absolute_ids() {
        let (keys, ids, _) = test_inputs(64, 8, 9);
        let group = GroupShared::new(keys.clone(), ids.clone());
        let r = IndexRetriever::new(
            Box::new(FlatIndex::new(keys.clone())),
            group,
            SearchParams::default(),
            "Flat",
        );
        assert!(r.supports_remove());
        // An exhaustive scan surfaces key 7's absolute id — until removal.
        let q: Vec<f32> = keys.row(7).to_vec();
        assert!(r.retrieve(&q, 64).ids.contains(&ids[7]));
        assert!(r.remove_batch(&[ids[7]]));
        assert_eq!(r.tombstones(), 1);
        assert_eq!(r.indexed_len(), Some(63));
        let out = r.retrieve(&q, 64);
        assert!(!out.ids.contains(&ids[7]), "tombstoned absolute id returned");
        // Unknown absolute ids are a no-op, not an error.
        assert!(r.remove_batch(&[9999]));
        assert_eq!(r.tombstones(), 1);
    }

    #[test]
    fn index_retriever_reclamation_epoch_remaps_and_shrinks() {
        let (keys, ids, _) = test_inputs(64, 8, 12);
        let group = GroupShared::new(keys.clone(), ids.clone());
        let r = IndexRetriever::new(
            Box::new(FlatIndex::new(keys.clone())),
            group.clone(),
            SearchParams::default(),
            "Flat",
        );
        assert!(r.supports_reclaim());
        // Tombstone the first 16 dense slots via their absolute ids.
        assert!(r.remove_batch(&ids[..16]));
        assert_eq!(r.tombstones(), 16);
        assert_eq!(r.dense_dead_ids(), (0..16).collect::<Vec<u32>>());
        // Build the epoch's plan through the production planner (what
        // `Job::Compact` uses) and run the full publish order:
        // map -> store -> front -> prev drop.
        let dead = r.dense_dead_ids();
        let old_map = group.id_map();
        let gen = old_map.store_gen + 1;
        let (plan, keep) =
            RemapPlan::from_dead(&dead, &group.keys(), gen).expect("plan must build");
        let new_ids: Vec<u32> = keep.iter().map(|&o| old_map.ids[o as usize]).collect();
        let new_store = plan.store.clone();
        let plan = Arc::new(plan);
        group.publish_remap(new_ids, new_store, gen);
        // Mid-epoch: the retained previous map keeps the old front usable.
        assert!(group.map_for_generation(0).is_some(), "prev map must be retained");
        let out = r.retrieve(&keys.row(20).to_vec(), 48);
        assert!(out.ids.contains(&ids[20]));
        assert!(r.apply_remap(&plan));
        group.finish_remap();
        assert_eq!(group.store_generation(), 1);
        assert!(group.map_for_generation(0).is_none(), "prev map must be released");
        assert_eq!(group.id_map().len(), 48);
        assert_eq!(group.keys().rows(), 48);
        assert_eq!(r.tombstones(), 0);
        assert_eq!(r.indexed_len(), Some(48));
        // Survivors keep their absolute ids; the reclaimed prefix is gone.
        let out = r.retrieve(&keys.row(20).to_vec(), 48);
        assert!(out.ids.contains(&ids[20]), "survivor lost: {:?}", out.ids);
        for victim in &ids[..16] {
            assert!(!out.ids.contains(victim), "reclaimed id {victim} returned");
        }
        assert!(group.dense_ids_for(&ids[..16]).is_empty());
        // Drains continue against the compacted space.
        let grown = group.extend(
            Matrix::from_vec(1, 8, vec![7.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]),
            &[999],
            true,
        );
        assert!(r.insert_batch(&grown, &[999], &InsertContext::none()));
        let out = r.retrieve(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], 1);
        assert_eq!(out.ids, vec![999]);
    }

    #[test]
    fn all_retriever_sees_group_extend() {
        let (keys, ids, _) = test_inputs(10, 8, 7);
        let group = GroupShared::new(keys, ids);
        let r = AllRetriever { group: group.clone() };
        assert!(r.supports_insert());
        assert!(!r.needs_store());
        group.extend(Matrix::zeros(0, 8), &[500, 501], false);
        let out = r.retrieve(&[0.0; 8], 1);
        assert_eq!(out.ids.len(), 12);
        assert!(out.ids.contains(&501));
    }

    #[test]
    fn empty_retriever_discards_inserts() {
        let (keys, _, _) = test_inputs(10, 8, 8);
        assert!(EmptyRetriever.supports_insert());
        assert!(EmptyRetriever.insert_batch(&keys, &[1, 2], &InsertContext::none()));
        assert!(EmptyRetriever.retrieve(&[0.0; 8], 4).ids.is_empty());
        assert!(EmptyRetriever.supports_remove());
        assert!(EmptyRetriever.remove_batch(&[1]));
    }

    #[test]
    fn streaming_retriever_window_semantics() {
        let (keys, ids, _) = test_inputs(64, 8, 11);
        let group = GroupShared::new(keys, ids.clone());
        let r = StreamingRetriever::new(group.clone(), 4, 8);
        // Long map: first `sinks` ∪ last `window`, nothing scanned.
        let out = r.retrieve(&[0.0; 8], 32);
        assert_eq!(out.scanned, 0);
        let mut want: Vec<u32> = ids[..4].to_vec();
        want.extend_from_slice(&ids[64 - 8..]);
        assert_eq!(out.ids, want);
        // The window follows group growth with no insert participation.
        group.extend(Matrix::zeros(0, 8), &[900, 901], false);
        let out = r.retrieve(&[0.0; 8], 32);
        assert_eq!(out.ids.len(), 12);
        assert!(out.ids.ends_with(&[900, 901]));
        assert!(!out.ids.contains(&ids[4]));
        // Short map (len <= sinks+window): everything, no duplicates.
        let (keys, short_ids, _) = test_inputs(6, 8, 12);
        let small = GroupShared::new(keys, short_ids.clone());
        let out = StreamingRetriever::new(small, 4, 8).retrieve(&[0.0; 8], 32);
        assert_eq!(out.ids, short_ids);
    }

    #[test]
    fn streaming_retriever_is_maintenance_inert() {
        let (keys, ids, _) = test_inputs(32, 8, 13);
        let group = GroupShared::new(keys.clone(), ids.clone());
        let r = StreamingRetriever::new(group, 4, 8);
        assert!(r.supports_insert() && !r.discards_inserts() && !r.needs_store());
        assert!(r.insert_batch(&keys, &ids[..2], &InsertContext::none()));
        assert!(r.supports_remove() && r.remove_batch(&ids[..2]) && r.remove_dense(&[0, 1]));
        assert!(r.supports_reclaim());
        assert_eq!(r.tombstones(), 0);
        assert!(r.dense_dead_ids().is_empty());
        assert_eq!(r.reclaim_counts(), None, "must not gate reclamation epochs");
        assert_eq!(r.indexed_len(), None, "must not gate drain validation");
        assert_eq!(r.memory_bytes(), 0);
    }

    #[test]
    fn streaming_retriever_save_restore_and_fork() {
        let (keys, ids, _) = test_inputs(64, 8, 14);
        let group = GroupShared::new(keys, ids);
        let r = StreamingRetriever::new(group.clone(), 4, 8);
        assert!(r.supports_save());
        let mut buf = Vec::new();
        {
            let mut w = crate::store::codec::SnapWriter::new(&mut buf);
            r.save_state(&mut w).expect("save");
        }
        let mut src = &buf[..];
        let mut rd = crate::store::codec::SnapReader::new(&mut src);
        let restored = restore_retriever(&mut rd, group.clone()).expect("restore");
        assert_eq!(restored.name(), "Streaming");
        assert_eq!(restored.retrieve(&[0.0; 8], 32).ids, r.retrieve(&[0.0; 8], 32).ids);
        // COW fork: the clone reads the new group's map.
        let (keys2, ids2, _) = test_inputs(6, 8, 15);
        let g2 = GroupShared::new(keys2, ids2.clone());
        let forked = r.fork_with_group(g2).expect("streaming forks structurally");
        assert_eq!(forked.retrieve(&[0.0; 8], 32).ids, ids2);
    }
}
