//! Host-side retrieval policies: the paper's method and every baseline.
//!
//! The engine decomposes each decode step's attention into the device set
//! `W` (static pattern, always attended) and a host set chosen per query.
//! Each method is a [`HostRetriever`] deciding that host set:
//!
//! | Method              | Host set                                          |
//! |---------------------|---------------------------------------------------|
//! | FullAttention/vLLM  | every host token (exact)                          |
//! | StreamingLLM        | ∅ (device static pattern only)                    |
//! | SnapKV              | fixed set scored by the last prompt window        |
//! | InfLLM              | top blocks by representative-key score            |
//! | Quest               | top pages by min/max criticality bound            |
//! | InfiniGen           | top-k under a low-rank score speculation          |
//! | Flat                | exact KNN over host keys                          |
//! | IVF                 | IVF index search                                  |
//! | HNSW                | HNSW index search (ablation)                      |
//! | RetrievalAttention  | attention-aware RoarGraph search                  |
//!
//! Retrievers are built once per (layer, query-head) at prefill and are
//! immutable afterwards, so decode-time searches fan out across heads
//! (Appendix C).

pub mod infinigen;
pub mod infllm;
pub mod quest;
pub mod snapkv;

use crate::config::{Method, RetrievalConfig};
use crate::index::{
    flat::FlatIndex,
    hnsw::{HnswIndex, HnswParams},
    ivf::IvfIndex,
    roargraph::{RoarGraph, RoarParams},
    SearchParams, VectorIndex,
};
use crate::tensor::Matrix;
use std::sync::Arc;

/// Result of one host retrieval: *absolute* token ids + scan count.
#[derive(Clone, Debug, Default)]
pub struct Retrieval {
    pub ids: Vec<u32>,
    pub scanned: usize,
}

/// A per-(layer, query-head) host retrieval policy.
pub trait HostRetriever: Send + Sync {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval;
    fn name(&self) -> &'static str;
    /// Index/metadata heap bytes (memory accounting).
    fn memory_bytes(&self) -> usize {
        0
    }
    /// InfiniGen's defining trick: layer *l*'s critical tokens are
    /// speculated from layer *l−1*'s query (so the prefetch can overlap
    /// with layer l−1's compute). The engine passes the previous layer's
    /// query to retrievers that return true — and this speculation
    /// mismatch is exactly the accuracy gap Table 2 shows for InfiniGen.
    fn speculates_from_previous_layer(&self) -> bool {
        false
    }
}

/// Everything a retriever constructor may need.
pub struct RetrieverInputs<'a> {
    /// Dense host key matrix (rows = indexed host tokens, in id order).
    pub host_keys: Arc<Matrix>,
    /// Absolute token id per dense row.
    pub host_ids: Arc<Vec<u32>>,
    /// This query head's prefill queries (training data for RoarGraph and
    /// scoring data for SnapKV).
    pub prefill_queries: &'a Matrix,
    /// Attention softmax scale (1/sqrt(d_h)).
    pub scale: f32,
    pub cfg: &'a RetrievalConfig,
    pub seed: u64,
}

/// Build the retriever for a method.
pub fn build_retriever(method: Method, inp: RetrieverInputs<'_>) -> Box<dyn HostRetriever> {
    match method {
        Method::StreamingLlm => Box::new(EmptyRetriever),
        Method::Full | Method::VllmLike => Box::new(AllRetriever {
            ids: inp.host_ids.clone(),
            n: inp.host_keys.rows(),
        }),
        Method::SnapKv => Box::new(snapkv::SnapKvRetriever::build(&inp)),
        Method::InfLlm => Box::new(infllm::InfLlmRetriever::build(&inp)),
        Method::Quest => Box::new(quest::QuestRetriever::build(&inp)),
        Method::InfiniGen => Box::new(infinigen::InfiniGenRetriever::build(&inp)),
        Method::Flat => Box::new(IndexRetriever {
            index: Box::new(FlatIndex::new(inp.host_keys.clone())),
            ids: inp.host_ids.clone(),
            params: SearchParams { ef: inp.cfg.ef, nprobe: inp.cfg.nprobe },
            label: "Flat",
        }),
        Method::Ivf => Box::new(IndexRetriever {
            index: Box::new(IvfIndex::build(inp.host_keys.clone(), None, inp.seed)),
            ids: inp.host_ids.clone(),
            params: SearchParams { ef: inp.cfg.ef, nprobe: inp.cfg.nprobe },
            label: "IVF",
        }),
        Method::Hnsw => Box::new(IndexRetriever {
            index: Box::new(HnswIndex::build(
                inp.host_keys.clone(),
                HnswParams { m: inp.cfg.m, ef_construction: inp.cfg.ef.max(64), seed: inp.seed },
            )),
            ids: inp.host_ids.clone(),
            params: SearchParams { ef: inp.cfg.ef, nprobe: inp.cfg.nprobe },
            label: "HNSW",
        }),
        Method::RetrievalAttention => Box::new(IndexRetriever {
            index: Box::new(RoarGraph::build(
                inp.host_keys.clone(),
                inp.prefill_queries,
                RoarParams { kb: inp.cfg.kb, m: inp.cfg.m, repair_sample: 256 },
            )),
            ids: inp.host_ids.clone(),
            params: SearchParams { ef: inp.cfg.ef, nprobe: inp.cfg.nprobe },
            label: "RetrievalAttention",
        }),
    }
}

/// StreamingLLM: no host tokens at all.
pub struct EmptyRetriever;

impl HostRetriever for EmptyRetriever {
    fn retrieve(&self, _q: &[f32], _k: usize) -> Retrieval {
        Retrieval::default()
    }

    fn name(&self) -> &'static str {
        "StreamingLLM"
    }
}

/// Full attention: every host token, no scan savings.
pub struct AllRetriever {
    ids: Arc<Vec<u32>>,
    n: usize,
}

impl HostRetriever for AllRetriever {
    fn retrieve(&self, _q: &[f32], _k: usize) -> Retrieval {
        Retrieval { ids: self.ids.as_ref().clone(), scanned: self.n }
    }

    fn name(&self) -> &'static str {
        "FullAttention"
    }
}

/// Any [`VectorIndex`] adapted to absolute ids.
pub struct IndexRetriever {
    index: Box<dyn VectorIndex>,
    ids: Arc<Vec<u32>>,
    params: SearchParams,
    label: &'static str,
}

impl IndexRetriever {
    pub fn index(&self) -> &dyn VectorIndex {
        self.index.as_ref()
    }
}

impl HostRetriever for IndexRetriever {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval {
        let r = self.index.search(q, k, &self.params);
        Retrieval {
            ids: r.ids.iter().map(|&dense| self.ids[dense as usize]).collect(),
            scanned: r.scanned,
        }
    }

    fn name(&self) -> &'static str {
        self.label
    }

    fn memory_bytes(&self) -> usize {
        self.index.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub(crate) fn test_inputs(
        n: usize,
        d: usize,
        seed: u64,
    ) -> (Arc<Matrix>, Arc<Vec<u32>>, Matrix) {
        let mut rng = Rng::seed_from(seed);
        let keys = Arc::new(Matrix::from_fn(n, d, |_, _| rng.normal()));
        // Absolute ids offset by the sink size (host tokens start past it).
        let ids = Arc::new((0..n as u32).map(|i| i + 128).collect::<Vec<_>>());
        let queries = Matrix::from_fn(64, d, |_, c| rng.normal() + if c < d / 4 { 1.5 } else { 0.0 });
        (keys, ids, queries)
    }

    #[test]
    fn empty_retriever_is_empty() {
        let r = EmptyRetriever.retrieve(&[1.0, 2.0], 10);
        assert!(r.ids.is_empty());
        assert_eq!(r.scanned, 0);
    }

    #[test]
    fn all_retriever_returns_everything() {
        let (keys, ids, _) = test_inputs(50, 8, 1);
        let r = AllRetriever { ids: ids.clone(), n: keys.rows() };
        let out = r.retrieve(&[0.0; 8], 5);
        assert_eq!(out.ids.len(), 50);
        assert_eq!(out.scanned, 50);
    }

    #[test]
    fn every_method_builds_and_retrieves() {
        let (keys, ids, queries) = test_inputs(512, 16, 2);
        let cfg = RetrievalConfig::default();
        for method in Method::ALL {
            let inp = RetrieverInputs {
                host_keys: keys.clone(),
                host_ids: ids.clone(),
                prefill_queries: &queries,
                scale: 0.25,
                cfg: &cfg,
                seed: 3,
            };
            let r = build_retriever(method, inp);
            let q: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
            let out = r.retrieve(&q, 20);
            // All ids must be valid absolute ids.
            for id in &out.ids {
                assert!(ids.contains(id), "{}: bogus id {id}", r.name());
            }
            if !matches!(method, Method::StreamingLlm) {
                assert!(!out.ids.is_empty(), "{}: empty retrieval", r.name());
            }
        }
    }

    #[test]
    fn index_retriever_maps_dense_to_absolute() {
        let (keys, ids, _) = test_inputs(100, 8, 4);
        let r = IndexRetriever {
            index: Box::new(FlatIndex::new(keys.clone())),
            ids: ids.clone(),
            params: SearchParams::default(),
            label: "Flat",
        };
        let q: Vec<f32> = keys.row(7).to_vec();
        let out = r.retrieve(&q, 1);
        assert_eq!(out.ids, vec![ids[7]]);
    }
}
