//! Host-side retrieval policies: the paper's method and every baseline.
//!
//! The engine decomposes each decode step's attention into the device set
//! `W` (static pattern, always attended) and a host set chosen per query.
//! Each method is a [`HostRetriever`] deciding that host set:
//!
//! | Method              | Host set                                          |
//! |---------------------|---------------------------------------------------|
//! | FullAttention/vLLM  | every host token (exact)                          |
//! | StreamingLLM        | ∅ (device static pattern only)                    |
//! | SnapKV              | fixed set scored by the last prompt window        |
//! | InfLLM              | top blocks by representative-key score            |
//! | Quest               | top pages by min/max criticality bound            |
//! | InfiniGen           | top-k under a low-rank score speculation          |
//! | Flat                | exact KNN over host keys                          |
//! | IVF                 | IVF index search                                  |
//! | HNSW                | HNSW index search (ablation)                      |
//! | RetrievalAttention  | attention-aware RoarGraph search                  |
//!
//! Retrievers are built once per (layer, query-head) at prefill; methods
//! with a live index additionally accept [`HostRetriever::insert_batch`]
//! so the engine can drain decoded tokens into the searchable set.
//! Decode-time searches still fan out across heads (Appendix C) — inserts
//! synchronise through per-retriever read/write locks.

pub mod infinigen;
pub mod infllm;
pub mod quest;
pub mod snapkv;

use crate::config::{Method, RetrievalConfig};
use crate::index::{
    flat::FlatIndex,
    hnsw::{HnswIndex, HnswParams},
    ivf::IvfIndex,
    roargraph::{RoarGraph, RoarParams},
    InsertContext, SearchParams, VectorIndex,
};
use crate::tensor::Matrix;
use std::sync::{Arc, RwLock};

/// Result of one host retrieval: *absolute* token ids + scan count.
#[derive(Clone, Debug, Default)]
pub struct Retrieval {
    pub ids: Vec<u32>,
    pub scanned: usize,
}

/// A per-(layer, query-head) host retrieval policy.
pub trait HostRetriever: Send + Sync {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval;
    fn name(&self) -> &'static str;
    /// Index/metadata heap bytes (memory accounting).
    fn memory_bytes(&self) -> usize {
        0
    }
    /// InfiniGen's defining trick: layer *l*'s critical tokens are
    /// speculated from layer *l−1*'s query (so the prefetch can overlap
    /// with layer l−1's compute). The engine passes the previous layer's
    /// query to retrievers that return true — and this speculation
    /// mismatch is exactly the accuracy gap Table 2 shows for InfiniGen.
    fn speculates_from_previous_layer(&self) -> bool {
        false
    }

    /// Whether [`HostRetriever::insert_batch`] can succeed. The engine only
    /// drains a cache's overflow buffer when every query head of the GQA
    /// group accepts inserts.
    fn supports_insert(&self) -> bool {
        false
    }

    /// True when this retriever "accepts" inserts by dropping the tokens
    /// (StreamingLLM semantics). Callers use this to (a) refuse
    /// discard-drains for sessions whose method promises exact attention,
    /// and (b) skip growing the shared key store for data nobody reads.
    fn discards_inserts(&self) -> bool {
        false
    }

    /// Whether [`HostRetriever::insert_batch`] actually reads `store`.
    /// When every head of a group returns false the caller may pass a
    /// stale store and skip the grow-and-copy entirely (AllRetriever only
    /// tracks ids; EmptyRetriever reads nothing).
    fn needs_store(&self) -> bool {
        true
    }

    /// Fold newly decoded host tokens into the searchable set.
    ///
    /// `store` is the grown dense key matrix shared by the whole GQA group
    /// (one copy per kv head, Appendix C): rows `[0, store.rows() -
    /// ids.len())` are unchanged from the previous drain, the final
    /// `ids.len()` rows are the new key vectors, and `ids` carries their
    /// absolute token ids. Takes `&self` — retrievers that support inserts
    /// use interior locking so decode-time searches keep fanning out
    /// lock-free across heads.
    ///
    /// Returns `false` when unsupported (fixed-set baselines): the caller
    /// keeps those tokens in the linearly-scanned overflow buffer.
    fn insert_batch(&self, store: &Arc<Matrix>, ids: &[u32], ctx: &InsertContext<'_>) -> bool {
        let _ = (store, ids, ctx);
        false
    }
}

/// Everything a retriever constructor may need.
pub struct RetrieverInputs<'a> {
    /// Dense host key matrix (rows = indexed host tokens, in id order).
    pub host_keys: Arc<Matrix>,
    /// Absolute token id per dense row.
    pub host_ids: Arc<Vec<u32>>,
    /// This query head's prefill queries (training data for RoarGraph and
    /// scoring data for SnapKV).
    pub prefill_queries: &'a Matrix,
    /// Attention softmax scale (1/sqrt(d_h)).
    pub scale: f32,
    pub cfg: &'a RetrievalConfig,
    pub seed: u64,
}

/// Build the retriever for a method.
pub fn build_retriever(method: Method, inp: RetrieverInputs<'_>) -> Box<dyn HostRetriever> {
    let index_retriever = |index: Box<dyn VectorIndex>, label: &'static str| {
        Box::new(IndexRetriever {
            index: RwLock::new(index),
            ids: RwLock::new(inp.host_ids.as_ref().clone()),
            params: SearchParams { ef: inp.cfg.ef, nprobe: inp.cfg.nprobe },
            label,
        })
    };
    match method {
        Method::StreamingLlm => Box::new(EmptyRetriever),
        Method::Full | Method::VllmLike => Box::new(AllRetriever {
            ids: RwLock::new(inp.host_ids.as_ref().clone()),
        }),
        Method::SnapKv => Box::new(snapkv::SnapKvRetriever::build(&inp)),
        Method::InfLlm => Box::new(infllm::InfLlmRetriever::build(&inp)),
        Method::Quest => Box::new(quest::QuestRetriever::build(&inp)),
        Method::InfiniGen => Box::new(infinigen::InfiniGenRetriever::build(&inp)),
        Method::Flat => index_retriever(Box::new(FlatIndex::new(inp.host_keys.clone())), "Flat"),
        Method::Ivf => {
            index_retriever(Box::new(IvfIndex::build(inp.host_keys.clone(), None, inp.seed)), "IVF")
        }
        Method::Hnsw => index_retriever(
            Box::new(HnswIndex::build(
                inp.host_keys.clone(),
                HnswParams { m: inp.cfg.m, ef_construction: inp.cfg.ef.max(64), seed: inp.seed },
            )),
            "HNSW",
        ),
        Method::RetrievalAttention => index_retriever(
            Box::new(RoarGraph::build(
                inp.host_keys.clone(),
                inp.prefill_queries,
                RoarParams {
                    kb: inp.cfg.kb,
                    m: inp.cfg.m,
                    repair_sample: 256,
                    rebuild_threshold: inp.cfg.maintenance.rebuild_threshold.max(1),
                },
            )),
            "RetrievalAttention",
        ),
    }
}

/// StreamingLLM: no host tokens at all. Inserts are "accepted" by
/// discarding — StreamingLLM's whole definition is that tokens outside
/// sink+window are dropped, so a drained overflow token simply ceases to
/// be attended.
pub struct EmptyRetriever;

impl HostRetriever for EmptyRetriever {
    fn retrieve(&self, _q: &[f32], _k: usize) -> Retrieval {
        Retrieval::default()
    }

    fn name(&self) -> &'static str {
        "StreamingLLM"
    }

    fn supports_insert(&self) -> bool {
        true
    }

    fn discards_inserts(&self) -> bool {
        true
    }

    fn needs_store(&self) -> bool {
        false
    }

    fn insert_batch(&self, _store: &Arc<Matrix>, _ids: &[u32], _ctx: &InsertContext<'_>) -> bool {
        true
    }
}

/// Full attention: every host token, no scan savings. Online inserts keep
/// the host set complete (and exact) for arbitrarily long generations.
pub struct AllRetriever {
    ids: RwLock<Vec<u32>>,
}

impl HostRetriever for AllRetriever {
    fn retrieve(&self, _q: &[f32], _k: usize) -> Retrieval {
        let ids = self.ids.read().unwrap().clone();
        let n = ids.len();
        Retrieval { ids, scanned: n }
    }

    fn name(&self) -> &'static str {
        "FullAttention"
    }

    fn supports_insert(&self) -> bool {
        true
    }

    fn needs_store(&self) -> bool {
        false
    }

    fn insert_batch(&self, _store: &Arc<Matrix>, ids: &[u32], _ctx: &InsertContext<'_>) -> bool {
        self.ids.write().unwrap().extend_from_slice(ids);
        true
    }
}

/// Any [`VectorIndex`] adapted to absolute ids. The index and the
/// dense→absolute id map sit behind read/write locks so decode-time
/// searches (read) and overflow drains (write) can share one retriever
/// across the engine's head-parallel fan-out.
pub struct IndexRetriever {
    index: RwLock<Box<dyn VectorIndex>>,
    ids: RwLock<Vec<u32>>,
    params: SearchParams,
    label: &'static str,
}

impl IndexRetriever {
    /// Run `f` against the underlying vector index (diagnostics).
    pub fn with_index<R>(&self, f: impl FnOnce(&dyn VectorIndex) -> R) -> R {
        f(self.index.read().unwrap().as_ref())
    }
}

impl HostRetriever for IndexRetriever {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval {
        let index = self.index.read().unwrap();
        let ids = self.ids.read().unwrap();
        let r = index.search(q, k, &self.params);
        Retrieval {
            ids: r.ids.iter().map(|&dense| ids[dense as usize]).collect(),
            scanned: r.scanned,
        }
    }

    fn name(&self) -> &'static str {
        self.label
    }

    fn memory_bytes(&self) -> usize {
        self.index.read().unwrap().memory_bytes()
    }

    fn supports_insert(&self) -> bool {
        self.index.read().unwrap().supports_insert()
    }

    fn insert_batch(&self, store: &Arc<Matrix>, ids: &[u32], ctx: &InsertContext<'_>) -> bool {
        // Lock order (index, then ids) matches `retrieve`.
        let mut index = self.index.write().unwrap();
        let old = index.len();
        if store.rows() != old + ids.len() {
            // Contract violation (caller's store is out of sync): refuse
            // rather than corrupt the dense↔absolute mapping.
            return false;
        }
        if !index.insert_batch(store.clone(), old..store.rows(), ctx) {
            return false;
        }
        self.ids.write().unwrap().extend_from_slice(ids);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub(crate) fn test_inputs(
        n: usize,
        d: usize,
        seed: u64,
    ) -> (Arc<Matrix>, Arc<Vec<u32>>, Matrix) {
        let mut rng = Rng::seed_from(seed);
        let keys = Arc::new(Matrix::from_fn(n, d, |_, _| rng.normal()));
        // Absolute ids offset by the sink size (host tokens start past it).
        let ids = Arc::new((0..n as u32).map(|i| i + 128).collect::<Vec<_>>());
        let queries = Matrix::from_fn(64, d, |_, c| rng.normal() + if c < d / 4 { 1.5 } else { 0.0 });
        (keys, ids, queries)
    }

    #[test]
    fn empty_retriever_is_empty() {
        let r = EmptyRetriever.retrieve(&[1.0, 2.0], 10);
        assert!(r.ids.is_empty());
        assert_eq!(r.scanned, 0);
    }

    #[test]
    fn all_retriever_returns_everything() {
        let (_keys, ids, _) = test_inputs(50, 8, 1);
        let r = AllRetriever { ids: RwLock::new(ids.as_ref().clone()) };
        let out = r.retrieve(&[0.0; 8], 5);
        assert_eq!(out.ids.len(), 50);
        assert_eq!(out.scanned, 50);
    }

    #[test]
    fn every_method_builds_and_retrieves() {
        let (keys, ids, queries) = test_inputs(512, 16, 2);
        let cfg = RetrievalConfig::default();
        for method in Method::ALL {
            let inp = RetrieverInputs {
                host_keys: keys.clone(),
                host_ids: ids.clone(),
                prefill_queries: &queries,
                scale: 0.25,
                cfg: &cfg,
                seed: 3,
            };
            let r = build_retriever(method, inp);
            let q: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
            let out = r.retrieve(&q, 20);
            // All ids must be valid absolute ids.
            for id in &out.ids {
                assert!(ids.contains(id), "{}: bogus id {id}", r.name());
            }
            if !matches!(method, Method::StreamingLlm) {
                assert!(!out.ids.is_empty(), "{}: empty retrieval", r.name());
            }
        }
    }

    #[test]
    fn index_retriever_maps_dense_to_absolute() {
        let (keys, ids, _) = test_inputs(100, 8, 4);
        let r = IndexRetriever {
            index: RwLock::new(Box::new(FlatIndex::new(keys.clone()))),
            ids: RwLock::new(ids.as_ref().clone()),
            params: SearchParams::default(),
            label: "Flat",
        };
        let q: Vec<f32> = keys.row(7).to_vec();
        let out = r.retrieve(&q, 1);
        assert_eq!(out.ids, vec![ids[7]]);
    }

    #[test]
    fn index_retriever_insert_extends_mapping() {
        let (keys, ids, _) = test_inputs(64, 8, 6);
        let r = IndexRetriever {
            index: RwLock::new(Box::new(FlatIndex::new(keys.clone()))),
            ids: RwLock::new(ids.as_ref().clone()),
            params: SearchParams::default(),
            label: "Flat",
        };
        assert!(r.supports_insert());
        // Grow the shared store by two rows with fresh absolute ids.
        let mut grown = (*keys).clone();
        grown.push_row(&[5.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        grown.push_row(&[0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let grown = Arc::new(grown);
        let ctx = InsertContext::none();
        assert!(r.insert_batch(&grown, &[900, 901], &ctx));
        let out = r.retrieve(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], 1);
        assert_eq!(out.ids, vec![900], "inserted token must map to its absolute id");
        // Out-of-sync store is refused.
        assert!(!r.insert_batch(&grown, &[902], &ctx), "stale store must be rejected");
    }

    #[test]
    fn all_retriever_accepts_inserts() {
        let (keys, ids, _) = test_inputs(10, 8, 7);
        let r = AllRetriever { ids: RwLock::new(ids.as_ref().clone()) };
        assert!(r.supports_insert());
        assert!(r.insert_batch(&keys, &[500, 501], &InsertContext::none()));
        let out = r.retrieve(&[0.0; 8], 1);
        assert_eq!(out.ids.len(), 12);
        assert!(out.ids.contains(&501));
    }

    #[test]
    fn empty_retriever_discards_inserts() {
        let (keys, _, _) = test_inputs(10, 8, 8);
        assert!(EmptyRetriever.supports_insert());
        assert!(EmptyRetriever.insert_batch(&keys, &[1, 2], &InsertContext::none()));
        assert!(EmptyRetriever.retrieve(&[0.0; 8], 4).ids.is_empty());
    }
}
