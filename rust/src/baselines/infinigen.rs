//! InfiniGen (Lee et al. 2024): speculative top-k via low-rank score
//! approximation.
//!
//! Keys are pre-projected into an r-dimensional sketch; at decode time the
//! query is sketched the same way and approximate scores pick the top-k
//! tokens, which are then attended exactly. Cheap (r ≪ d per key) but the
//! sketch loses rank — the paper observes a noticeable accuracy drop from
//! speculation misses (Table 2: InfiniGen −4.6 vs full attention).

use super::{HostRetriever, IdMap, Retrieval, RetrieverInputs};
use crate::tensor::{argtopk, dot, Matrix};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Sketch rank (channel reduction d -> R).
const R: usize = 16;

pub struct InfiniGenRetriever {
    ids: Arc<IdMap>,
    /// Random projection `[d, R]` (shared by keys and queries).
    proj: Matrix,
    /// Projected keys `[n, R]`.
    sketches: Matrix,
    d: usize,
}

impl InfiniGenRetriever {
    pub fn build(inp: &RetrieverInputs<'_>) -> Self {
        let keys = inp.host_keys();
        let n = keys.rows();
        let d = keys.cols();
        let mut rng = Rng::seed_from(inp.seed ^ 0x1AF1_6E4);
        let scale = 1.0 / (R as f32).sqrt();
        let proj = Matrix::from_fn(d, R, |_, _| rng.normal() * scale);
        let mut sketches = Matrix::zeros(n, R);
        for i in 0..n {
            let key = keys.row(i);
            let out = sketches.row_mut(i);
            for (j, o) in out.iter_mut().enumerate() {
                let mut s = 0.0;
                for (kk, &kv) in key.iter().enumerate() {
                    s += kv * proj[(kk, j)];
                }
                *o = s;
            }
        }
        InfiniGenRetriever { ids: inp.host_ids(), proj, sketches, d }
    }
}

impl HostRetriever for InfiniGenRetriever {
    fn retrieve(&self, q: &[f32], k: usize) -> Retrieval {
        let n = self.sketches.rows();
        if n == 0 {
            return Retrieval::default();
        }
        // Sketch the query.
        let mut qs = vec![0.0f32; R];
        for (j, o) in qs.iter_mut().enumerate() {
            let mut s = 0.0;
            for (i, &qv) in q.iter().enumerate() {
                s += qv * self.proj[(i, j)];
            }
            *o = s;
        }
        // Approximate scores over all sketches.
        let scores: Vec<f32> = (0..n).map(|i| dot(&qs, self.sketches.row(i))).collect();
        let top = argtopk(&scores, k.min(n));
        // Scan cost: n sketch reads of R dims ≈ n*R/d full-key equivalents.
        let scanned = (n * R).div_ceil(self.d);
        Retrieval { ids: top.into_iter().map(|i| self.ids.ids[i]).collect(), scanned }
    }

    fn name(&self) -> &'static str {
        "InfiniGen"
    }

    fn speculates_from_previous_layer(&self) -> bool {
        true
    }

    fn memory_bytes(&self) -> usize {
        (self.sketches.as_slice().len() + self.proj.as_slice().len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::test_inputs;
    use crate::config::RetrievalConfig;
    use crate::index::KeyStore;

    fn build(n: usize, d: usize, seed: u64) -> (InfiniGenRetriever, KeyStore, Vec<u32>) {
        let (keys, ids, queries) = test_inputs(n, d, seed);
        let cfg = RetrievalConfig::default();
        let inp =
            RetrieverInputs::from_parts(keys.clone(), ids.clone(), &queries, 0.25, &cfg, seed);
        (InfiniGenRetriever::build(&inp), keys, ids)
    }

    #[test]
    fn speculation_finds_strong_signal() {
        // A key with an overwhelming inner product must survive sketching.
        let (_, _, _) = build(10, 16, 1);
        let mut rng = Rng::seed_from(2);
        let mut keys = Matrix::from_fn(400, 32, |_, _| rng.normal() * 0.3);
        let q: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        // Plant key 217 = 5x the query direction.
        for (j, v) in keys.row_mut(217).iter_mut().enumerate() {
            *v = q[j] * 5.0;
        }
        let ids: Vec<u32> = (0..400u32).collect();
        let queries = Matrix::from_fn(4, 32, |_, _| 0.1);
        let cfg = RetrievalConfig::default();
        let inp =
            RetrieverInputs::from_parts(KeyStore::from_matrix(keys), ids, &queries, 0.2, &cfg, 3);
        let r = InfiniGenRetriever::build(&inp);
        let out = r.retrieve(&q, 20);
        assert!(out.ids.contains(&217), "planted key missed by speculation");
    }

    #[test]
    fn approximation_is_lossy() {
        // With rank 16 << d and near-uniform scores, speculation should NOT
        // perfectly match exact top-k — that loss is InfiniGen's accuracy
        // story in Table 2.
        let (r, keys, ids) = build(2000, 64, 4);
        let mut rng = Rng::seed_from(5);
        let q: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let exact: Vec<u32> = crate::index::exact_topk_store(&keys, &q, 50)
            .iter()
            .map(|&i| ids[i as usize])
            .collect();
        let out = r.retrieve(&q, 50);
        let hits = out.ids.iter().filter(|i| exact.contains(i)).count();
        // Random chance would be 50*50/2000 ≈ 1.25 hits; the sketch must
        // beat that, but rank 16 ≪ 64 on near-uniform scores is far from
        // exact — this lossiness is InfiniGen's Table-2 accuracy story.
        assert!(hits >= 3, "sketch should keep some signal: {hits}/50");
        assert!(hits < 45, "rank-16 sketch should not be near-exact");
    }

    #[test]
    fn scan_cost_reflects_rank_reduction() {
        let (r, _, _) = build(1000, 64, 6);
        let out = r.retrieve(&[0.1; 64], 10);
        assert_eq!(out.scanned, 1000 * R / 64);
    }
}
