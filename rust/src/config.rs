//! The serving configuration system.
//!
//! Everything the launcher needs is described by one [`ServeConfig`]
//! (JSON on disk, `--config` on the CLI), mirroring how vLLM/SGLang expose
//! engine knobs: model preset, retrieval method, index/build parameters,
//! static pattern, scheduler limits, hardware profile. Serialization goes
//! through the in-crate [`crate::util::json`] module.

use crate::attention::budget::BudgetPolicy;
use crate::kernel::QuantMode;
use crate::kvcache::StaticPattern;
use crate::policy::HeadPolicyConfig;
use crate::util::json::{self, Value};
use std::path::Path;

/// Which attention/retrieval method the engine uses — every comparator row
/// of Tables 2–4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Exact attention over the full KV cache.
    Full,
    /// vLLM-like: full attention with paged device KV (OOMs past budget).
    VllmLike,
    /// Sink + window only (drops the rest).
    StreamingLlm,
    /// Critical tokens observed from the last prompt window.
    SnapKv,
    /// Block representatives, top-k blocks retrieved from host.
    InfLlm,
    /// Page min/max criticality bound.
    Quest,
    /// Low-rank speculation of important tokens.
    InfiniGen,
    /// Exact KNN over host keys.
    Flat,
    /// IVF index over host keys.
    Ivf,
    /// HNSW index over host keys (ablation; not in the paper's main tables).
    Hnsw,
    /// The paper's method: attention-aware RoarGraph index.
    RetrievalAttention,
}

impl Method {
    pub const ALL: [Method; 11] = [
        Method::Full,
        Method::VllmLike,
        Method::StreamingLlm,
        Method::SnapKv,
        Method::InfLlm,
        Method::Quest,
        Method::InfiniGen,
        Method::Flat,
        Method::Ivf,
        Method::Hnsw,
        Method::RetrievalAttention,
    ];

    /// Display name matching the paper's tables.
    pub fn label(&self) -> &'static str {
        match self {
            Method::Full => "FullAttention",
            Method::VllmLike => "vLLM",
            Method::StreamingLlm => "StreamingLLM",
            Method::SnapKv => "SnapKV",
            Method::InfLlm => "InfLLM",
            Method::Quest => "Quest",
            Method::InfiniGen => "InfiniGen",
            Method::Flat => "Flat",
            Method::Ivf => "IVF",
            Method::Hnsw => "HNSW",
            Method::RetrievalAttention => "RetrievalAttention",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.label().eq_ignore_ascii_case(s))
    }

    /// Whether the method's host tier is an ANN index over the full host
    /// set. These are the methods the per-head policy layer
    /// ([`crate::policy`]) can specialize: a streaming head swaps its
    /// index for a constant sink+window view. The fixed-set baselines
    /// (StreamingLLM, SnapKV, ...) already embody a per-method policy of
    /// their own and are left untouched.
    pub fn index_backed(&self) -> bool {
        matches!(
            self,
            Method::Flat | Method::Ivf | Method::Hnsw | Method::RetrievalAttention
        )
    }
}

/// Online index-maintenance knobs: how decoded KV vectors are folded back
/// into the ANN substrate (the overflow→index drain).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MaintenanceConfig {
    /// Overflow tokens per (layer, kv-head) that trigger a batched drain
    /// into the index. `0` disables online *index* maintenance (the
    /// overflow buffer then grows unbounded and is scanned linearly —
    /// the paper's original build-once behaviour). StreamingLLM sessions
    /// drop overflow tokens regardless: that is the method's semantics,
    /// not a maintenance policy.
    pub drain_watermark: usize,
    /// Recent decode queries retained per query head; they become the
    /// bipartite training side when RoarGraph wires inserted keys.
    pub recent_queries: usize,
    /// Online inserts tolerated before a full index re-projection.
    pub rebuild_threshold: usize,
    /// Run drains/evictions on the background maintenance worker (double-
    /// buffered index swap, completions applied next step) instead of
    /// inline at the end of the decode step.
    pub async_worker: bool,
}

impl Default for MaintenanceConfig {
    fn default() -> Self {
        // drain_watermark re-tuned for the segmented store + off-thread
        // worker (see ROADMAP "maintenance knob tuning"): with the
        // O(context) per-drain copy gone and inserts off the token path,
        // the watermark's only remaining cost term is the exact scan over
        // the overflow buffer — so it drops from 64 to 32 to halve that
        // scan, and larger values no longer buy anything.
        MaintenanceConfig {
            drain_watermark: 32,
            recent_queries: 32,
            rebuild_threshold: 4096,
            async_worker: true,
        }
    }
}

impl MaintenanceConfig {
    pub fn enabled(&self) -> bool {
        self.drain_watermark > 0
    }
}

/// Host-side eviction policy: StreamingLLM-style retirement of the oldest
/// indexed tokens once a group's live indexed tier exceeds `max_indexed`
/// (Ltri-LLM-style streaming workloads continuously retire tokens that
/// would otherwise linger in the indexes forever). Retired tokens are
/// dropped from attention immediately and tombstoned in every head's
/// index by the maintenance worker. Tombstoned rows are *physically*
/// reclaimed by the generation-based remap governed by `reclaim_ratio`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvictionConfig {
    /// Live indexed tokens retained per (layer, kv-head). `0` disables
    /// eviction (the paper's unbounded host set).
    pub max_indexed: usize,
    /// Reclamation-epoch trigger: once the tombstones accumulated in a
    /// GQA group's indexes exceed `reclaim_ratio` × the *live* row count,
    /// the maintenance worker runs a `Job::Compact` — it rebuilds the
    /// group's segmented key store and dense→absolute id map with the
    /// dead rows dropped, renumbers the surviving dense ids contiguously,
    /// and remaps every head's index under a bumped **store generation**
    /// (flat/IVF rewrite their id lists exactly; HNSW relabels its graph
    /// in place; RoarGraph relabels its CSR and re-runs connectivity
    /// repair, trading a little recall noise for zero rebuild cost).
    /// This is what turns tombstoning into memory that actually shrinks:
    /// host bytes stay ≤ (1 + ratio) × live instead of growing without
    /// bound over a streaming session. `0.0` disables reclamation
    /// (tombstoned K/V rows then occupy host memory until an
    /// index-family-internal rebuild happens to drop them). Default 0.5:
    /// one epoch per ~50% garbage, balancing remap cost (O(live) per
    /// epoch, off the token path) against peak memory overhead.
    pub reclaim_ratio: f32,
}

impl Default for EvictionConfig {
    fn default() -> Self {
        EvictionConfig { max_indexed: 0, reclaim_ratio: 0.5 }
    }
}

impl EvictionConfig {
    pub fn enabled(&self) -> bool {
        self.max_indexed > 0
    }

    /// Whether reclamation epochs (physical tombstone reclamation) run.
    /// Independent of `enabled()`: truncation-heavy sessions accumulate
    /// tombstones without any eviction window configured.
    pub fn reclaim_enabled(&self) -> bool {
        self.reclaim_ratio > 0.0
    }
}

/// Quantized scan-tier knobs (`retrieval.quant`).
///
/// With a mode enabled, the segmented key store keeps a compressed mirror
/// per chunk (`fp16` = bit-truncated f32/bfloat16, 2 B/dim; `int8` =
/// symmetric per-row scale, 1 B/dim + 4 B/row) and **all four index
/// families rank candidates against it** — the bandwidth-bound scan moves
/// 2–4× fewer key bytes. Exactness is preserved where it matters: the
/// host attention read (`attend_subset`) always uses the f32 keys, and
/// the top `rerank × k` candidates of each search are re-scored exactly
/// against the f32 rows before the final top-k is returned, so
/// quantization error is confined to candidate ordering beyond the
/// re-rank pool. Mirrors are built at prefill-build and maintenance-
/// worker (drain/compact) time — never on the decode token path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    /// Scan-tier format: `off` (exact f32 scan), `fp16`, or `int8`.
    pub mode: QuantMode,
    /// Exact re-rank pool multiplier: the top `rerank × k` quantized
    /// candidates are re-scored against f32 keys (paper-style exactness
    /// confinement). `0` or `1` disables the re-rank pass. Ignored when
    /// `mode = off`.
    pub rerank: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        // Off by default: the exact-f32 behaviour of every earlier PR.
        // `rerank = 2` is the recommended pool (2×k) the moment a mode is
        // switched on.
        QuantConfig { mode: QuantMode::Off, rerank: 2 }
    }
}

/// Retrieval/index knobs.
#[derive(Clone, Copy, Debug)]
pub struct RetrievalConfig {
    /// Tokens retrieved per decode step (paper default: top-100).
    pub top_k: usize,
    /// Graph beam width at search time.
    pub ef: usize,
    /// IVF probes at search time.
    pub nprobe: usize,
    /// RoarGraph: per-training-query KNN list length.
    pub kb: usize,
    /// Graph max out-degree.
    pub m: usize,
    /// Per-layer budget policy (Appendix F).
    pub budget: BudgetPolicy,
    /// Online index maintenance for decoded tokens.
    pub maintenance: MaintenanceConfig,
    /// Indexed-tier eviction (window retirement over host memory).
    pub eviction: EvictionConfig,
    /// Quantized scan tier + exact re-rank pool.
    pub quant: QuantConfig,
}

impl Default for RetrievalConfig {
    fn default() -> Self {
        RetrievalConfig {
            top_k: 100,
            ef: 128,
            nprobe: 8,
            kb: 32,
            m: 32,
            budget: BudgetPolicy::Uniform { k: 100 },
            maintenance: MaintenanceConfig::default(),
            eviction: EvictionConfig::default(),
            quant: QuantConfig::default(),
        }
    }
}

/// Multi-turn session cache knobs (`serving.session_cache`): how many
/// finished sessions a replica keeps decode-ready, and where the rest go.
///
/// A request carrying a `session_id` skips prefill on every turn after
/// the first: the replica retains the finished session up to
/// `max_resident_bytes` of RAM, LRU-parks colder sessions to `spill_dir`
/// through the versioned snapshot format (no re-prefill and no index
/// rebuild on resume — see [`crate::store`]), and rejects with
/// backpressure once parked bytes would exceed `max_disk_bytes`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionCacheConfig {
    /// RAM budget for resident (decode-ready) finished sessions. `0`
    /// forces every finished session straight to disk — the configuration
    /// the persistence e2e tests pin down.
    pub max_resident_bytes: usize,
    /// Directory for parked-session snapshots. Empty ⇒ a per-process
    /// directory under the system temp dir.
    pub spill_dir: String,
    /// Disk budget for parked snapshots; exhaustion rejects the insert
    /// with backpressure instead of silently dropping session state.
    pub max_disk_bytes: usize,
    /// Treat the spill tier as per-process scratch: a dropped cache
    /// deletes its parked snapshots and directory. `false` (the default
    /// for a configured `spill_dir`) makes the tier durable — parked
    /// sessions survive a crash or deploy and are re-registered by the
    /// boot scan. Forced `true` when `spill_dir` is empty: the
    /// per-process temp directory can never be rediscovered, so durable
    /// files there would only be litter.
    pub ephemeral_spill: bool,
    /// Extra attempts for transient spill IO (park writes, restore
    /// opens) before the error surfaces. `0` fails on first error.
    pub spill_retries: usize,
    /// Base backoff between spill retries, doubling per attempt.
    pub spill_retry_backoff_ms: u64,
}

impl Default for SessionCacheConfig {
    fn default() -> Self {
        SessionCacheConfig {
            max_resident_bytes: 512 << 20,
            spill_dir: String::new(),
            max_disk_bytes: 8 << 30,
            ephemeral_spill: false,
            spill_retries: 2,
            spill_retry_backoff_ms: 10,
        }
    }
}

/// Observability knobs (`serving.telemetry`) — see docs/observability.md
/// for the metric-name registry and the span taxonomy these feed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Collect structured spans on the decode path (per-request span
    /// trees in the done event). Off by default: the disabled path is a
    /// single atomic load with zero allocations, and enabling it never
    /// changes decoded tokens (locked by the scheduler equivalence
    /// suite's telemetry-on leg).
    pub spans: bool,
    /// Opt-in chrome://tracing output: when non-empty, every span is
    /// additionally streamed to this file as a trace event (JSON array
    /// format — loadable even mid-run). Empty ⇒ no trace file.
    pub trace_path: String,
    /// Flight-recorder ring capacity (recent structured events kept in
    /// memory for the supervisor's crash dump). `0` disables recording.
    pub flightrec_capacity: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            spans: false,
            trace_path: String::new(),
            flightrec_capacity: 256,
        }
    }
}

/// Serving-layer (coordinator/replica) knobs beyond raw scheduling.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServingConfig {
    /// The multi-turn session registry's storage budget.
    pub session_cache: SessionCacheConfig,
    /// Per-request deadline for the event stream, in milliseconds: a
    /// request whose replica stops producing events for this long fails
    /// with a timeout instead of blocking `collect` forever (a
    /// dead-but-connected worker). `0` disables the deadline.
    pub request_deadline_ms: u64,
    /// Times the router's supervisor will respawn a crashed replica
    /// worker before giving up and failing its requests outright.
    pub max_respawns: u32,
    /// Observability knobs (spans, trace file, flight recorder).
    pub telemetry: TelemetryConfig,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            session_cache: SessionCacheConfig::default(),
            // 0 = no deadline: existing single-process deployments block
            // indefinitely, exactly as before this knob existed.
            request_deadline_ms: 0,
            max_respawns: 3,
            telemetry: TelemetryConfig::default(),
        }
    }
}

/// Scheduler/batcher limits.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max concurrent sessions admitted.
    pub max_sessions: usize,
    /// Max decode requests batched per engine step.
    pub max_batch: usize,
    /// Queue depth before admission rejects new requests (backpressure).
    pub max_queue: usize,
    /// Resident sessions scheduled per decode wave. `0` (the default) is
    /// unthrottled: every resident session decodes one token per wave.
    /// A positive value bounds the fused kernel dispatch width; skipped
    /// sessions accumulate wait and win future picks (longest-wait
    /// first, admission-order tiebreak).
    pub wave_size: usize,
    /// Fairness bound for a throttled wave (`wave_size > 0`): a session
    /// about to sit out this many consecutive waves is force-included
    /// regardless of the throttle, so no session's inter-token gap ever
    /// exceeds `fairness_waves` waves. `0` disables the floor (pure
    /// longest-wait-first, starvation possible only if waits tie
    /// forever, which the monotone wait counter prevents anyway).
    pub fairness_waves: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_sessions: 64,
            max_batch: 8,
            max_queue: 256,
            wave_size: 0,
            fairness_waves: 4,
        }
    }
}

/// Top-level engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Model preset name (see `model::presets`).
    pub model: String,
    pub method: Method,
    pub pattern: StaticPattern,
    pub retrieval: RetrievalConfig,
    /// Per-head retrieval-vs-streaming policy (DuoAttention). A separate
    /// top-level block (not inside `retrieval`) because it carries
    /// override lists — `retrieval` stays `Copy`.
    pub policy: HeadPolicyConfig,
    pub scheduler: SchedulerConfig,
    pub serving: ServingConfig,
    /// Hardware profile name for modeled numbers ("localhost" = raw).
    pub hw: String,
    /// Directory holding AOT artifacts.
    pub artifacts_dir: String,
    /// Deterministic seed for synthetic weights/workloads.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "induction-mini".into(),
            method: Method::RetrievalAttention,
            pattern: StaticPattern::PAPER,
            retrieval: RetrievalConfig::default(),
            policy: HeadPolicyConfig::default(),
            scheduler: SchedulerConfig::default(),
            serving: ServingConfig::default(),
            hw: "localhost".into(),
            artifacts_dir: "artifacts".into(),
            seed: 0,
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("model", self.model.as_str());
        o.set("method", self.method.label());
        let mut p = Value::obj();
        p.set("sink", self.pattern.sink).set("window", self.pattern.window);
        o.set("pattern", p);
        let mut r = Value::obj();
        r.set("top_k", self.retrieval.top_k)
            .set("ef", self.retrieval.ef)
            .set("nprobe", self.retrieval.nprobe)
            .set("kb", self.retrieval.kb)
            .set("m", self.retrieval.m);
        let mut mnt = Value::obj();
        mnt.set("drain_watermark", self.retrieval.maintenance.drain_watermark)
            .set("recent_queries", self.retrieval.maintenance.recent_queries)
            .set("rebuild_threshold", self.retrieval.maintenance.rebuild_threshold)
            .set("async_worker", self.retrieval.maintenance.async_worker);
        r.set("maintenance", mnt);
        let mut ev = Value::obj();
        ev.set("max_indexed", self.retrieval.eviction.max_indexed)
            .set("reclaim_ratio", self.retrieval.eviction.reclaim_ratio as f64);
        r.set("eviction", ev);
        let mut qz = Value::obj();
        qz.set("mode", self.retrieval.quant.mode.label())
            .set("rerank", self.retrieval.quant.rerank);
        r.set("quant", qz);
        match self.retrieval.budget {
            BudgetPolicy::Uniform { k } => {
                let mut b = Value::obj();
                b.set("policy", "uniform").set("k", k);
                r.set("budget", b);
            }
            BudgetPolicy::Pyramid { k, beta } => {
                let mut b = Value::obj();
                b.set("policy", "pyramid").set("k", k).set("beta", beta as f64);
                r.set("budget", b);
            }
        }
        o.set("retrieval", r);
        o.set("policy", self.policy.to_json());
        let mut s = Value::obj();
        s.set("max_sessions", self.scheduler.max_sessions)
            .set("max_batch", self.scheduler.max_batch)
            .set("max_queue", self.scheduler.max_queue)
            .set("wave_size", self.scheduler.wave_size)
            .set("fairness_waves", self.scheduler.fairness_waves);
        o.set("scheduler", s);
        let mut sc = Value::obj();
        sc.set("max_resident_bytes", self.serving.session_cache.max_resident_bytes)
            .set("spill_dir", self.serving.session_cache.spill_dir.as_str())
            .set("max_disk_bytes", self.serving.session_cache.max_disk_bytes)
            .set("ephemeral_spill", self.serving.session_cache.ephemeral_spill)
            .set("spill_retries", self.serving.session_cache.spill_retries)
            .set("spill_retry_backoff_ms", self.serving.session_cache.spill_retry_backoff_ms);
        let mut tl = Value::obj();
        tl.set("spans", self.serving.telemetry.spans)
            .set("trace_path", self.serving.telemetry.trace_path.as_str())
            .set("flightrec_capacity", self.serving.telemetry.flightrec_capacity);
        let mut sv = Value::obj();
        sv.set("session_cache", sc);
        sv.set("telemetry", tl);
        sv.set("request_deadline_ms", self.serving.request_deadline_ms)
            .set("max_respawns", self.serving.max_respawns as u64);
        o.set("serving", sv);
        o.set("hw", self.hw.as_str());
        o.set("artifacts_dir", self.artifacts_dir.as_str());
        o.set("seed", self.seed);
        o
    }

    /// Parse from a JSON value; absent fields fall back to defaults.
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut c = ServeConfig::default();
        if let Some(m) = v.get("model").and_then(Value::as_str) {
            c.model = m.to_string();
        }
        if let Some(m) = v.get("method").and_then(Value::as_str) {
            c.method =
                Method::parse(m).ok_or_else(|| anyhow::anyhow!("unknown method `{m}`"))?;
        }
        if let Some(p) = v.get("pattern") {
            c.pattern = StaticPattern {
                sink: p.req_usize("sink")?,
                window: p.req_usize("window")?,
            };
        }
        if let Some(r) = v.get("retrieval") {
            if let Some(x) = r.get("top_k").and_then(Value::as_usize) {
                c.retrieval.top_k = x;
            }
            if let Some(x) = r.get("ef").and_then(Value::as_usize) {
                c.retrieval.ef = x;
            }
            if let Some(x) = r.get("nprobe").and_then(Value::as_usize) {
                c.retrieval.nprobe = x;
            }
            if let Some(x) = r.get("kb").and_then(Value::as_usize) {
                c.retrieval.kb = x;
            }
            if let Some(x) = r.get("m").and_then(Value::as_usize) {
                c.retrieval.m = x;
            }
            if let Some(mnt) = r.get("maintenance") {
                if let Some(x) = mnt.get("drain_watermark").and_then(Value::as_usize) {
                    c.retrieval.maintenance.drain_watermark = x;
                }
                if let Some(x) = mnt.get("recent_queries").and_then(Value::as_usize) {
                    c.retrieval.maintenance.recent_queries = x;
                }
                if let Some(x) = mnt.get("rebuild_threshold").and_then(Value::as_usize) {
                    c.retrieval.maintenance.rebuild_threshold = x;
                }
                if let Some(x) = mnt.get("async_worker").and_then(Value::as_bool) {
                    c.retrieval.maintenance.async_worker = x;
                }
            }
            if let Some(ev) = r.get("eviction") {
                if let Some(x) = ev.get("max_indexed").and_then(Value::as_usize) {
                    c.retrieval.eviction.max_indexed = x;
                }
                if let Some(x) = ev.get("reclaim_ratio").and_then(Value::as_f64) {
                    c.retrieval.eviction.reclaim_ratio = x as f32;
                }
            }
            if let Some(qz) = r.get("quant") {
                if let Some(m) = qz.get("mode").and_then(Value::as_str) {
                    c.retrieval.quant.mode = QuantMode::parse(m)
                        .ok_or_else(|| anyhow::anyhow!("unknown quant mode `{m}`"))?;
                }
                if let Some(x) = qz.get("rerank").and_then(Value::as_usize) {
                    c.retrieval.quant.rerank = x;
                }
            }
            if let Some(b) = r.get("budget") {
                let k = b.req_usize("k")?;
                c.retrieval.budget = match b.req_str("policy")? {
                    "uniform" => BudgetPolicy::Uniform { k },
                    "pyramid" => BudgetPolicy::Pyramid {
                        k,
                        beta: b.get("beta").and_then(Value::as_f64).unwrap_or(3.0) as f32,
                    },
                    other => anyhow::bail!("unknown budget policy `{other}`"),
                };
            }
        }
        if let Some(p) = v.get("policy") {
            c.policy.apply_json(p)?;
        }
        if let Some(s) = v.get("scheduler") {
            if let Some(x) = s.get("max_sessions").and_then(Value::as_usize) {
                c.scheduler.max_sessions = x;
            }
            if let Some(x) = s.get("max_batch").and_then(Value::as_usize) {
                c.scheduler.max_batch = x;
            }
            if let Some(x) = s.get("max_queue").and_then(Value::as_usize) {
                c.scheduler.max_queue = x;
            }
            if let Some(x) = s.get("wave_size").and_then(Value::as_usize) {
                c.scheduler.wave_size = x;
            }
            if let Some(x) = s.get("fairness_waves").and_then(Value::as_usize) {
                c.scheduler.fairness_waves = x;
            }
        }
        if let Some(sv) = v.get("serving") {
            if let Some(sc) = sv.get("session_cache") {
                if let Some(x) = sc.get("max_resident_bytes").and_then(Value::as_usize) {
                    c.serving.session_cache.max_resident_bytes = x;
                }
                if let Some(x) = sc.get("spill_dir").and_then(Value::as_str) {
                    c.serving.session_cache.spill_dir = x.to_string();
                }
                if let Some(x) = sc.get("max_disk_bytes").and_then(Value::as_usize) {
                    c.serving.session_cache.max_disk_bytes = x;
                }
                if let Some(x) = sc.get("ephemeral_spill").and_then(Value::as_bool) {
                    c.serving.session_cache.ephemeral_spill = x;
                }
                if let Some(x) = sc.get("spill_retries").and_then(Value::as_usize) {
                    c.serving.session_cache.spill_retries = x;
                }
                if let Some(x) = sc.get("spill_retry_backoff_ms").and_then(Value::as_u64) {
                    c.serving.session_cache.spill_retry_backoff_ms = x;
                }
            }
            if let Some(tl) = sv.get("telemetry") {
                if let Some(x) = tl.get("spans").and_then(Value::as_bool) {
                    c.serving.telemetry.spans = x;
                }
                if let Some(x) = tl.get("trace_path").and_then(Value::as_str) {
                    c.serving.telemetry.trace_path = x.to_string();
                }
                if let Some(x) = tl.get("flightrec_capacity").and_then(Value::as_usize) {
                    c.serving.telemetry.flightrec_capacity = x;
                }
            }
            if let Some(x) = sv.get("request_deadline_ms").and_then(Value::as_u64) {
                c.serving.request_deadline_ms = x;
            }
            if let Some(x) = sv.get("max_respawns").and_then(Value::as_u64) {
                c.serving.max_respawns = x as u32;
            }
        }
        if let Some(h) = v.get("hw").and_then(Value::as_str) {
            c.hw = h.to_string();
        }
        if let Some(a) = v.get("artifacts_dir").and_then(Value::as_str) {
            c.artifacts_dir = a.to_string();
        }
        if let Some(s) = v.get("seed").and_then(Value::as_u64) {
            c.seed = s;
        }
        Ok(c)
    }

    pub fn from_file(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(&path)?;
        Self::from_json(&json::parse(&text)?)
    }

    pub fn to_file(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_json() {
        let c = ServeConfig::default();
        let v = c.to_json();
        let back = ServeConfig::from_json(&v).unwrap();
        assert_eq!(back.method, Method::RetrievalAttention);
        assert_eq!(back.pattern, StaticPattern::PAPER);
        assert_eq!(back.retrieval.top_k, c.retrieval.top_k);
        assert_eq!(back.scheduler.max_batch, c.scheduler.max_batch);
        assert_eq!(back.retrieval.maintenance, c.retrieval.maintenance);
    }

    #[test]
    fn scheduler_wave_knobs_roundtrip_and_default() {
        let mut c = ServeConfig::default();
        assert_eq!(c.scheduler.wave_size, 0, "unthrottled by default");
        assert_eq!(c.scheduler.fairness_waves, 4);
        c.scheduler.wave_size = 3;
        c.scheduler.fairness_waves = 9;
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.scheduler.wave_size, 3);
        assert_eq!(back.scheduler.fairness_waves, 9);
        // Absent knobs fall back to defaults.
        let v = json::parse(r#"{"scheduler":{"max_batch":2}}"#).unwrap();
        let parsed = ServeConfig::from_json(&v).unwrap();
        assert_eq!(parsed.scheduler.max_batch, 2);
        assert_eq!(parsed.scheduler.wave_size, 0);
        assert_eq!(parsed.scheduler.fairness_waves, 4);
    }

    #[test]
    fn maintenance_roundtrips_and_defaults() {
        let mut c = ServeConfig::default();
        c.retrieval.maintenance = MaintenanceConfig {
            drain_watermark: 7,
            recent_queries: 3,
            rebuild_threshold: 99,
            async_worker: false,
        };
        c.retrieval.eviction = EvictionConfig { max_indexed: 4096, reclaim_ratio: 0.25 };
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.retrieval.maintenance.drain_watermark, 7);
        assert_eq!(back.retrieval.maintenance.recent_queries, 3);
        assert_eq!(back.retrieval.maintenance.rebuild_threshold, 99);
        assert!(!back.retrieval.maintenance.async_worker);
        assert_eq!(
            back.retrieval.eviction,
            EvictionConfig { max_indexed: 4096, reclaim_ratio: 0.25 }
        );
        assert!(back.retrieval.eviction.enabled());
        assert!(back.retrieval.maintenance.enabled());
        // Absent block falls back to defaults; watermark 0 disables.
        let v = json::parse(r#"{"retrieval":{"top_k":5}}"#).unwrap();
        let parsed = ServeConfig::from_json(&v).unwrap();
        assert_eq!(parsed.retrieval.maintenance, MaintenanceConfig::default());
        assert!(parsed.retrieval.maintenance.async_worker, "worker defaults on");
        assert!(!parsed.retrieval.eviction.enabled(), "eviction defaults off");
        assert!(parsed.retrieval.eviction.reclaim_enabled(), "reclaim defaults on");
        assert!((parsed.retrieval.eviction.reclaim_ratio - 0.5).abs() < 1e-6);
        let no_reclaim = EvictionConfig { reclaim_ratio: 0.0, ..Default::default() };
        assert!(!no_reclaim.reclaim_enabled());
        let off = MaintenanceConfig { drain_watermark: 0, ..Default::default() };
        assert!(!off.enabled());
    }

    #[test]
    fn quant_roundtrips_and_defaults_off() {
        let mut c = ServeConfig::default();
        assert_eq!(c.retrieval.quant, QuantConfig::default());
        assert_eq!(c.retrieval.quant.mode, QuantMode::Off);
        c.retrieval.quant = QuantConfig { mode: QuantMode::Int8, rerank: 4 };
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.retrieval.quant, QuantConfig { mode: QuantMode::Int8, rerank: 4 });
        // Absent block falls back to defaults (off, rerank 2).
        let v = json::parse(r#"{"retrieval":{"top_k":5}}"#).unwrap();
        let parsed = ServeConfig::from_json(&v).unwrap();
        assert_eq!(parsed.retrieval.quant, QuantConfig::default());
        // fp16 parses; unknown modes are rejected loudly.
        let v = json::parse(r#"{"retrieval":{"quant":{"mode":"fp16"}}}"#).unwrap();
        let parsed = ServeConfig::from_json(&v).unwrap();
        assert_eq!(parsed.retrieval.quant.mode, QuantMode::Fp16);
        assert_eq!(parsed.retrieval.quant.rerank, 2, "rerank keeps its default");
        let v = json::parse(r#"{"retrieval":{"quant":{"mode":"int4"}}}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err());
    }

    #[test]
    fn session_cache_roundtrips_and_defaults() {
        let mut c = ServeConfig::default();
        assert_eq!(c.serving, ServingConfig::default());
        c.serving.session_cache = SessionCacheConfig {
            max_resident_bytes: 0,
            spill_dir: "/tmp/ra-spill".into(),
            max_disk_bytes: 1 << 20,
            ephemeral_spill: true,
            spill_retries: 5,
            spill_retry_backoff_ms: 25,
        };
        c.serving.request_deadline_ms = 1500;
        c.serving.max_respawns = 7;
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.serving.session_cache.max_resident_bytes, 0);
        assert_eq!(back.serving.session_cache.spill_dir, "/tmp/ra-spill");
        assert_eq!(back.serving.session_cache.max_disk_bytes, 1 << 20);
        assert!(back.serving.session_cache.ephemeral_spill);
        assert_eq!(back.serving.session_cache.spill_retries, 5);
        assert_eq!(back.serving.session_cache.spill_retry_backoff_ms, 25);
        assert_eq!(back.serving.request_deadline_ms, 1500);
        assert_eq!(back.serving.max_respawns, 7);
        // Absent block falls back to defaults.
        let v = json::parse(r#"{"retrieval":{"top_k":5}}"#).unwrap();
        let parsed = ServeConfig::from_json(&v).unwrap();
        assert_eq!(parsed.serving.session_cache, SessionCacheConfig::default());
        assert!(parsed.serving.session_cache.max_resident_bytes > 0);
        assert!(parsed.serving.session_cache.spill_dir.is_empty());
        assert!(!parsed.serving.session_cache.ephemeral_spill, "durable by default");
        assert_eq!(parsed.serving.request_deadline_ms, 0, "no deadline by default");
        assert_eq!(parsed.serving.max_respawns, 3);
    }

    #[test]
    fn telemetry_roundtrips_and_defaults() {
        let mut c = ServeConfig::default();
        assert_eq!(c.serving.telemetry, TelemetryConfig::default());
        assert!(!c.serving.telemetry.spans, "spans off by default");
        assert!(c.serving.telemetry.trace_path.is_empty(), "no trace file by default");
        assert_eq!(c.serving.telemetry.flightrec_capacity, 256);
        c.serving.telemetry = TelemetryConfig {
            spans: true,
            trace_path: "/tmp/ra-trace.jsonl".into(),
            flightrec_capacity: 64,
        };
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.serving.telemetry, c.serving.telemetry);
        // Absent block falls back to defaults.
        let v = json::parse(r#"{"serving":{"max_respawns":5}}"#).unwrap();
        let parsed = ServeConfig::from_json(&v).unwrap();
        assert_eq!(parsed.serving.telemetry, TelemetryConfig::default());
        assert_eq!(parsed.serving.max_respawns, 5);
    }

    #[test]
    fn head_policy_roundtrips_and_defaults_off() {
        use crate::policy::PolicyMode;
        let mut c = ServeConfig::default();
        assert_eq!(c.policy, HeadPolicyConfig::default());
        assert_eq!(c.policy.mode, PolicyMode::Off, "policy layer defaults off");
        c.policy = HeadPolicyConfig {
            mode: PolicyMode::Calibrated,
            calibration_steps: 3,
            mass_threshold: 0.75,
            sinks: 16,
            window: 256,
            force_streaming: vec![(0, 1), (2, 0)],
            force_retrieval: vec![(1, 1)],
        };
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.policy, c.policy);
        // Absent block falls back to defaults.
        let v = json::parse(r#"{"retrieval":{"top_k":5}}"#).unwrap();
        let parsed = ServeConfig::from_json(&v).unwrap();
        assert_eq!(parsed.policy, HeadPolicyConfig::default());
        // Partial block keeps the other defaults; bad modes are loud.
        let v = json::parse(r#"{"policy":{"mode":"static","sinks":9}}"#).unwrap();
        let parsed = ServeConfig::from_json(&v).unwrap();
        assert_eq!(parsed.policy.mode, PolicyMode::Static);
        assert_eq!(parsed.policy.sinks, 9);
        assert_eq!(parsed.policy.window, HeadPolicyConfig::default().window);
        let v = json::parse(r#"{"policy":{"mode":"bogus"}}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"policy":{"force_streaming":[[0]]}}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err(), "malformed pair rejected");
    }

    #[test]
    fn method_labels_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.label()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = json::parse(r#"{"model":"x","method":"Flat"}"#).unwrap();
        let c = ServeConfig::from_json(&v).unwrap();
        assert_eq!(c.method, Method::Flat);
        assert_eq!(c.pattern, StaticPattern::PAPER);
        assert_eq!(c.retrieval.top_k, 100);
        assert_eq!(c.hw, "localhost");
    }

    #[test]
    fn pyramid_budget_roundtrips() {
        let mut c = ServeConfig::default();
        c.retrieval.budget = BudgetPolicy::Pyramid { k: 64, beta: 2.0 };
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.retrieval.budget, BudgetPolicy::Pyramid { k: 64, beta: 2.0 });
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ra-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let c = ServeConfig::default();
        c.to_file(&path).unwrap();
        let back = ServeConfig::from_file(&path).unwrap();
        assert_eq!(back.model, c.model);
        std::fs::remove_dir_all(&dir).ok();
    }
}
