//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Shapes/dtypes per artifact plus the model geometry the
//! weights must match.

use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One tensor's shape + dtype.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(Value::as_arr)
            .context("tensor spec missing shape")?
            .iter()
            .map(|d| d.as_usize().context("non-numeric dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { shape, dtype: v.req_str("dtype")?.to_string() })
    }
}

/// One AOT-lowered entry point.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub outs: Vec<TensorSpec>,
}

/// Model geometry as recorded by aot.py (mirrors python ModelSpec).
#[derive(Clone, Debug)]
pub struct SpecMeta {
    pub layers: usize,
    pub d_model: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub norm: bool,
    pub ffn_dim: usize,
    pub static_len: usize,
}

impl SpecMeta {
    pub fn group_size(&self) -> usize {
        self.q_heads / self.kv_heads
    }

    /// Built-in geometry for a preset name, mirroring
    /// `python/compile/model.py::PRESETS` exactly. This is what lets the
    /// native runtime backend serve a preset without `make artifacts`.
    pub fn builtin(name: &str) -> Option<SpecMeta> {
        match name {
            "induction-mini" => Some(SpecMeta {
                layers: 2,
                d_model: 192,
                q_heads: 1,
                kv_heads: 1,
                head_dim: 192,
                vocab: 4096,
                norm: false,
                ffn_dim: 8,
                static_len: 640,
            }),
            "llama3-mini" => Some(SpecMeta {
                layers: 4,
                d_model: 512,
                q_heads: 8,
                kv_heads: 2,
                head_dim: 64,
                vocab: 8192,
                norm: true,
                ffn_dim: 1024,
                static_len: 640,
            }),
            "yi6-mini" => Some(SpecMeta {
                layers: 4,
                d_model: 512,
                q_heads: 8,
                kv_heads: 1,
                head_dim: 64,
                vocab: 8192,
                norm: true,
                ffn_dim: 1024,
                static_len: 640,
            }),
            "yi9-mini" => Some(SpecMeta {
                layers: 6,
                d_model: 512,
                q_heads: 8,
                kv_heads: 1,
                head_dim: 64,
                vocab: 8192,
                norm: true,
                ffn_dim: 1024,
                static_len: 640,
            }),
            _ => None,
        }
    }

    /// Names accepted by [`SpecMeta::builtin`].
    pub fn builtin_names() -> &'static [&'static str] {
        &["induction-mini", "llama3-mini", "yi6-mini", "yi9-mini"]
    }
}

/// One preset: geometry + its artifacts.
#[derive(Clone, Debug)]
pub struct PresetMeta {
    pub spec: SpecMeta,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl PresetMeta {
    /// Synthesize the preset metadata the native runtime backend serves
    /// when no AOT artifacts exist: the built-in geometry plus one
    /// [`ArtifactMeta`] per entry point, with shapes mirroring
    /// `python/compile/model.py::entry_points`.
    pub fn builtin(name: &str) -> Option<PresetMeta> {
        let spec = SpecMeta::builtin(name)?;
        let f32s = |shape: &[usize]| TensorSpec { shape: shape.to_vec(), dtype: "float32".into() };
        let i32s = |shape: &[usize]| TensorSpec { shape: shape.to_vec(), dtype: "int32".into() };
        let (d, dh, h, kv, f, s, v) = (
            spec.d_model,
            spec.head_dim,
            spec.q_heads,
            spec.kv_heads,
            spec.ffn_dim,
            spec.static_len,
            spec.vocab,
        );
        let mut artifacts = BTreeMap::new();
        let mut add = |aname: String, args: Vec<TensorSpec>, outs: Vec<TensorSpec>| {
            artifacts.insert(aname, ArtifactMeta { file: "<native>".into(), args, outs });
        };
        for b in [1usize, 256] {
            add(
                format!("embed_b{b}"),
                vec![f32s(&[v, d]), i32s(&[b]), f32s(&[b, d])],
                vec![f32s(&[b, d])],
            );
            add(
                format!("qkv_b{b}"),
                vec![
                    f32s(&[b, d]),
                    f32s(&[d]),
                    f32s(&[d, h * dh]),
                    f32s(&[d, kv * dh]),
                    f32s(&[d, kv * dh]),
                ],
                vec![f32s(&[b, h, dh]), f32s(&[b, kv, dh]), f32s(&[b, kv, dh])],
            );
            add(
                format!("post_b{b}"),
                vec![
                    f32s(&[b, d]),
                    f32s(&[b, h * dh]),
                    f32s(&[h * dh, d]),
                    f32s(&[d]),
                    f32s(&[d, f]),
                    f32s(&[d, f]),
                    f32s(&[f, d]),
                ],
                vec![f32s(&[b, d])],
            );
            add(
                format!("lm_head_b{b}"),
                vec![f32s(&[b, d]), f32s(&[d]), f32s(&[d, v])],
                vec![f32s(&[b, v])],
            );
        }
        add(
            "static_attn".into(),
            vec![f32s(&[h, dh]), f32s(&[s, kv, dh]), f32s(&[s, kv, dh]), f32s(&[s])],
            vec![f32s(&[h, dh]), f32s(&[h])],
        );
        add(
            "combine".into(),
            vec![f32s(&[h, dh]), f32s(&[h]), f32s(&[h, dh]), f32s(&[h])],
            vec![f32s(&[h, dh]), f32s(&[h])],
        );
        Some(PresetMeta { spec, artifacts })
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub presets: BTreeMap<String, PresetMeta>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text)?;
        let mut presets = BTreeMap::new();
        let obj = v.get("presets").context("manifest missing presets")?;
        let Value::Obj(map) = obj else {
            anyhow::bail!("presets is not an object");
        };
        for (name, p) in map {
            let s = p.get("spec").context("preset missing spec")?;
            let spec = SpecMeta {
                layers: s.req_usize("layers")?,
                d_model: s.req_usize("d_model")?,
                q_heads: s.req_usize("q_heads")?,
                kv_heads: s.req_usize("kv_heads")?,
                head_dim: s.req_usize("head_dim")?,
                vocab: s.req_usize("vocab")?,
                norm: s.get("norm").and_then(Value::as_bool).unwrap_or(false),
                ffn_dim: s.req_usize("ffn_dim")?,
                static_len: s.req_usize("static_len")?,
            };
            let mut artifacts = BTreeMap::new();
            let arts = p.get("artifacts").context("preset missing artifacts")?;
            let Value::Obj(amap) = arts else {
                anyhow::bail!("artifacts is not an object");
            };
            for (aname, a) in amap {
                let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                    a.get(key)
                        .and_then(Value::as_arr)
                        .with_context(|| format!("artifact {aname} missing {key}"))?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect()
                };
                artifacts.insert(
                    aname.clone(),
                    ArtifactMeta {
                        file: a.req_str("file")?.to_string(),
                        args: parse_specs("args")?,
                        outs: parse_specs("outs")?,
                    },
                );
            }
            presets.insert(name.clone(), PresetMeta { spec, artifacts });
        }
        Ok(Manifest { presets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "presets": {
        "tiny": {
          "spec": {"layers": 2, "d_model": 8, "q_heads": 2, "kv_heads": 1,
                   "head_dim": 4, "vocab": 16, "norm": true, "ffn_dim": 8,
                   "static_len": 128},
          "artifacts": {
            "qkv_b1": {
              "file": "tiny/qkv_b1.hlo.txt",
              "args": [{"shape": [1, 8], "dtype": "float32"}],
              "outs": [{"shape": [1, 2, 4], "dtype": "float32"}],
              "sha256": "x"
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let p = &m.presets["tiny"];
        assert_eq!(p.spec.layers, 2);
        assert_eq!(p.spec.group_size(), 2);
        assert!(p.spec.norm);
        let a = &p.artifacts["qkv_b1"];
        assert_eq!(a.args[0].shape, vec![1, 8]);
        assert_eq!(a.outs[0].numel(), 8);
    }

    #[test]
    fn builtin_presets_cover_python_geometry() {
        for name in SpecMeta::builtin_names() {
            let p = PresetMeta::builtin(name).unwrap();
            assert_eq!(p.spec.q_heads % p.spec.kv_heads, 0);
            // Every entry point the engine calls must exist with the right
            // arg counts (the runtime's debug_assert relies on this).
            for (aname, nargs) in [
                ("embed_b1", 3),
                ("embed_b256", 3),
                ("qkv_b1", 5),
                ("post_b256", 7),
                ("lm_head_b1", 3),
                ("static_attn", 4),
                ("combine", 4),
            ] {
                let a = p.artifacts.get(aname).unwrap_or_else(|| panic!("{name}/{aname}"));
                assert_eq!(a.args.len(), nargs, "{name}/{aname} arg count");
            }
        }
        assert!(PresetMeta::builtin("no-such-preset").is_none());
        let ind = SpecMeta::builtin("induction-mini").unwrap();
        assert_eq!(ind.head_dim, ind.d_model);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"presets": {"x": {}}}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Integration: if `make artifacts` has run, the real manifest parses.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if std::path::Path::new(path).exists() {
            let m = Manifest::load(path).unwrap();
            assert!(m.presets.contains_key("induction-mini"));
            let p = &m.presets["llama3-mini"];
            assert_eq!(p.spec.head_dim, 64);
            assert!(p.artifacts.contains_key("static_attn"));
        }
    }
}
