//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Shapes/dtypes per artifact plus the model geometry the
//! weights must match.

use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One tensor's shape + dtype.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(Value::as_arr)
            .context("tensor spec missing shape")?
            .iter()
            .map(|d| d.as_usize().context("non-numeric dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { shape, dtype: v.req_str("dtype")?.to_string() })
    }
}

/// One AOT-lowered entry point.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub outs: Vec<TensorSpec>,
}

/// Model geometry as recorded by aot.py (mirrors python ModelSpec).
#[derive(Clone, Debug)]
pub struct SpecMeta {
    pub layers: usize,
    pub d_model: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub norm: bool,
    pub ffn_dim: usize,
    pub static_len: usize,
}

impl SpecMeta {
    pub fn group_size(&self) -> usize {
        self.q_heads / self.kv_heads
    }
}

/// One preset: geometry + its artifacts.
#[derive(Clone, Debug)]
pub struct PresetMeta {
    pub spec: SpecMeta,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub presets: BTreeMap<String, PresetMeta>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text)?;
        let mut presets = BTreeMap::new();
        let obj = v.get("presets").context("manifest missing presets")?;
        let Value::Obj(map) = obj else {
            anyhow::bail!("presets is not an object");
        };
        for (name, p) in map {
            let s = p.get("spec").context("preset missing spec")?;
            let spec = SpecMeta {
                layers: s.req_usize("layers")?,
                d_model: s.req_usize("d_model")?,
                q_heads: s.req_usize("q_heads")?,
                kv_heads: s.req_usize("kv_heads")?,
                head_dim: s.req_usize("head_dim")?,
                vocab: s.req_usize("vocab")?,
                norm: s.get("norm").and_then(Value::as_bool).unwrap_or(false),
                ffn_dim: s.req_usize("ffn_dim")?,
                static_len: s.req_usize("static_len")?,
            };
            let mut artifacts = BTreeMap::new();
            let arts = p.get("artifacts").context("preset missing artifacts")?;
            let Value::Obj(amap) = arts else {
                anyhow::bail!("artifacts is not an object");
            };
            for (aname, a) in amap {
                let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                    a.get(key)
                        .and_then(Value::as_arr)
                        .with_context(|| format!("artifact {aname} missing {key}"))?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect()
                };
                artifacts.insert(
                    aname.clone(),
                    ArtifactMeta {
                        file: a.req_str("file")?.to_string(),
                        args: parse_specs("args")?,
                        outs: parse_specs("outs")?,
                    },
                );
            }
            presets.insert(name.clone(), PresetMeta { spec, artifacts });
        }
        Ok(Manifest { presets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "presets": {
        "tiny": {
          "spec": {"layers": 2, "d_model": 8, "q_heads": 2, "kv_heads": 1,
                   "head_dim": 4, "vocab": 16, "norm": true, "ffn_dim": 8,
                   "static_len": 128},
          "artifacts": {
            "qkv_b1": {
              "file": "tiny/qkv_b1.hlo.txt",
              "args": [{"shape": [1, 8], "dtype": "float32"}],
              "outs": [{"shape": [1, 2, 4], "dtype": "float32"}],
              "sha256": "x"
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let p = &m.presets["tiny"];
        assert_eq!(p.spec.layers, 2);
        assert_eq!(p.spec.group_size(), 2);
        assert!(p.spec.norm);
        let a = &p.artifacts["qkv_b1"];
        assert_eq!(a.args[0].shape, vec![1, 8]);
        assert_eq!(a.outs[0].numel(), 8);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"presets": {"x": {}}}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Integration: if `make artifacts` has run, the real manifest parses.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if std::path::Path::new(path).exists() {
            let m = Manifest::load(path).unwrap();
            assert!(m.presets.contains_key("induction-mini"));
            let p = &m.presets["llama3-mini"];
            assert_eq!(p.spec.head_dim, 64);
            assert!(p.artifacts.contains_key("static_attn"));
        }
    }
}
