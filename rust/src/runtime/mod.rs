//! The runtime: loads and executes the AOT artifacts.
//!
//! Two interchangeable backends sit behind one [`Runtime`] surface:
//!
//! * **PJRT** — `make artifacts` (the only place Python runs) leaves
//!   `artifacts/manifest.json` plus one HLO-text file per entry point;
//!   every artifact is compiled once at startup on the PJRT CPU client.
//!   HLO *text* is the interchange format (not serialized protos): jax
//!   ≥0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects,
//!   while the text parser reassigns ids cleanly (see aot.py / DESIGN.md).
//! * **Native** — a direct Rust implementation of the same entry points
//!   ([`native::NativeExecutor`]), selected automatically when artifacts
//!   are absent or PJRT cannot compile (e.g. the vendored `xla` stub).
//!   This keeps the engine, the serving stack, and the e2e tests fully
//!   executable in a bare checkout.

pub mod manifest;
pub mod native;

use crate::tensor::Matrix;
use crate::util::sync::{AtomicU64, Ordering};
use anyhow::{Context, Result};
use manifest::{ArtifactMeta, Manifest, PresetMeta};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

enum Backend {
    Pjrt { execs: HashMap<String, xla::PjRtLoadedExecutable> },
    Native(native::NativeExecutor),
}

/// A loaded model preset: compiled executables (or the native executor)
/// plus metadata.
pub struct Runtime {
    client: xla::PjRtClient,
    backend: Backend,
    preset: String,
    meta: PresetMeta,
    /// Cumulative device-execution count (perf diagnostics).
    /// Relaxed (allowlisted counter): a monotonically increasing
    /// diagnostic; nothing is published through it.
    pub exec_count: AtomicU64,
}

impl Runtime {
    /// Load one preset from the artifacts directory, compiling every
    /// artifact on the PJRT CPU client ("the device").
    pub fn load(artifacts_dir: impl AsRef<Path>, preset: &str) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let meta = manifest
            .presets
            .get(preset)
            .with_context(|| format!("preset `{preset}` not in manifest"))?
            .clone();

        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
        let mut execs = HashMap::new();
        for (name, art) in &meta.artifacts {
            let path: PathBuf = dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
            execs.insert(name.clone(), exe);
        }
        Ok(Runtime {
            client,
            backend: Backend::Pjrt { execs },
            preset: preset.to_string(),
            meta,
            exec_count: AtomicU64::new(0),
        })
    }

    /// Build a native-backend runtime for a built-in preset: no artifacts
    /// required, entry points execute as plain Rust.
    pub fn load_native(preset: &str) -> Result<Runtime> {
        let meta = PresetMeta::builtin(preset).with_context(|| {
            format!(
                "preset `{preset}` has no built-in geometry (known: {})",
                manifest::SpecMeta::builtin_names().join(", ")
            )
        })?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("creating client: {e}"))?;
        Ok(Runtime {
            client,
            backend: Backend::Native(native::NativeExecutor::new(meta.spec.clone())),
            preset: preset.to_string(),
            meta,
            exec_count: AtomicU64::new(0),
        })
    }

    /// Preferred entry point: PJRT when artifacts exist *and* compile,
    /// otherwise the native backend. The fallback is recorded in the
    /// telemetry layer (a `runtime.pjrt_fallbacks_total` counter plus a
    /// flight-recorder event), not printed: library code stays silent on
    /// stderr (xtask lint rule 6) and the stats verb / crash dump show
    /// which device actually ran.
    pub fn load_auto(artifacts_dir: impl AsRef<Path>, preset: &str) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref();
        if dir.join("manifest.json").exists() {
            match Runtime::load(dir, preset) {
                Ok(rt) => return Ok(rt),
                Err(e) => {
                    crate::telemetry::registry()
                        .counter("runtime.pjrt_fallbacks_total")
                        .inc();
                    crate::telemetry::flightrec(
                        "runtime.fallback",
                        format!(
                            "PJRT load of `{preset}` failed ({e}); \
                             falling back to the native backend"
                        ),
                    );
                }
            }
        }
        Runtime::load_native(preset)
    }

    pub fn preset(&self) -> &str {
        &self.preset
    }

    pub fn meta(&self) -> &PresetMeta {
        &self.meta
    }

    pub fn artifact_meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.meta.artifacts.get(name)
    }

    /// True when entry points run as native Rust rather than compiled HLO.
    pub fn is_native(&self) -> bool {
        matches!(self.backend, Backend::Native(_))
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Pjrt { .. } => self.client.platform_name(),
            Backend::Native(_) => "native-cpu".to_string(),
        }
    }

    /// Execute an artifact. Inputs must match the manifest arg shapes
    /// (count checked in debug builds); outputs are the flattened tuple.
    pub fn exec(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        debug_assert_eq!(
            inputs.len(),
            self.meta
                .artifacts
                .get(name)
                .map(|a| a.args.len())
                .unwrap_or(inputs.len()),
            "arg count mismatch for {name}"
        );
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        match &self.backend {
            Backend::Native(ex) => ex.execute(name, inputs),
            Backend::Pjrt { execs } => {
                let exe = execs
                    .get(name)
                    .with_context(|| format!("unknown artifact `{}/{name}`", self.preset))?;
                let result = exe
                    .execute::<&xla::Literal>(inputs)
                    .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
                let lit = result[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("fetching {name} result: {e}"))?;
                // aot.py lowers with return_tuple=True: always a tuple.
                lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {name} result: {e}"))
            }
        }
    }

    /// Execute an artifact with pre-uploaded device buffers. This is the
    /// hot-path variant: weights are uploaded once at engine construction
    /// (see EXPERIMENTS.md §Perf — the literal path re-transferred ~30MB
    /// of weights per decode step).
    pub fn exec_b(&self, name: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        match &self.backend {
            Backend::Native(ex) => {
                let lits: Vec<&xla::Literal> = inputs.iter().map(|b| b.literal()).collect();
                ex.execute(name, &lits)
            }
            Backend::Pjrt { execs } => {
                let exe = execs
                    .get(name)
                    .with_context(|| format!("unknown artifact `{}/{name}`", self.preset))?;
                let result = exe
                    .execute_b::<&xla::PjRtBuffer>(inputs)
                    .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
                let lit = result[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("fetching {name} result: {e}"))?;
                lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {name} result: {e}"))
            }
        }
    }

    /// Upload an f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {:?}: {e}", dims))
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {:?}: {e}", dims))
    }

    /// Upload a matrix to the device.
    pub fn upload_matrix(&self, m: &crate::tensor::Matrix) -> Result<xla::PjRtBuffer> {
        self.upload_f32(m.as_slice(), &[m.rows(), m.cols()])
    }
}

/// Build an f32 literal from a row-major matrix.
pub fn literal_from_matrix(m: &Matrix) -> Result<xla::Literal> {
    literal_f32(m.as_slice(), &[m.rows() as i64, m.cols() as i64])
}

/// Build an f32 literal of arbitrary shape from a flat buffer.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {:?} != len {}", dims, data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e}"))
}

/// Build an i32 literal (token ids).
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {:?} != len {}", dims, data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e}"))
}

/// Read an f32 literal back into a flat vec.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = literal_from_matrix(&m).unwrap();
        assert_eq!(literal_to_f32(&lit).unwrap(), m.as_slice());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3], &[2]).is_err());
    }

    #[test]
    fn native_runtime_loads_builtin_presets() {
        let rt = Runtime::load_native("induction-mini").unwrap();
        assert!(rt.is_native());
        assert_eq!(rt.platform(), "native-cpu");
        assert_eq!(rt.meta().spec.d_model, 192);
        assert!(Runtime::load_native("not-a-preset").is_err());
    }

    #[test]
    fn load_auto_falls_back_without_artifacts() {
        let dir = std::env::temp_dir().join(format!("ra-empty-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rt = Runtime::load_auto(&dir, "llama3-mini").unwrap();
        assert!(rt.is_native());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn native_exec_roundtrips_buffers() {
        let rt = Runtime::load_native("induction-mini").unwrap();
        let spec = rt.meta().spec.clone();
        let (h, dh, s, kv) = (spec.q_heads, spec.head_dim, spec.static_len, spec.kv_heads);
        let q = rt.upload_f32(&vec![0.1; h * dh], &[h, dh]).unwrap();
        let k = rt.upload_f32(&vec![0.2; s * kv * dh], &[s, kv, dh]).unwrap();
        let v = rt.upload_f32(&vec![0.3; s * kv * dh], &[s, kv, dh]).unwrap();
        let m = rt.upload_f32(&vec![0.0; s], &[s]).unwrap();
        let outs = rt.exec_b("static_attn", &[&q, &k, &v, &m]).unwrap();
        assert_eq!(outs.len(), 2);
        let o = literal_to_f32(&outs[0]).unwrap();
        // Uniform values => attention output equals the value vector.
        assert!(o.iter().all(|x| (x - 0.3).abs() < 1e-5));
        assert!(rt.exec_count.load(Ordering::Relaxed) >= 1);
    }
}
