//! The PJRT runtime: loads and executes the AOT artifacts.
//!
//! `make artifacts` (the only place Python runs) leaves
//! `artifacts/manifest.json` plus one HLO-text file per entry point. This
//! module is the bridge the Rust hot path calls into: it parses the
//! manifest, compiles every artifact once at startup on the PJRT CPU
//! client, and exposes typed execute helpers.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids cleanly (see aot.py / DESIGN.md).

pub mod manifest;

use crate::tensor::Matrix;
use anyhow::{Context, Result};
use manifest::{ArtifactMeta, Manifest, PresetMeta};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded model preset: compiled executables + metadata.
pub struct Runtime {
    client: xla::PjRtClient,
    preset: String,
    meta: PresetMeta,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative device-execution count (perf diagnostics).
    pub exec_count: std::sync::atomic::AtomicU64,
}

impl Runtime {
    /// Load one preset from the artifacts directory, compiling every
    /// artifact on the PJRT CPU client ("the device").
    pub fn load(artifacts_dir: impl AsRef<Path>, preset: &str) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let meta = manifest
            .presets
            .get(preset)
            .with_context(|| format!("preset `{preset}` not in manifest"))?
            .clone();

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut execs = HashMap::new();
        for (name, art) in &meta.artifacts {
            let path: PathBuf = dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
            execs.insert(name.clone(), exe);
        }
        Ok(Runtime {
            client,
            preset: preset.to_string(),
            meta,
            execs,
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn preset(&self) -> &str {
        &self.preset
    }

    pub fn meta(&self) -> &PresetMeta {
        &self.meta
    }

    pub fn artifact_meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.meta.artifacts.get(name)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute an artifact. Inputs must match the manifest arg shapes
    /// (count checked in debug builds); outputs are the flattened tuple.
    pub fn exec(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .execs
            .get(name)
            .with_context(|| format!("unknown artifact `{}/{name}`", self.preset))?;
        debug_assert_eq!(
            inputs.len(),
            self.meta.artifacts[name].args.len(),
            "arg count mismatch for {name}"
        );
        self.exec_count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {name} result: {e}"))
    }

    /// Execute an artifact with pre-uploaded device buffers. This is the
    /// hot-path variant: weights are uploaded once at engine construction
    /// (see EXPERIMENTS.md §Perf — the literal path re-transferred ~30MB
    /// of weights per decode step).
    pub fn exec_b(&self, name: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .execs
            .get(name)
            .with_context(|| format!("unknown artifact `{}/{name}`", self.preset))?;
        self.exec_count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {name} result: {e}"))
    }

    /// Upload an f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {:?}: {e}", dims))
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {:?}: {e}", dims))
    }

    /// Upload a matrix to the device.
    pub fn upload_matrix(&self, m: &crate::tensor::Matrix) -> Result<xla::PjRtBuffer> {
        self.upload_f32(m.as_slice(), &[m.rows(), m.cols()])
    }
}

/// Build an f32 literal from a row-major matrix.
pub fn literal_from_matrix(m: &Matrix) -> Result<xla::Literal> {
    literal_f32(m.as_slice(), &[m.rows() as i64, m.cols() as i64])
}

/// Build an f32 literal of arbitrary shape from a flat buffer.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {:?} != len {}", dims, data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e}"))
}

/// Build an i32 literal (token ids).
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {:?} != len {}", dims, data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e}"))
}

/// Read an f32 literal back into a flat vec.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = literal_from_matrix(&m).unwrap();
        assert_eq!(literal_to_f32(&lit).unwrap(), m.as_slice());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3], &[2]).is_err());
    }
}
