//! Native CPU executor for the AOT entry points.
//!
//! The vendored `xla` crate is a host-data stub — it cannot execute HLO.
//! This module is the fallback "device": a direct Rust implementation of
//! every artifact in `python/compile/model.py::entry_points`, keyed by
//! artifact name and reading argument tensors out of the stub literals.
//! Numerics mirror the JAX graph op-for-op (RMSNorm epsilon, SiLU, the
//! flash-decode online-softmax `(o, lse)` contract), which is exactly what
//! `tests/cross_layer.rs` asserts against the host attention code.
//!
//! With a real `xla` crate and `make artifacts` the PJRT backend is used
//! instead; the engine never knows which one is underneath.

use crate::attention::{combine, PartialAttention};
use crate::runtime::manifest::SpecMeta;
use anyhow::{Context, Result};
use xla::Literal;

/// Executes entry points for one model preset.
pub struct NativeExecutor {
    spec: SpecMeta,
}

impl NativeExecutor {
    pub fn new(spec: SpecMeta) -> NativeExecutor {
        NativeExecutor { spec }
    }

    /// Run one artifact. Inputs follow the manifest arg order; the result
    /// is the flattened output tuple, matching what the PJRT path returns
    /// after `to_tuple()`.
    pub fn execute(&self, name: &str, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        if let Some(b) = name.strip_prefix("embed_b") {
            return self.embed(b.parse()?, inputs);
        }
        if let Some(b) = name.strip_prefix("qkv_b") {
            return self.qkv(b.parse()?, inputs);
        }
        if let Some(b) = name.strip_prefix("post_b") {
            return self.post(b.parse()?, inputs);
        }
        if let Some(b) = name.strip_prefix("lm_head_b") {
            return self.lm_head(b.parse()?, inputs);
        }
        match name {
            "static_attn" => self.static_attn(inputs),
            "combine" => self.combine_op(inputs),
            other => anyhow::bail!("native backend: unknown artifact `{other}`"),
        }
    }

    /// `table[ids] + pos` — token embedding plus additive position code.
    fn embed(&self, b: usize, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let d = self.spec.d_model;
        let table = f32_arg(inputs, 0, "table")?;
        let ids = i32_arg(inputs, 1, "ids")?;
        let pos = f32_arg(inputs, 2, "pos")?;
        anyhow::ensure!(ids.len() == b && pos.len() == b * d, "embed_b{b}: bad arg shapes");
        anyhow::ensure!(table.len() == self.spec.vocab * d, "embed: bad table shape");
        let mut out = vec![0.0f32; b * d];
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            anyhow::ensure!(id < self.spec.vocab, "embed: token id {id} out of vocab");
            let row = &table[id * d..(id + 1) * d];
            let o = &mut out[i * d..(i + 1) * d];
            for j in 0..d {
                o[j] = row[j] + pos[i * d + j];
            }
        }
        Ok(vec![Literal::from_f32(out, &[b, d])])
    }

    /// Pre-norm QKV projection.
    fn qkv(&self, b: usize, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let (d, h, kv, dh) =
            (self.spec.d_model, self.spec.q_heads, self.spec.kv_heads, self.spec.head_dim);
        let x = f32_arg(inputs, 0, "x")?;
        let g = f32_arg(inputs, 1, "g")?;
        let wq = f32_arg(inputs, 2, "wq")?;
        let wk = f32_arg(inputs, 3, "wk")?;
        let wv = f32_arg(inputs, 4, "wv")?;
        anyhow::ensure!(x.len() == b * d && g.len() == d, "qkv_b{b}: bad arg shapes");
        let xn = rmsnorm(x, g, b, d, self.spec.norm);
        let q = matmul(&xn, b, d, wq, h * dh);
        let k = matmul(&xn, b, d, wk, kv * dh);
        let v = matmul(&xn, b, d, wv, kv * dh);
        Ok(vec![
            Literal::from_f32(q, &[b, h, dh]),
            Literal::from_f32(k, &[b, kv, dh]),
            Literal::from_f32(v, &[b, kv, dh]),
        ])
    }

    /// Output projection + residual + SwiGLU FFN.
    fn post(&self, b: usize, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let (d, h, dh, f) =
            (self.spec.d_model, self.spec.q_heads, self.spec.head_dim, self.spec.ffn_dim);
        let x = f32_arg(inputs, 0, "x")?;
        let attn = f32_arg(inputs, 1, "attn")?;
        let wo = f32_arg(inputs, 2, "wo")?;
        let g2 = f32_arg(inputs, 3, "g2")?;
        let w1 = f32_arg(inputs, 4, "w1")?;
        let w3 = f32_arg(inputs, 5, "w3")?;
        let w2 = f32_arg(inputs, 6, "w2")?;
        anyhow::ensure!(
            x.len() == b * d && attn.len() == b * h * dh,
            "post_b{b}: bad arg shapes"
        );
        let mut hres = matmul(attn, b, h * dh, wo, d);
        for (o, &xi) in hres.iter_mut().zip(x.iter()) {
            *o += xi;
        }
        let hn = rmsnorm(&hres, g2, b, d, self.spec.norm);
        let mut a1 = matmul(&hn, b, d, w1, f);
        let a3 = matmul(&hn, b, d, w3, f);
        for (u, &w) in a1.iter_mut().zip(a3.iter()) {
            // SiLU(u) * w
            *u = *u / (1.0 + (-*u).exp()) * w;
        }
        let ffn = matmul(&a1, b, f, w2, d);
        for (o, &e) in hres.iter_mut().zip(ffn.iter()) {
            *o += e;
        }
        Ok(vec![Literal::from_f32(hres, &[b, d])])
    }

    /// Final norm + unembedding.
    fn lm_head(&self, b: usize, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let (d, v) = (self.spec.d_model, self.spec.vocab);
        let x = f32_arg(inputs, 0, "x")?;
        let gf = f32_arg(inputs, 1, "gf")?;
        let wu = f32_arg(inputs, 2, "wu")?;
        anyhow::ensure!(x.len() == b * d, "lm_head_b{b}: bad arg shapes");
        let xn = rmsnorm(x, gf, b, d, self.spec.norm);
        let logits = matmul(&xn, b, d, wu, v);
        Ok(vec![Literal::from_f32(logits, &[b, v])])
    }

    /// Device-side partial attention over the static set `W`
    /// (flash-decode contract: per query head, `(o, lse)` of the scaled
    /// masked logits; GQA expands KV groups to query heads).
    fn static_attn(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let (h, kv, dh, s) =
            (self.spec.q_heads, self.spec.kv_heads, self.spec.head_dim, self.spec.static_len);
        let group = self.spec.group_size();
        let q = f32_arg(inputs, 0, "q")?;
        let keys = f32_arg(inputs, 1, "keys")?;
        let values = f32_arg(inputs, 2, "values")?;
        let mask = f32_arg(inputs, 3, "mask")?;
        anyhow::ensure!(
            q.len() == h * dh && keys.len() == s * kv * dh && values.len() == keys.len(),
            "static_attn: bad arg shapes"
        );
        anyhow::ensure!(mask.len() == s, "static_attn: bad mask shape");
        let scale = 1.0 / (dh as f32).sqrt();
        let mut o = vec![0.0f32; h * dh];
        let mut lse = vec![0.0f32; h];
        for head in 0..h {
            let kvh = head / group;
            let qh = &q[head * dh..(head + 1) * dh];
            // Online softmax (single pass over slots, flash-decode style).
            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            let mut acc = vec![0.0f32; dh];
            for slot in 0..s {
                let off = (slot * kv + kvh) * dh;
                let z = crate::tensor::dot(qh, &keys[off..off + dh]) * scale + mask[slot];
                if z > m {
                    let corr = (m - z).exp();
                    for a in acc.iter_mut() {
                        *a *= corr;
                    }
                    l *= corr;
                    m = z;
                }
                let p = (z - m).exp();
                l += p;
                crate::tensor::axpy(p, &values[off..off + dh], &mut acc);
            }
            let inv = 1.0 / l;
            for (oo, a) in o[head * dh..(head + 1) * dh].iter_mut().zip(acc.iter()) {
                *oo = a * inv;
            }
            lse[head] = m + l.ln();
        }
        Ok(vec![Literal::from_f32(o, &[h, dh]), Literal::from_f32(lse, &[h])])
    }

    /// Exact two-set merge (Eq. 4/5), per query head.
    fn combine_op(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let (h, dh) = (self.spec.q_heads, self.spec.head_dim);
        let o1 = f32_arg(inputs, 0, "o1")?;
        let l1 = f32_arg(inputs, 1, "lse1")?;
        let o2 = f32_arg(inputs, 2, "o2")?;
        let l2 = f32_arg(inputs, 3, "lse2")?;
        anyhow::ensure!(
            o1.len() == h * dh && o2.len() == h * dh && l1.len() == h && l2.len() == h,
            "combine: bad arg shapes"
        );
        let mut o = vec![0.0f32; h * dh];
        let mut lse = vec![0.0f32; h];
        for head in 0..h {
            let p1 = PartialAttention {
                o: o1[head * dh..(head + 1) * dh].to_vec(),
                lse: l1[head],
            };
            let p2 = PartialAttention {
                o: o2[head * dh..(head + 1) * dh].to_vec(),
                lse: l2[head],
            };
            let merged = combine(&[p1, p2]);
            o[head * dh..(head + 1) * dh].copy_from_slice(&merged.o);
            lse[head] = merged.lse;
        }
        Ok(vec![Literal::from_f32(o, &[h, dh]), Literal::from_f32(lse, &[h])])
    }
}

fn f32_arg<'a>(inputs: &[&'a Literal], i: usize, name: &str) -> Result<&'a [f32]> {
    inputs
        .get(i)
        .with_context(|| format!("missing arg {i} ({name})"))?
        .f32s()
        .with_context(|| format!("arg {i} ({name}) is not f32"))
}

fn i32_arg<'a>(inputs: &[&'a Literal], i: usize, name: &str) -> Result<&'a [i32]> {
    inputs
        .get(i)
        .with_context(|| format!("missing arg {i} ({name})"))?
        .i32s()
        .with_context(|| format!("arg {i} ({name}) is not i32"))
}

/// `x * rsqrt(mean(x^2) + 1e-6) * g` per row, or a copy when norm is off
/// (matches `model.py::rmsnorm`).
fn rmsnorm(x: &[f32], g: &[f32], b: usize, d: usize, enabled: bool) -> Vec<f32> {
    let mut out = x.to_vec();
    if !enabled {
        return out;
    }
    for r in 0..b {
        let row = &mut out[r * d..(r + 1) * d];
        let mean_sq = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (mean_sq + 1e-6).sqrt();
        for (v, &gi) in row.iter_mut().zip(g.iter()) {
            *v *= inv * gi;
        }
    }
    out
}

/// Row-major `[b, k] @ [k, n] -> [b, n]`, axpy-ordered for cache locality;
/// zero activations (padded prefill rows, sparse induction streams) are
/// skipped.
fn matmul(x: &[f32], b: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * k);
    debug_assert_eq!(w.len(), k * n);
    let mut out = vec![0.0f32; b * n];
    for r in 0..b {
        let xr = &x[r * k..(r + 1) * k];
        let or = &mut out[r * n..(r + 1) * n];
        for (i, &xi) in xr.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            crate::tensor::axpy(xi, &w[i * n..(i + 1) * n], or);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attend_subset;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn tiny_exec() -> NativeExecutor {
        NativeExecutor::new(SpecMeta::builtin("induction-mini").unwrap())
    }

    #[test]
    fn embed_adds_position_code() {
        let ex = tiny_exec();
        let d = 192;
        let table: Vec<f32> = (0..4096 * d).map(|i| (i % 7) as f32 * 0.1).collect();
        let ids = vec![3i32, 0];
        let pos: Vec<f32> = (0..2 * d).map(|i| i as f32 * 1e-3).collect();
        let t = Literal::from_f32(table.clone(), &[4096, d]);
        let i = xla::Literal::vec1(&ids);
        let p = Literal::from_f32(pos.clone(), &[2, d]);
        let out = ex.execute("embed_b2", &[&t, &i, &p]).unwrap();
        let o = out[0].to_vec::<f32>().unwrap();
        assert_eq!(o.len(), 2 * d);
        assert!((o[0] - (table[3 * d] + pos[0])).abs() < 1e-6);
        assert!((o[d] - (table[0] + pos[d])).abs() < 1e-6);
    }

    #[test]
    fn static_attn_matches_host_attention() {
        let ex = tiny_exec();
        let spec = SpecMeta::builtin("induction-mini").unwrap();
        let (s, dh) = (spec.static_len, spec.head_dim);
        let mut rng = Rng::seed_from(3);
        let q: Vec<f32> = (0..dh).map(|_| rng.normal()).collect();
        let keys: Vec<f32> = (0..s * dh).map(|_| rng.normal()).collect();
        let values: Vec<f32> = (0..s * dh).map(|_| rng.normal()).collect();
        let valid = s - 37;
        let mask: Vec<f32> = (0..s).map(|i| if i < valid { 0.0 } else { -1.0e30 }).collect();
        let out = ex
            .execute(
                "static_attn",
                &[
                    &Literal::from_f32(q.clone(), &[1, dh]),
                    &Literal::from_f32(keys.clone(), &[s, 1, dh]),
                    &Literal::from_f32(values.clone(), &[s, 1, dh]),
                    &Literal::from_f32(mask, &[s]),
                ],
            )
            .unwrap();
        let o_dev = out[0].to_vec::<f32>().unwrap();
        let lse_dev = out[1].to_vec::<f32>().unwrap();

        let k_m = Matrix::from_vec(s, dh, keys);
        let v_m = Matrix::from_vec(s, dh, values);
        let ids: Vec<u32> = (0..valid as u32).collect();
        let part = attend_subset(&q, &k_m, &v_m, &ids, 1.0 / (dh as f32).sqrt());
        for (a, b) in part.o.iter().zip(o_dev.iter()) {
            assert!((a - b).abs() < 1e-3, "o mismatch {a} vs {b}");
        }
        assert!((part.lse - lse_dev[0]).abs() < 1e-3, "lse {} vs {}", part.lse, lse_dev[0]);
    }

    #[test]
    fn qkv_projects_without_norm() {
        let ex = tiny_exec();
        let d = 192;
        // x = e_0 row: q = wq row 0.
        let mut x = vec![0.0f32; d];
        x[0] = 2.0;
        let g = vec![1.0f32; d];
        let wq: Vec<f32> = (0..d * d).map(|i| (i % 5) as f32).collect();
        let wk = vec![0.0f32; d * d];
        let wv = vec![0.0f32; d * d];
        let out = ex
            .execute(
                "qkv_b1",
                &[
                    &Literal::from_f32(x, &[1, d]),
                    &Literal::from_f32(g, &[d]),
                    &Literal::from_f32(wq.clone(), &[d, d]),
                    &Literal::from_f32(wk, &[d, d]),
                    &Literal::from_f32(wv, &[d, d]),
                ],
            )
            .unwrap();
        let q = out[0].to_vec::<f32>().unwrap();
        for j in 0..d {
            assert!((q[j] - 2.0 * wq[j]).abs() < 1e-5);
        }
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![0.0; d]);
    }

    #[test]
    fn unknown_artifact_rejected() {
        let ex = tiny_exec();
        assert!(ex.execute("frobnicate", &[]).is_err());
    }
}
