//! Segmented immutable key storage: the dense host key store as a
//! `Vec<Arc<Matrix>>` of chunks.
//!
//! PR 1's online drain deep-copied the whole dense store to append a
//! watermark-sized batch — an O(context) memcpy per drain that grows with
//! the generation. A segmented store fixes the asymptotics: appending
//! returns a *new* store that shares every existing chunk by `Arc` and
//! adds one chunk holding only the new rows, so the immutable prefix is
//! never recopied (RetroInfer-style append-only segments).
//!
//! To keep per-row lookups logarithmic in the *segment count* rather than
//! linear in the drain count, appends run an LSM-style tail merge: the two
//! youngest segments are merged while the older one is no larger than the
//! younger. Segment sizes therefore decrease geometrically from the tail,
//! the segment count stays O(log n), and each row is copied O(log n)
//! times over the whole generation (amortised O(log n) per appended row —
//! versus O(context) per *drain* for the monolithic store).

use crate::kernel::{self, QuantChunk, QuantMode};
use crate::tensor::Matrix;
use std::sync::Arc;

/// Immutable, cheaply-clonable segmented row store. Logical rows are the
/// concatenation of all segments in order; row ids are stable across
/// appends (rows `[0, old.rows())` of an appended store are bit-identical
/// to the old store).
///
/// ## Quantized scan tier
///
/// With a [`QuantMode`] enabled (see [`SegmentedStore::with_quant`]), the
/// store keeps a compressed **mirror** per chunk (bf16 or symmetric int8,
/// [`crate::kernel::QuantChunk`]). The scoring entry points
/// ([`SegmentedStore::score`], [`SegmentedStore::score_ids`],
/// [`SegmentedStore::score_segment_range`]) read the mirror when one
/// exists — 2–4× fewer key bytes per candidate on the bandwidth-bound
/// scan paths — while [`SegmentedStore::score_exact`] and
/// [`SegmentedStore::row`] always read the f32 payload. Mirrors are built
/// wherever chunks are born (append, tail merge, compaction gather),
/// which are exactly the prefill-build and maintenance-worker paths — so
/// quantization cost never lands on the decode token path — and are
/// shared by `Arc` alongside the chunks they shadow (a compaction that
/// keeps a chunk intact keeps its mirror without re-quantizing).
#[derive(Clone, Debug)]
pub struct SegmentedStore {
    segments: Vec<Arc<Matrix>>,
    /// `starts[i]` = global index of segment i's first row.
    starts: Vec<usize>,
    rows: usize,
    cols: usize,
    /// Scan-tier quantization mode (Off ⇒ `mirrors` holds only `None`).
    quant: QuantMode,
    /// Per-chunk quantized mirrors, parallel to `segments`.
    mirrors: Vec<Option<Arc<QuantChunk>>>,
}

impl SegmentedStore {
    /// Empty store of the given width.
    pub fn new(cols: usize) -> Self {
        SegmentedStore {
            segments: Vec::new(),
            starts: Vec::new(),
            rows: 0,
            cols,
            quant: QuantMode::Off,
            mirrors: Vec::new(),
        }
    }

    /// Single-segment store adopting `m` without copying its buffer.
    pub fn from_arc(m: Arc<Matrix>) -> Self {
        let (rows, cols) = (m.rows(), m.cols());
        let mut s = SegmentedStore::new(cols);
        if rows > 0 {
            s.segments.push(m);
            s.starts.push(0);
            s.rows = rows;
            s.mirrors.push(None);
        }
        s
    }

    /// Adopt a scan-tier quantization mode, (re)building the mirror of
    /// every chunk that lacks one. Build-time only (prefill retriever
    /// construction); later appends/compactions maintain mirrors
    /// incrementally.
    pub fn with_quant(mut self, mode: QuantMode) -> Self {
        self.quant = mode;
        self.mirrors = self
            .segments
            .iter()
            .map(|seg| QuantChunk::build(mode, seg).map(Arc::new))
            .collect();
        self
    }

    /// The scan-tier quantization mode.
    pub fn quant_mode(&self) -> QuantMode {
        self.quant
    }

    /// Whether scans read a quantized mirror (candidate ordering is then
    /// approximate; exact rerank/attention reads stay f32).
    pub fn is_quantized(&self) -> bool {
        self.quant.enabled()
    }

    /// Number of chunks that currently carry a mirror.
    pub fn mirrored_segments(&self) -> usize {
        self.mirrors.iter().flatten().count()
    }

    /// Heap bytes of the quantized mirrors (memory accounting).
    pub fn quant_bytes(&self) -> usize {
        self.mirrors.iter().flatten().map(|c| c.bytes()).sum()
    }

    pub fn from_matrix(m: Matrix) -> Self {
        SegmentedStore::from_arc(Arc::new(m))
    }

    /// Rebuild a store from an explicit chunk sequence, preserving segment
    /// boundaries exactly (no tail merge) — the persistence subsystem's
    /// restore path: a snapshot round-trips the *structure*, not just the
    /// logical rows, so per-segment scans and mirrors come back identical.
    /// Mirrors are rebuilt deterministically from `quant`
    /// ([`QuantChunk::build`] is a pure function of the chunk payload), so
    /// they are bit-identical to the ones the snapshot's source held.
    /// Empty chunks are skipped; every chunk must share `cols`.
    pub fn from_chunks(cols: usize, chunks: Vec<Matrix>, quant: QuantMode) -> Self {
        let mut s = SegmentedStore::new(cols);
        s.quant = quant;
        for chunk in chunks {
            if chunk.rows() == 0 {
                continue;
            }
            assert_eq!(chunk.cols(), cols, "snapshot chunk has wrong width");
            let mirror = QuantChunk::build(quant, &chunk).map(Arc::new);
            s.push_segment(Arc::new(chunk), mirror);
        }
        s
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Number of chunks (diagnostics; O(log rows) by the tail-merge rule).
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// The underlying chunks, oldest first (for segment-local scans).
    pub fn segments(&self) -> &[Arc<Matrix>] {
        &self.segments
    }

    /// Index of the segment containing global row `i`: `partition_point`
    /// returns the first start > i; its predecessor is the segment.
    #[inline]
    fn seg_of(&self, i: usize) -> usize {
        debug_assert!(i < self.rows, "row {i} out of bounds ({} rows)", self.rows);
        self.starts.partition_point(|&s| s <= i) - 1
    }

    /// Borrow logical row `i` (always the exact f32 payload). Rows never
    /// straddle a segment boundary.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let seg = self.seg_of(i);
        self.segments[seg].row(i - self.starts[seg])
    }

    /// Scan-tier score of `q` against row `i`: the quantized mirror when
    /// one is built, the exact f32 row otherwise.
    #[inline]
    pub fn score(&self, q: &[f32], i: usize) -> f32 {
        let seg = self.seg_of(i);
        let local = i - self.starts[seg];
        match self.mirrors[seg].as_deref() {
            Some(ch) => ch.score(q, local),
            None => kernel::dot(q, self.segments[seg].row(local)),
        }
    }

    /// Exact f32 inner product of `q` with row `i` (the rerank tier).
    #[inline]
    pub fn score_exact(&self, q: &[f32], i: usize) -> f32 {
        kernel::dot(q, self.row(i))
    }

    /// Batched scan-tier gather: scores of `q` against `ids`, appended to
    /// `out`. One kernel dispatch per *segment run*: the single-chunk
    /// layout (a fresh prefill) takes one dispatch for the whole batch,
    /// and a multi-chunk store (the steady state once drains have run —
    /// O(log n) chunks by the tail-merge rule) batches each run of ids
    /// that lands in the same chunk, so the per-id chunk lookup pays once
    /// per run and the x86 path still prefetches ahead of the gather.
    pub fn score_ids(&self, q: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        if ids.is_empty() {
            return;
        }
        if self.segments.len() == 1 {
            match self.mirrors[0].as_deref() {
                Some(ch) => ch.score_ids(q, ids, out),
                None => kernel::dot_gather(q, self.segments[0].as_slice(), self.cols, ids, out),
            }
            return;
        }
        out.reserve(ids.len());
        self.gather_runs(ids, |seg, locals| match self.mirrors[seg].as_deref() {
            Some(ch) => ch.score_ids(q, locals, out),
            None => kernel::dot_gather(q, self.segments[seg].as_slice(), self.cols, locals, out),
        });
    }

    /// Batched **exact** f32 gather (the rerank tier): same segment-run
    /// batching as [`SegmentedStore::score_ids`] but always reading the
    /// f32 payload, mirror or not.
    pub fn score_ids_exact(&self, q: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        if ids.is_empty() {
            return;
        }
        if self.segments.len() == 1 {
            kernel::dot_gather(q, self.segments[0].as_slice(), self.cols, ids, out);
            return;
        }
        out.reserve(ids.len());
        self.gather_runs(ids, |seg, locals| {
            kernel::dot_gather(q, self.segments[seg].as_slice(), self.cols, locals, out)
        });
    }

    /// Partition `ids` into maximal runs that land in the same chunk and
    /// visit each run with chunk-local ids: the chunk lookup pays once per
    /// run instead of once per id (runs are long — beam/posting-list ids
    /// cluster, and the tail-merge rule bounds chunks at O(log n)).
    fn gather_runs(&self, ids: &[u32], mut visit: impl FnMut(usize, &[u32])) {
        let mut locals: Vec<u32> = Vec::new();
        let mut i = 0usize;
        while i < ids.len() {
            let seg = self.seg_of(ids[i] as usize);
            let start = self.starts[seg];
            let end = start + self.segments[seg].rows();
            locals.clear();
            while i < ids.len() {
                let id = ids[i] as usize;
                if id < start || id >= end {
                    break;
                }
                locals.push((id - start) as u32);
                i += 1;
            }
            visit(seg, &locals);
        }
    }

    /// Batched scan-tier contiguous scan of segment `s`, segment-local
    /// rows `[lo, hi)`, appended to `out` (the flat-scan hot path).
    pub fn score_segment_range(
        &self,
        q: &[f32],
        s: usize,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) {
        debug_assert!(hi <= self.segments[s].rows());
        match self.mirrors[s].as_deref() {
            Some(ch) => ch.score_range(q, lo, hi, out),
            None => {
                let seg = &self.segments[s];
                let rows = &seg.as_slice()[lo * self.cols..hi * self.cols];
                kernel::dot_rows(q, rows, self.cols, out);
            }
        }
    }

    /// A new store sharing every current chunk and appending `new_rows` as
    /// a fresh tail chunk, then tail-merging to keep the chunk count
    /// logarithmic. The receiver is untouched (persistent structure).
    pub fn append_rows(&self, new_rows: Matrix) -> SegmentedStore {
        if new_rows.rows() == 0 {
            return self.clone();
        }
        let cols = if self.rows == 0 { new_rows.cols() } else { self.cols };
        assert_eq!(new_rows.cols(), cols, "appended rows have wrong width");
        let mut out = self.clone();
        out.cols = cols;
        out.rows += new_rows.rows();
        out.starts.push(self.rows);
        // The fresh chunk is sealed the moment it is appended (this store
        // is persistent), so its mirror is built right here — append runs
        // at drain time on the maintenance worker, off the token path.
        out.mirrors.push(QuantChunk::build(out.quant, &new_rows).map(Arc::new));
        out.segments.push(Arc::new(new_rows));
        // LSM tail merge: fold the youngest chunk into its elder while the
        // elder is no larger — geometric sizes, O(log n) chunks. The
        // merged chunk is re-quantized in the same pass (same amortised
        // O(log n) copies-per-row bound as the merge itself).
        while out.segments.len() >= 2 {
            let last = out.segments[out.segments.len() - 1].rows();
            let prev = out.segments[out.segments.len() - 2].rows();
            if prev > last {
                break;
            }
            let b = out.segments.pop().expect("tail segment");
            let a = out.segments.pop().expect("tail segment");
            out.mirrors.pop();
            out.mirrors.pop();
            out.starts.pop();
            let mut merged = Matrix::zeros(0, cols);
            for r in 0..a.rows() {
                merged.push_row(a.row(r));
            }
            for r in 0..b.rows() {
                merged.push_row(b.row(r));
            }
            out.mirrors.push(QuantChunk::build(out.quant, &merged).map(Arc::new));
            out.segments.push(Arc::new(merged));
        }
        out
    }

    /// Append a non-empty chunk as-is with its mirror (no tail merge; used
    /// by compaction, which controls its own chunk granularity — intact
    /// chunks pass their existing mirror through by `Arc`).
    fn push_segment(&mut self, seg: Arc<Matrix>, mirror: Option<Arc<QuantChunk>>) {
        if seg.rows() == 0 {
            return;
        }
        self.starts.push(self.rows);
        self.rows += seg.rows();
        self.segments.push(seg);
        self.mirrors.push(mirror);
    }

    /// A new store holding exactly the rows named in `keep` (strictly
    /// ascending), renumbered contiguously in order — the storage half of
    /// a reclamation epoch: tombstoned rows are physically dropped, so
    /// host memory actually shrinks. Segments that survive intact are
    /// shared by `Arc` without copying (the common FIFO-retirement case
    /// is a prefix drop, where every suffix segment survives); rows of
    /// partially-surviving segments are gathered into fresh chunks. The
    /// receiver is untouched (persistent structure).
    pub fn compact_select(&self, keep: &[u32]) -> SegmentedStore {
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]), "keep must be ascending");
        debug_assert!(keep.last().map(|&k| (k as usize) < self.rows).unwrap_or(true));
        let mut out = SegmentedStore::new(self.cols);
        out.quant = self.quant;
        let mut i = 0usize; // cursor into keep
        let mut pending = Matrix::zeros(0, self.cols);
        let flush =
            |out: &mut SegmentedStore, pending: &mut Matrix| {
                if pending.rows() > 0 {
                    let flushed = std::mem::replace(pending, Matrix::zeros(0, self.cols));
                    // Gathered survivor rows form a fresh chunk: quantize
                    // it here (compaction runs on the maintenance worker).
                    let mirror = QuantChunk::build(self.quant, &flushed).map(Arc::new);
                    out.push_segment(Arc::new(flushed), mirror);
                }
            };
        for (seg_idx, seg) in self.segments.iter().enumerate() {
            let start = self.starts[seg_idx];
            let end = start + seg.rows();
            let lo = i;
            while i < keep.len() && (keep[i] as usize) < end {
                i += 1;
            }
            if i == lo {
                continue;
            }
            if i - lo == seg.rows() {
                // Every row survives: flush gathered rows, share the chunk
                // AND its mirror (no re-quantization for intact chunks).
                flush(&mut out, &mut pending);
                out.push_segment(seg.clone(), self.mirrors[seg_idx].clone());
            } else {
                for &k in &keep[lo..i] {
                    pending.push_row(seg.row(k as usize - start));
                }
            }
        }
        flush(&mut out, &mut pending);
        debug_assert_eq!(out.rows(), keep.len());
        out
    }

    /// Materialise into one contiguous matrix (index builds that need a
    /// dense view, and the bench's segmented-vs-copy comparison).
    pub fn to_matrix(&self) -> Matrix {
        let mut m = Matrix::zeros(0, self.cols);
        for seg in &self.segments {
            for r in 0..seg.rows() {
                m.push_row(seg.row(r));
            }
        }
        m
    }

    /// Heap bytes of the chunk table (the f32 payload is shared and counted
    /// once per GQA group by the owner).
    pub fn table_bytes(&self) -> usize {
        self.segments.len() * std::mem::size_of::<Arc<Matrix>>()
            + self.starts.len() * std::mem::size_of::<usize>()
    }
}

impl From<Matrix> for SegmentedStore {
    fn from(m: Matrix) -> Self {
        SegmentedStore::from_matrix(m)
    }
}

impl From<Arc<Matrix>> for SegmentedStore {
    fn from(m: Arc<Matrix>) -> Self {
        SegmentedStore::from_arc(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, tag: f32) -> Matrix {
        Matrix::from_fn(rows, cols, |r, c| tag + (r * cols + c) as f32)
    }

    #[test]
    fn rows_match_materialised_view() {
        let mut s = SegmentedStore::from_matrix(mat(100, 4, 0.0));
        for batch in 0..10 {
            s = s.append_rows(mat(7, 4, 1000.0 * (batch + 1) as f32));
        }
        assert_eq!(s.rows(), 170);
        let dense = s.to_matrix();
        for i in 0..s.rows() {
            assert_eq!(s.row(i), dense.row(i), "row {i} diverged");
        }
    }

    #[test]
    fn append_shares_the_prefix_chunk() {
        let base = SegmentedStore::from_matrix(mat(512, 8, 0.0));
        let grown = base.append_rows(mat(16, 8, 9.0));
        // The big prefill chunk must be the same allocation, not a copy.
        assert!(Arc::ptr_eq(&base.segments()[0], &grown.segments()[0]));
        // Old store is untouched (persistent).
        assert_eq!(base.rows(), 512);
        assert_eq!(grown.rows(), 528);
        assert_eq!(grown.row(520)[0], 9.0 + 64.0);
    }

    #[test]
    fn tail_merge_keeps_chunk_count_logarithmic() {
        let mut s = SegmentedStore::from_matrix(mat(1024, 2, 0.0));
        for i in 0..256 {
            s = s.append_rows(mat(4, 2, i as f32));
        }
        assert_eq!(s.rows(), 1024 + 256 * 4);
        // 2048 logical rows: the merge rule bounds chunks by ~log2(n).
        assert!(s.segment_count() <= 12, "too many chunks: {}", s.segment_count());
        let dense = s.to_matrix();
        for i in (0..s.rows()).step_by(97) {
            assert_eq!(s.row(i), dense.row(i));
        }
    }

    #[test]
    fn compact_select_gathers_live_rows() {
        let mut s = SegmentedStore::from_matrix(mat(64, 3, 0.0));
        for b in 0..6 {
            s = s.append_rows(mat(8, 3, 100.0 * (b + 1) as f32));
        }
        let n = s.rows();
        // Keep every row not divisible by 3.
        let keep: Vec<u32> = (0..n as u32).filter(|k| k % 3 != 0).collect();
        let c = s.compact_select(&keep);
        assert_eq!(c.rows(), keep.len());
        assert_eq!(c.cols(), 3);
        for (new, &old) in keep.iter().enumerate() {
            assert_eq!(c.row(new), s.row(old as usize), "row {old} -> {new} diverged");
        }
        // Degenerate selections.
        let none = s.compact_select(&[]);
        assert!(none.is_empty());
        assert_eq!(none.cols(), 3);
        let all: Vec<u32> = (0..n as u32).collect();
        let full = s.compact_select(&all);
        assert_eq!(full.rows(), n);
        assert_eq!(full.row(n - 1), s.row(n - 1));
    }

    #[test]
    fn compact_select_prefix_drop_shares_suffix_segments() {
        // FIFO retirement drops a dense-id prefix: every segment wholly
        // past the cut must be shared by Arc, not copied.
        let mut s = SegmentedStore::from_matrix(mat(32, 2, 0.0));
        s = s.append_rows(mat(64, 2, 500.0)); // tail-merges into one chunk of 96
        s = s.append_rows(mat(16, 2, 900.0));
        s = s.append_rows(mat(4, 2, 990.0));
        assert!(s.segment_count() >= 3, "setup needs several segments");
        // Drop the first segment entirely (keep a pure suffix).
        let first_len = s.segments()[0].rows();
        let keep: Vec<u32> = (first_len as u32..s.rows() as u32).collect();
        let c = s.compact_select(&keep);
        assert_eq!(c.rows(), s.rows() - first_len);
        // Every surviving segment is the same allocation.
        assert_eq!(c.segment_count(), s.segment_count() - 1);
        for (i, seg) in c.segments().iter().enumerate() {
            assert!(Arc::ptr_eq(seg, &s.segments()[i + 1]), "segment {i} copied");
        }
        for (new, &old) in keep.iter().enumerate() {
            assert_eq!(c.row(new), s.row(old as usize));
        }
        // A cut inside the first segment gathers its survivors but still
        // shares the untouched suffix chunks.
        let keep2: Vec<u32> = (4u32..s.rows() as u32).collect();
        let c2 = s.compact_select(&keep2);
        assert_eq!(c2.rows(), s.rows() - 4);
        let last = s.segment_count() - 1;
        assert!(
            Arc::ptr_eq(&c2.segments()[c2.segment_count() - 1], &s.segments()[last]),
            "suffix chunk copied"
        );
        for (new, &old) in keep2.iter().enumerate() {
            assert_eq!(c2.row(new), s.row(old as usize));
        }
    }

    #[test]
    fn quant_mirrors_follow_appends_and_compaction() {
        let mut s = SegmentedStore::from_matrix(mat(64, 8, 0.0)).with_quant(QuantMode::Fp16);
        assert!(s.is_quantized());
        assert_eq!(s.quant_mode(), QuantMode::Fp16);
        assert_eq!(s.mirrored_segments(), s.segment_count());
        // Seven 8-row appends leave a [64, 32, 16, 8] tail-merge shape
        // (an eighth would fold everything into one chunk).
        for b in 0..7 {
            s = s.append_rows(mat(8, 8, 100.0 * (b + 1) as f32));
            assert_eq!(s.mirrored_segments(), s.segment_count(), "append lost a mirror");
        }
        assert!(s.segment_count() >= 3, "setup needs several segments");
        assert!(s.quant_bytes() > 0);
        assert!(s.quant_bytes() < s.rows() * s.cols() * 4, "mirror must be smaller than f32");
        // Batched scoring agrees with the per-row scan-tier score bitwise.
        let q: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.1).collect();
        let ids: Vec<u32> = (0..s.rows() as u32).step_by(7).collect();
        let mut batched = Vec::new();
        s.score_ids(&q, &ids, &mut batched);
        for (j, &id) in ids.iter().enumerate() {
            assert_eq!(batched[j].to_bits(), s.score(&q, id as usize).to_bits());
        }
        let mut ranged = Vec::new();
        s.score_segment_range(&q, 0, 0, s.segments()[0].rows(), &mut ranged);
        for (j, v) in ranged.iter().enumerate() {
            assert_eq!(v.to_bits(), s.score(&q, j).to_bits());
        }
        // Compaction keeps the tier: intact chunks share their mirror by
        // Arc, gathered survivor chunks are re-quantized.
        let keep: Vec<u32> = (4..s.rows() as u32).collect();
        let c = s.compact_select(&keep);
        assert!(c.is_quantized());
        assert_eq!(c.mirrored_segments(), c.segment_count(), "compaction lost a mirror");
        // The untouched suffix chunk's mirror is the same allocation.
        let last = s.segment_count() - 1;
        assert!(Arc::ptr_eq(&c.segments()[c.segment_count() - 1], &s.segments()[last]));
        assert!(c.quant_bytes() > 0, "compacted store must keep a quantized tier");
        // An unquantized store scores the f32 rows exactly.
        let plain = SegmentedStore::from_matrix(mat(16, 8, 0.0));
        assert!(!plain.is_quantized());
        assert_eq!(plain.mirrored_segments(), 0);
        for i in 0..plain.rows() {
            assert_eq!(plain.score(&q, i).to_bits(), plain.score_exact(&q, i).to_bits());
        }
    }

    #[test]
    fn from_chunks_preserves_structure_and_mirrors() {
        let mut s = SegmentedStore::from_matrix(mat(64, 8, 0.0)).with_quant(QuantMode::Int8);
        for b in 0..7 {
            s = s.append_rows(mat(8, 8, 100.0 * (b + 1) as f32));
        }
        let chunks: Vec<Matrix> =
            s.segments().iter().map(|seg| seg.as_ref().clone()).collect();
        let back = SegmentedStore::from_chunks(s.cols(), chunks, s.quant_mode());
        assert_eq!(back.rows(), s.rows());
        assert_eq!(back.segment_count(), s.segment_count());
        assert_eq!(back.mirrored_segments(), s.mirrored_segments());
        assert_eq!(back.quant_bytes(), s.quant_bytes());
        let q: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.1).collect();
        for i in (0..s.rows()).step_by(5) {
            assert_eq!(back.row(i), s.row(i));
            assert_eq!(back.score(&q, i).to_bits(), s.score(&q, i).to_bits());
        }
    }

    #[test]
    fn empty_and_from_arc() {
        let e = SegmentedStore::new(3);
        assert!(e.is_empty());
        assert_eq!(e.segment_count(), 0);
        let g = e.append_rows(mat(5, 3, 1.0));
        assert_eq!(g.rows(), 5);
        assert_eq!(g.row(0), mat(5, 3, 1.0).row(0));
        let a = Arc::new(mat(4, 3, 2.0));
        let s = SegmentedStore::from_arc(a.clone());
        assert!(Arc::ptr_eq(&s.segments()[0], &a));
        // Zero-row matrices produce no segment.
        let z = SegmentedStore::from_matrix(Matrix::zeros(0, 6));
        assert!(z.is_empty());
        assert_eq!(z.cols(), 6);
    }
}
