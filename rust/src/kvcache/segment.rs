//! Segmented immutable key storage: the dense host key store as a
//! `Vec<Arc<Matrix>>` of chunks.
//!
//! PR 1's online drain deep-copied the whole dense store to append a
//! watermark-sized batch — an O(context) memcpy per drain that grows with
//! the generation. A segmented store fixes the asymptotics: appending
//! returns a *new* store that shares every existing chunk by `Arc` and
//! adds one chunk holding only the new rows, so the immutable prefix is
//! never recopied (RetroInfer-style append-only segments).
//!
//! To keep per-row lookups logarithmic in the *segment count* rather than
//! linear in the drain count, appends run an LSM-style tail merge: the two
//! youngest segments are merged while the older one is no larger than the
//! younger. Segment sizes therefore decrease geometrically from the tail,
//! the segment count stays O(log n), and each row is copied O(log n)
//! times over the whole generation (amortised O(log n) per appended row —
//! versus O(context) per *drain* for the monolithic store).

use crate::tensor::Matrix;
use std::sync::Arc;

/// Immutable, cheaply-clonable segmented row store. Logical rows are the
/// concatenation of all segments in order; row ids are stable across
/// appends (rows `[0, old.rows())` of an appended store are bit-identical
/// to the old store).
#[derive(Clone, Debug)]
pub struct SegmentedStore {
    segments: Vec<Arc<Matrix>>,
    /// `starts[i]` = global index of segment i's first row.
    starts: Vec<usize>,
    rows: usize,
    cols: usize,
}

impl SegmentedStore {
    /// Empty store of the given width.
    pub fn new(cols: usize) -> Self {
        SegmentedStore { segments: Vec::new(), starts: Vec::new(), rows: 0, cols }
    }

    /// Single-segment store adopting `m` without copying its buffer.
    pub fn from_arc(m: Arc<Matrix>) -> Self {
        let (rows, cols) = (m.rows(), m.cols());
        let mut s = SegmentedStore::new(cols);
        if rows > 0 {
            s.segments.push(m);
            s.starts.push(0);
            s.rows = rows;
        }
        s
    }

    pub fn from_matrix(m: Matrix) -> Self {
        SegmentedStore::from_arc(Arc::new(m))
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Number of chunks (diagnostics; O(log rows) by the tail-merge rule).
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// The underlying chunks, oldest first (for segment-local scans).
    pub fn segments(&self) -> &[Arc<Matrix>] {
        &self.segments
    }

    /// Borrow logical row `i`. Rows never straddle a segment boundary.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows, "row {i} out of bounds ({} rows)", self.rows);
        // partition_point returns the first start > i; its predecessor is
        // the segment containing i.
        let seg = self.starts.partition_point(|&s| s <= i) - 1;
        self.segments[seg].row(i - self.starts[seg])
    }

    /// A new store sharing every current chunk and appending `new_rows` as
    /// a fresh tail chunk, then tail-merging to keep the chunk count
    /// logarithmic. The receiver is untouched (persistent structure).
    pub fn append_rows(&self, new_rows: Matrix) -> SegmentedStore {
        if new_rows.rows() == 0 {
            return self.clone();
        }
        let cols = if self.rows == 0 { new_rows.cols() } else { self.cols };
        assert_eq!(new_rows.cols(), cols, "appended rows have wrong width");
        let mut out = self.clone();
        out.cols = cols;
        out.rows += new_rows.rows();
        out.starts.push(self.rows);
        out.segments.push(Arc::new(new_rows));
        // LSM tail merge: fold the youngest chunk into its elder while the
        // elder is no larger — geometric sizes, O(log n) chunks.
        while out.segments.len() >= 2 {
            let last = out.segments[out.segments.len() - 1].rows();
            let prev = out.segments[out.segments.len() - 2].rows();
            if prev > last {
                break;
            }
            let b = out.segments.pop().expect("tail segment");
            let a = out.segments.pop().expect("tail segment");
            out.starts.pop();
            let mut merged = Matrix::zeros(0, cols);
            for r in 0..a.rows() {
                merged.push_row(a.row(r));
            }
            for r in 0..b.rows() {
                merged.push_row(b.row(r));
            }
            out.segments.push(Arc::new(merged));
        }
        out
    }

    /// Append a non-empty chunk as-is (no tail merge; used by compaction,
    /// which controls its own chunk granularity).
    fn push_segment(&mut self, seg: Arc<Matrix>) {
        if seg.rows() == 0 {
            return;
        }
        self.starts.push(self.rows);
        self.rows += seg.rows();
        self.segments.push(seg);
    }

    /// A new store holding exactly the rows named in `keep` (strictly
    /// ascending), renumbered contiguously in order — the storage half of
    /// a reclamation epoch: tombstoned rows are physically dropped, so
    /// host memory actually shrinks. Segments that survive intact are
    /// shared by `Arc` without copying (the common FIFO-retirement case
    /// is a prefix drop, where every suffix segment survives); rows of
    /// partially-surviving segments are gathered into fresh chunks. The
    /// receiver is untouched (persistent structure).
    pub fn compact_select(&self, keep: &[u32]) -> SegmentedStore {
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]), "keep must be ascending");
        debug_assert!(keep.last().map(|&k| (k as usize) < self.rows).unwrap_or(true));
        let mut out = SegmentedStore::new(self.cols);
        let mut i = 0usize; // cursor into keep
        let mut pending = Matrix::zeros(0, self.cols);
        for (seg_idx, seg) in self.segments.iter().enumerate() {
            let start = self.starts[seg_idx];
            let end = start + seg.rows();
            let lo = i;
            while i < keep.len() && (keep[i] as usize) < end {
                i += 1;
            }
            if i == lo {
                continue;
            }
            if i - lo == seg.rows() {
                // Every row survives: flush gathered rows, share the chunk.
                if pending.rows() > 0 {
                    let flushed = std::mem::replace(&mut pending, Matrix::zeros(0, self.cols));
                    out.push_segment(Arc::new(flushed));
                }
                out.push_segment(seg.clone());
            } else {
                for &k in &keep[lo..i] {
                    pending.push_row(seg.row(k as usize - start));
                }
            }
        }
        if pending.rows() > 0 {
            out.push_segment(Arc::new(pending));
        }
        debug_assert_eq!(out.rows(), keep.len());
        out
    }

    /// Materialise into one contiguous matrix (index builds that need a
    /// dense view, and the bench's segmented-vs-copy comparison).
    pub fn to_matrix(&self) -> Matrix {
        let mut m = Matrix::zeros(0, self.cols);
        for seg in &self.segments {
            for r in 0..seg.rows() {
                m.push_row(seg.row(r));
            }
        }
        m
    }

    /// Heap bytes of the chunk table (the f32 payload is shared and counted
    /// once per GQA group by the owner).
    pub fn table_bytes(&self) -> usize {
        self.segments.len() * std::mem::size_of::<Arc<Matrix>>()
            + self.starts.len() * std::mem::size_of::<usize>()
    }
}

impl From<Matrix> for SegmentedStore {
    fn from(m: Matrix) -> Self {
        SegmentedStore::from_matrix(m)
    }
}

impl From<Arc<Matrix>> for SegmentedStore {
    fn from(m: Arc<Matrix>) -> Self {
        SegmentedStore::from_arc(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, tag: f32) -> Matrix {
        Matrix::from_fn(rows, cols, |r, c| tag + (r * cols + c) as f32)
    }

    #[test]
    fn rows_match_materialised_view() {
        let mut s = SegmentedStore::from_matrix(mat(100, 4, 0.0));
        for batch in 0..10 {
            s = s.append_rows(mat(7, 4, 1000.0 * (batch + 1) as f32));
        }
        assert_eq!(s.rows(), 170);
        let dense = s.to_matrix();
        for i in 0..s.rows() {
            assert_eq!(s.row(i), dense.row(i), "row {i} diverged");
        }
    }

    #[test]
    fn append_shares_the_prefix_chunk() {
        let base = SegmentedStore::from_matrix(mat(512, 8, 0.0));
        let grown = base.append_rows(mat(16, 8, 9.0));
        // The big prefill chunk must be the same allocation, not a copy.
        assert!(Arc::ptr_eq(&base.segments()[0], &grown.segments()[0]));
        // Old store is untouched (persistent).
        assert_eq!(base.rows(), 512);
        assert_eq!(grown.rows(), 528);
        assert_eq!(grown.row(520)[0], 9.0 + 64.0);
    }

    #[test]
    fn tail_merge_keeps_chunk_count_logarithmic() {
        let mut s = SegmentedStore::from_matrix(mat(1024, 2, 0.0));
        for i in 0..256 {
            s = s.append_rows(mat(4, 2, i as f32));
        }
        assert_eq!(s.rows(), 1024 + 256 * 4);
        // 2048 logical rows: the merge rule bounds chunks by ~log2(n).
        assert!(s.segment_count() <= 12, "too many chunks: {}", s.segment_count());
        let dense = s.to_matrix();
        for i in (0..s.rows()).step_by(97) {
            assert_eq!(s.row(i), dense.row(i));
        }
    }

    #[test]
    fn compact_select_gathers_live_rows() {
        let mut s = SegmentedStore::from_matrix(mat(64, 3, 0.0));
        for b in 0..6 {
            s = s.append_rows(mat(8, 3, 100.0 * (b + 1) as f32));
        }
        let n = s.rows();
        // Keep every row not divisible by 3.
        let keep: Vec<u32> = (0..n as u32).filter(|k| k % 3 != 0).collect();
        let c = s.compact_select(&keep);
        assert_eq!(c.rows(), keep.len());
        assert_eq!(c.cols(), 3);
        for (new, &old) in keep.iter().enumerate() {
            assert_eq!(c.row(new), s.row(old as usize), "row {old} -> {new} diverged");
        }
        // Degenerate selections.
        let none = s.compact_select(&[]);
        assert!(none.is_empty());
        assert_eq!(none.cols(), 3);
        let all: Vec<u32> = (0..n as u32).collect();
        let full = s.compact_select(&all);
        assert_eq!(full.rows(), n);
        assert_eq!(full.row(n - 1), s.row(n - 1));
    }

    #[test]
    fn compact_select_prefix_drop_shares_suffix_segments() {
        // FIFO retirement drops a dense-id prefix: every segment wholly
        // past the cut must be shared by Arc, not copied.
        let mut s = SegmentedStore::from_matrix(mat(32, 2, 0.0));
        s = s.append_rows(mat(64, 2, 500.0)); // tail-merges into one chunk of 96
        s = s.append_rows(mat(16, 2, 900.0));
        s = s.append_rows(mat(4, 2, 990.0));
        assert!(s.segment_count() >= 3, "setup needs several segments");
        // Drop the first segment entirely (keep a pure suffix).
        let first_len = s.segments()[0].rows();
        let keep: Vec<u32> = (first_len as u32..s.rows() as u32).collect();
        let c = s.compact_select(&keep);
        assert_eq!(c.rows(), s.rows() - first_len);
        // Every surviving segment is the same allocation.
        assert_eq!(c.segment_count(), s.segment_count() - 1);
        for (i, seg) in c.segments().iter().enumerate() {
            assert!(Arc::ptr_eq(seg, &s.segments()[i + 1]), "segment {i} copied");
        }
        for (new, &old) in keep.iter().enumerate() {
            assert_eq!(c.row(new), s.row(old as usize));
        }
        // A cut inside the first segment gathers its survivors but still
        // shares the untouched suffix chunks.
        let keep2: Vec<u32> = (4u32..s.rows() as u32).collect();
        let c2 = s.compact_select(&keep2);
        assert_eq!(c2.rows(), s.rows() - 4);
        let last = s.segment_count() - 1;
        assert!(
            Arc::ptr_eq(&c2.segments()[c2.segment_count() - 1], &s.segments()[last]),
            "suffix chunk copied"
        );
        for (new, &old) in keep2.iter().enumerate() {
            assert_eq!(c2.row(new), s.row(old as usize));
        }
    }

    #[test]
    fn empty_and_from_arc() {
        let e = SegmentedStore::new(3);
        assert!(e.is_empty());
        assert_eq!(e.segment_count(), 0);
        let g = e.append_rows(mat(5, 3, 1.0));
        assert_eq!(g.rows(), 5);
        assert_eq!(g.row(0), mat(5, 3, 1.0).row(0));
        let a = Arc::new(mat(4, 3, 2.0));
        let s = SegmentedStore::from_arc(a.clone());
        assert!(Arc::ptr_eq(&s.segments()[0], &a));
        // Zero-row matrices produce no segment.
        let z = SegmentedStore::from_matrix(Matrix::zeros(0, 6));
        assert!(z.is_empty());
        assert_eq!(z.cols(), 6);
    }
}
