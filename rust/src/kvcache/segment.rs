//! Segmented immutable key storage: the dense host key store as a
//! `Vec<Arc<Matrix>>` of chunks.
//!
//! PR 1's online drain deep-copied the whole dense store to append a
//! watermark-sized batch — an O(context) memcpy per drain that grows with
//! the generation. A segmented store fixes the asymptotics: appending
//! returns a *new* store that shares every existing chunk by `Arc` and
//! adds one chunk holding only the new rows, so the immutable prefix is
//! never recopied (RetroInfer-style append-only segments).
//!
//! To keep per-row lookups logarithmic in the *segment count* rather than
//! linear in the drain count, appends run an LSM-style tail merge: the two
//! youngest segments are merged while the older one is no larger than the
//! younger. Segment sizes therefore decrease geometrically from the tail,
//! the segment count stays O(log n), and each row is copied O(log n)
//! times over the whole generation (amortised O(log n) per appended row —
//! versus O(context) per *drain* for the monolithic store).

use crate::tensor::Matrix;
use std::sync::Arc;

/// Immutable, cheaply-clonable segmented row store. Logical rows are the
/// concatenation of all segments in order; row ids are stable across
/// appends (rows `[0, old.rows())` of an appended store are bit-identical
/// to the old store).
#[derive(Clone, Debug)]
pub struct SegmentedStore {
    segments: Vec<Arc<Matrix>>,
    /// `starts[i]` = global index of segment i's first row.
    starts: Vec<usize>,
    rows: usize,
    cols: usize,
}

impl SegmentedStore {
    /// Empty store of the given width.
    pub fn new(cols: usize) -> Self {
        SegmentedStore { segments: Vec::new(), starts: Vec::new(), rows: 0, cols }
    }

    /// Single-segment store adopting `m` without copying its buffer.
    pub fn from_arc(m: Arc<Matrix>) -> Self {
        let (rows, cols) = (m.rows(), m.cols());
        let mut s = SegmentedStore::new(cols);
        if rows > 0 {
            s.segments.push(m);
            s.starts.push(0);
            s.rows = rows;
        }
        s
    }

    pub fn from_matrix(m: Matrix) -> Self {
        SegmentedStore::from_arc(Arc::new(m))
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Number of chunks (diagnostics; O(log rows) by the tail-merge rule).
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// The underlying chunks, oldest first (for segment-local scans).
    pub fn segments(&self) -> &[Arc<Matrix>] {
        &self.segments
    }

    /// Borrow logical row `i`. Rows never straddle a segment boundary.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows, "row {i} out of bounds ({} rows)", self.rows);
        // partition_point returns the first start > i; its predecessor is
        // the segment containing i.
        let seg = self.starts.partition_point(|&s| s <= i) - 1;
        self.segments[seg].row(i - self.starts[seg])
    }

    /// A new store sharing every current chunk and appending `new_rows` as
    /// a fresh tail chunk, then tail-merging to keep the chunk count
    /// logarithmic. The receiver is untouched (persistent structure).
    pub fn append_rows(&self, new_rows: Matrix) -> SegmentedStore {
        if new_rows.rows() == 0 {
            return self.clone();
        }
        let cols = if self.rows == 0 { new_rows.cols() } else { self.cols };
        assert_eq!(new_rows.cols(), cols, "appended rows have wrong width");
        let mut out = self.clone();
        out.cols = cols;
        out.rows += new_rows.rows();
        out.starts.push(self.rows);
        out.segments.push(Arc::new(new_rows));
        // LSM tail merge: fold the youngest chunk into its elder while the
        // elder is no larger — geometric sizes, O(log n) chunks.
        while out.segments.len() >= 2 {
            let last = out.segments[out.segments.len() - 1].rows();
            let prev = out.segments[out.segments.len() - 2].rows();
            if prev > last {
                break;
            }
            let b = out.segments.pop().expect("tail segment");
            let a = out.segments.pop().expect("tail segment");
            out.starts.pop();
            let mut merged = Matrix::zeros(0, cols);
            for r in 0..a.rows() {
                merged.push_row(a.row(r));
            }
            for r in 0..b.rows() {
                merged.push_row(b.row(r));
            }
            out.segments.push(Arc::new(merged));
        }
        out
    }

    /// Materialise into one contiguous matrix (index builds that need a
    /// dense view, and the bench's segmented-vs-copy comparison).
    pub fn to_matrix(&self) -> Matrix {
        let mut m = Matrix::zeros(0, self.cols);
        for seg in &self.segments {
            for r in 0..seg.rows() {
                m.push_row(seg.row(r));
            }
        }
        m
    }

    /// Heap bytes of the chunk table (the f32 payload is shared and counted
    /// once per GQA group by the owner).
    pub fn table_bytes(&self) -> usize {
        self.segments.len() * std::mem::size_of::<Arc<Matrix>>()
            + self.starts.len() * std::mem::size_of::<usize>()
    }
}

impl From<Matrix> for SegmentedStore {
    fn from(m: Matrix) -> Self {
        SegmentedStore::from_matrix(m)
    }
}

impl From<Arc<Matrix>> for SegmentedStore {
    fn from(m: Arc<Matrix>) -> Self {
        SegmentedStore::from_arc(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, tag: f32) -> Matrix {
        Matrix::from_fn(rows, cols, |r, c| tag + (r * cols + c) as f32)
    }

    #[test]
    fn rows_match_materialised_view() {
        let mut s = SegmentedStore::from_matrix(mat(100, 4, 0.0));
        for batch in 0..10 {
            s = s.append_rows(mat(7, 4, 1000.0 * (batch + 1) as f32));
        }
        assert_eq!(s.rows(), 170);
        let dense = s.to_matrix();
        for i in 0..s.rows() {
            assert_eq!(s.row(i), dense.row(i), "row {i} diverged");
        }
    }

    #[test]
    fn append_shares_the_prefix_chunk() {
        let base = SegmentedStore::from_matrix(mat(512, 8, 0.0));
        let grown = base.append_rows(mat(16, 8, 9.0));
        // The big prefill chunk must be the same allocation, not a copy.
        assert!(Arc::ptr_eq(&base.segments()[0], &grown.segments()[0]));
        // Old store is untouched (persistent).
        assert_eq!(base.rows(), 512);
        assert_eq!(grown.rows(), 528);
        assert_eq!(grown.row(520)[0], 9.0 + 64.0);
    }

    #[test]
    fn tail_merge_keeps_chunk_count_logarithmic() {
        let mut s = SegmentedStore::from_matrix(mat(1024, 2, 0.0));
        for i in 0..256 {
            s = s.append_rows(mat(4, 2, i as f32));
        }
        assert_eq!(s.rows(), 1024 + 256 * 4);
        // 2048 logical rows: the merge rule bounds chunks by ~log2(n).
        assert!(s.segment_count() <= 12, "too many chunks: {}", s.segment_count());
        let dense = s.to_matrix();
        for i in (0..s.rows()).step_by(97) {
            assert_eq!(s.row(i), dense.row(i));
        }
    }

    #[test]
    fn empty_and_from_arc() {
        let e = SegmentedStore::new(3);
        assert!(e.is_empty());
        assert_eq!(e.segment_count(), 0);
        let g = e.append_rows(mat(5, 3, 1.0));
        assert_eq!(g.rows(), 5);
        assert_eq!(g.row(0), mat(5, 3, 1.0).row(0));
        let a = Arc::new(mat(4, 3, 2.0));
        let s = SegmentedStore::from_arc(a.clone());
        assert!(Arc::ptr_eq(&s.segments()[0], &a));
        // Zero-row matrices produce no segment.
        let z = SegmentedStore::from_matrix(Matrix::zeros(0, 6));
        assert!(z.is_empty());
        assert_eq!(z.cols(), 6);
    }
}
