//! KV-cache management: paged storage plus the device/host tiering of §3.3.
//!
//! RetrievalAttention splits each head's KV cache into two disjoint sets:
//!
//! * the **device set `W`** — the static pattern (attention-sink prefix +
//!   sliding local window, StreamingLLM-style) held in GPU memory and
//!   attended with the AOT FlashAttention artifact;
//! * the **host set `H`** — everything else, offloaded to CPU memory and
//!   organised by an ANNS index, retrieved per decode query.
//!
//! Tokens generated during decode enter the sliding window; tokens the
//! window slides past land in a small *overflow* buffer that is attended
//! exactly (linear scan) until the engine drains it into the ANN index on
//! a configurable watermark ([`TieredKvCache::advance_indexed`] moves the
//! indexed/overflow boundary). The paper builds its index once at prefill
//! and lets the overflow grow; treating the KV cache as a *live* vector
//! store instead (RetroInfer, arXiv:2505.02922) keeps per-token decode
//! cost bounded for arbitrarily long generations.

pub mod paged;
pub mod segment;

pub use segment::SegmentedStore;

use crate::tensor::Matrix;
use std::ops::Range;

/// The static device-resident pattern: `sink` initial tokens plus a
/// `window`-token sliding suffix (the paper uses 128 + 512 = 640).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StaticPattern {
    pub sink: usize,
    pub window: usize,
}

impl StaticPattern {
    /// The paper's default: 128 initial + 512 local tokens.
    pub const PAPER: StaticPattern = StaticPattern { sink: 128, window: 512 };

    pub fn total(&self) -> usize {
        self.sink + self.window
    }

    /// Device-resident index ranges at sequence length `len`:
    /// `[0, sink)` and `[len - window, len)`, clipped and deduplicated when
    /// the sequence is shorter than the pattern. The subtraction saturates
    /// so degenerate patterns (`window > len` with a short sink, `window ==
    /// 0`) can never underflow `usize`.
    pub fn device_ranges(&self, len: usize) -> (Range<usize>, Range<usize>) {
        if len <= self.total() {
            return (0..len, len..len);
        }
        (0..self.sink.min(len), len.saturating_sub(self.window)..len)
    }

    /// True iff token `i` (at current length `len`) is device-resident.
    pub fn on_device(&self, i: usize, len: usize) -> bool {
        let (a, b) = self.device_ranges(len);
        a.contains(&i) || b.contains(&i)
    }
}

/// Per-(layer, kv-head) tiered KV storage.
///
/// Keys and values are stored once, contiguously, on the host (Appendix C:
/// indexes in the same GQA group share one KV copy and address it by id).
/// Tier membership is computed from positions, so "moving" a token between
/// tiers is free — matching the paper's pointer-based design.
#[derive(Clone)]
pub struct TieredKvCache {
    d: usize,
    keys: Matrix,
    values: Matrix,
    pattern: StaticPattern,
    /// Sequence length at the moment the index was (or would be) built.
    prefill_len: usize,
    /// One past the last host token covered by the ANN index. Starts at
    /// the prefill boundary (`prefill_len - window`, floored at `sink`)
    /// and advances when the engine drains the overflow buffer via
    /// [`TieredKvCache::advance_indexed`].
    indexed_end: usize,
    /// One past the last *retired* host token: tokens in
    /// `[sink, retired_end)` were evicted from the indexed tier
    /// (StreamingLLM-style window retirement over host memory) and are no
    /// longer attended. `0` ⇒ nothing retired.
    retired_end: usize,
}

impl TieredKvCache {
    pub fn new(d: usize, pattern: StaticPattern) -> Self {
        TieredKvCache {
            d,
            keys: Matrix::zeros(0, d),
            values: Matrix::zeros(0, d),
            pattern,
            prefill_len: 0,
            indexed_end: 0,
            retired_end: 0,
        }
    }

    /// Append one (key, value) pair; returns its token position.
    pub fn append(&mut self, key: &[f32], value: &[f32]) -> usize {
        assert_eq!(key.len(), self.d);
        assert_eq!(value.len(), self.d);
        self.keys.push_row(key);
        self.values.push_row(value);
        self.keys.rows() - 1
    }

    /// Bulk-load the prefill KV and mark the prefill boundary.
    pub fn load_prefill(&mut self, keys: Matrix, values: Matrix) {
        assert_eq!(keys.cols(), self.d);
        assert_eq!(keys.rows(), values.rows());
        self.keys = keys;
        self.values = values;
        self.seal_prefill();
    }

    /// Mark the current length as the prefill boundary (after appends).
    pub fn seal_prefill(&mut self) {
        self.prefill_len = self.keys.rows();
        self.indexed_end = if self.prefill_len > self.pattern.total() {
            self.prefill_len - self.pattern.window
        } else {
            self.pattern.sink
        };
    }

    pub fn len(&self) -> usize {
        self.keys.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn pattern(&self) -> StaticPattern {
        self.pattern
    }

    pub fn prefill_len(&self) -> usize {
        self.prefill_len
    }

    #[inline]
    pub fn key(&self, i: usize) -> &[f32] {
        self.keys.row(i)
    }

    #[inline]
    pub fn value(&self, i: usize) -> &[f32] {
        self.values.row(i)
    }

    pub fn keys(&self) -> &Matrix {
        &self.keys
    }

    pub fn values(&self) -> &Matrix {
        &self.values
    }

    /// Token ids currently on the device (`W` of Algorithm 1).
    pub fn device_ids(&self) -> Vec<u32> {
        let (a, b) = self.pattern.device_ranges(self.len());
        a.chain(b).map(|i| i as u32).collect()
    }

    /// First live indexed token: past the sink and past anything retired.
    fn live_indexed_start(&self) -> usize {
        self.retired_end.max(self.pattern.sink)
    }

    /// Host-side *indexed* ids: tokens the ANNS index currently covers —
    /// the prefill host set plus every overflow token drained so far,
    /// minus anything the eviction policy has retired.
    pub fn indexed_ids(&self) -> Vec<u32> {
        let lo = self.live_indexed_start();
        if self.indexed_end <= lo {
            return Vec::new();
        }
        (lo..self.indexed_end).map(|i| i as u32).collect()
    }

    /// Number of live indexed tokens without materialising the id list.
    pub fn indexed_len(&self) -> usize {
        self.indexed_end.saturating_sub(self.live_indexed_start())
    }

    /// One past the last indexed host token (the drain boundary). Clamped
    /// to the cache length: a cache shorter than the static pattern must
    /// not report a boundary of `sink` tokens it does not have.
    pub fn indexed_end(&self) -> usize {
        self.indexed_end.max(self.pattern.sink).min(self.len())
    }

    /// Retired (evicted) host ids: `[sink, retired_end)`. These tokens'
    /// K/V still occupy host memory (dense ids must stay stable) but they
    /// are tombstoned in the indexes and never attended.
    pub fn retired_ids(&self) -> Vec<u32> {
        let lo = self.pattern.sink.min(self.retired_end);
        (lo..self.retired_end).map(|i| i as u32).collect()
    }

    /// True iff token `i` has been retired by the eviction policy.
    #[inline]
    pub fn is_retired(&self, i: usize) -> bool {
        i >= self.pattern.sink && i < self.retired_end
    }

    /// Retire the `n` oldest live indexed tokens (StreamingLLM-style
    /// window retirement over the indexed tier); returns their ids so the
    /// caller can tombstone them in the group's indexes. Clamped to the
    /// indexed boundary — overflow/device tokens can never be retired.
    pub fn retire_oldest_indexed(&mut self, n: usize) -> Vec<u32> {
        let lo = self.live_indexed_start();
        let hi = (lo + n).min(self.indexed_end);
        if hi <= lo {
            return Vec::new();
        }
        self.retired_end = hi;
        (lo..hi).map(|i| i as u32).collect()
    }

    /// Start of the sliding device window at the current length (== one
    /// past the last possible overflow token).
    pub fn window_start(&self) -> usize {
        let len = self.len();
        if len <= self.pattern.total() {
            len
        } else {
            len - self.pattern.window
        }
    }

    /// Drop every token at position >= `new_len` (session truncation).
    /// Index/retired boundaries are clamped so the tier partition stays
    /// exact; the caller is responsible for tombstoning the dropped ids in
    /// (or rebuilding) the ANN indexes.
    pub fn truncate(&mut self, new_len: usize) {
        if new_len >= self.len() {
            return;
        }
        self.keys.truncate_rows(new_len);
        self.values.truncate_rows(new_len);
        self.prefill_len = self.prefill_len.min(new_len);
        let window_floor = if new_len > self.pattern.total() {
            new_len - self.pattern.window
        } else {
            self.pattern.sink.min(new_len)
        };
        self.indexed_end = self.indexed_end.min(window_floor);
        self.retired_end = self.retired_end.min(self.indexed_end);
    }

    /// Host-side *overflow* ids: tokens the sliding window has passed over
    /// but the index does not cover yet — scanned linearly until drained.
    pub fn overflow_ids(&self) -> Vec<u32> {
        let len = self.len();
        if len <= self.pattern.total() {
            return Vec::new();
        }
        let window_start = len - self.pattern.window;
        let lo = self.indexed_end.max(self.pattern.sink).min(window_start);
        (lo..window_start).map(|i| i as u32).collect()
    }

    /// Number of overflow tokens without materialising the id list (the
    /// per-step watermark check runs on every decode token).
    pub fn overflow_len(&self) -> usize {
        let len = self.len();
        if len <= self.pattern.total() {
            return 0;
        }
        let window_start = len - self.pattern.window;
        window_start - self.indexed_end.max(self.pattern.sink).min(window_start)
    }

    /// Record that host tokens below `upto` are now covered by the ANN
    /// index (the engine calls this after a successful overflow drain).
    /// Clamped to the current window start: device-resident tokens can
    /// never be marked as indexed.
    pub fn advance_indexed(&mut self, upto: usize) {
        let len = self.len();
        if len <= self.pattern.total() {
            return;
        }
        let window_start = len - self.pattern.window;
        let bounded = upto.min(window_start);
        self.indexed_end = self.indexed_end.max(self.pattern.sink).max(bounded);
    }

    /// Raw tier boundaries `(prefill_len, indexed_end, retired_end)` for
    /// session persistence — unclamped, exactly as stored, so a snapshot
    /// round-trips the tier partition bit-for-bit (the public accessors
    /// clamp for presentation).
    pub fn persist_bounds(&self) -> (usize, usize, usize) {
        (self.prefill_len, self.indexed_end, self.retired_end)
    }

    /// Rebuild a cache from snapshotted parts (the inverse of reading
    /// [`TieredKvCache::keys`]/[`TieredKvCache::values`] plus
    /// [`TieredKvCache::persist_bounds`]).
    pub fn from_parts(
        pattern: StaticPattern,
        keys: Matrix,
        values: Matrix,
        bounds: (usize, usize, usize),
    ) -> TieredKvCache {
        assert_eq!(keys.rows(), values.rows(), "kv snapshot rows mismatch");
        assert_eq!(keys.cols(), values.cols(), "kv snapshot dims mismatch");
        let d = keys.cols();
        TieredKvCache {
            d,
            keys,
            values,
            pattern,
            prefill_len: bounds.0,
            indexed_end: bounds.1,
            retired_end: bounds.2,
        }
    }

    /// Copy the indexed host keys into a standalone matrix (for index
    /// construction). Ids in the returned matrix are *dense*; map back with
    /// `indexed_ids()[dense_id]`.
    pub fn indexed_keys_matrix(&self) -> Matrix {
        let ids = self.indexed_ids();
        let mut m = Matrix::zeros(0, self.d);
        for &i in &ids {
            m.push_row(self.keys.row(i as usize));
        }
        m
    }

    /// Device-tier bytes (2 tensors × fp16 in the paper's accounting —
    /// see [`crate::hw::kv_bytes_per_token`]; here the element size is a
    /// parameter so experiments can model fp16 while we store f32).
    pub fn device_bytes(&self, elt_size: usize) -> usize {
        self.device_ids().len() * 2 * self.d * elt_size
    }

    /// Host-tier bytes.
    pub fn host_bytes(&self, elt_size: usize) -> usize {
        (self.len() - self.device_ids().len()) * 2 * self.d * elt_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(len: usize, d: usize, pattern: StaticPattern) -> TieredKvCache {
        let mut c = TieredKvCache::new(d, pattern);
        for i in 0..len {
            let k: Vec<f32> = (0..d).map(|j| (i * d + j) as f32).collect();
            let v: Vec<f32> = (0..d).map(|j| -((i * d + j) as f32)).collect();
            c.append(&k, &v);
        }
        c.seal_prefill();
        c
    }

    #[test]
    fn short_sequence_all_on_device() {
        let c = filled(100, 4, StaticPattern { sink: 128, window: 512 });
        assert_eq!(c.device_ids().len(), 100);
        assert!(c.indexed_ids().is_empty());
        assert!(c.overflow_ids().is_empty());
    }

    #[test]
    fn tiers_partition_tokens() {
        let pattern = StaticPattern { sink: 8, window: 16 };
        let mut c = filled(100, 4, pattern);
        // Decode 10 more tokens.
        for i in 0..10 {
            let k = vec![i as f32; 4];
            c.append(&k, &k);
        }
        let dev = c.device_ids();
        let idxed = c.indexed_ids();
        let over = c.overflow_ids();
        let mut all: Vec<u32> = dev.iter().chain(&idxed).chain(&over).copied().collect();
        all.sort_unstable();
        let expect: Vec<u32> = (0..110).collect();
        assert_eq!(all, expect, "tiers must partition all tokens exactly once");
        // Window covers the newest tokens.
        assert!(dev.contains(&109));
        assert!(dev.contains(&0));
        // Overflow = prefill tokens the window slid past (100-16=84 .. 110-16=94).
        assert_eq!(over, (84..94).collect::<Vec<u32>>());
    }

    #[test]
    fn indexed_ids_stable_across_decode() {
        let pattern = StaticPattern { sink: 4, window: 8 };
        let mut c = filled(64, 2, pattern);
        let before = c.indexed_ids();
        for _ in 0..5 {
            c.append(&[0.0, 0.0], &[0.0, 0.0]);
        }
        assert_eq!(before, c.indexed_ids(), "index set must not change during decode");
    }

    #[test]
    fn device_ranges_clip() {
        let p = StaticPattern { sink: 128, window: 512 };
        let (a, b) = p.device_ranges(50);
        assert_eq!(a, 0..50);
        assert!(b.is_empty());
    }

    #[test]
    fn device_ranges_short_context_regressions() {
        // Regression: every len < sink + window must clip, not underflow.
        let p = StaticPattern { sink: 128, window: 512 };
        for len in [0usize, 1, 127, 128, 129, 511, 512, 513, 639, 640] {
            let (a, b) = p.device_ranges(len);
            assert_eq!(a, 0..len, "len={len}");
            assert!(b.is_empty(), "len={len}");
            for i in 0..len {
                assert!(p.on_device(i, len), "token {i} must be on device at len={len}");
            }
        }
        // One past the pattern: both ranges non-degenerate, disjoint.
        let (a, b) = p.device_ranges(641);
        assert_eq!(a, 0..128);
        assert_eq!(b, 129..641);
        // Degenerate patterns stay clipped too.
        let zero_window = StaticPattern { sink: 4, window: 0 };
        let (a, b) = zero_window.device_ranges(10);
        assert_eq!(a, 0..4);
        assert_eq!(b, 10..10);
        let zero_sink = StaticPattern { sink: 0, window: 8 };
        let (a, b) = zero_sink.device_ranges(9);
        assert!(a.is_empty());
        assert_eq!(b, 1..9);
    }

    #[test]
    fn advance_indexed_drains_overflow() {
        let pattern = StaticPattern { sink: 8, window: 16 };
        let mut c = filled(100, 4, pattern);
        for i in 0..40 {
            let k = vec![i as f32; 4];
            c.append(&k, &k);
        }
        // Overflow = prefill boundary (100-16=84) .. window start (140-16=124).
        assert_eq!(c.overflow_ids(), (84..124).collect::<Vec<u32>>());
        assert_eq!(c.overflow_len(), c.overflow_ids().len());
        assert_eq!(c.indexed_end(), 84);
        // Drain everything currently in overflow.
        c.advance_indexed(124);
        assert!(c.overflow_ids().is_empty(), "drained overflow must vanish");
        assert_eq!(c.overflow_len(), 0);
        assert_eq!(c.indexed_ids(), (8..124).collect::<Vec<u32>>());
        // Tiers still partition every token exactly once.
        let mut all: Vec<u32> = c.device_ids();
        all.extend(c.indexed_ids());
        all.extend(c.overflow_ids());
        all.sort_unstable();
        assert_eq!(all, (0..140).collect::<Vec<u32>>());
        // Further decode re-accumulates overflow after the drain point.
        for i in 0..10 {
            let k = vec![i as f32; 4];
            c.append(&k, &k);
        }
        assert_eq!(c.overflow_ids(), (124..134).collect::<Vec<u32>>());
    }

    #[test]
    fn advance_indexed_clamps_to_window() {
        let pattern = StaticPattern { sink: 4, window: 8 };
        let mut c = filled(64, 2, pattern);
        // Requesting past the window start must clamp (device tokens can
        // never be marked indexed), and short caches must be no-ops.
        c.advance_indexed(1000);
        assert_eq!(c.indexed_end(), 64 - 8);
        assert!(c.overflow_ids().is_empty());
        let mut short = filled(6, 2, pattern);
        short.advance_indexed(1000);
        assert!(short.indexed_ids().is_empty());
        assert_eq!(short.device_ids().len(), 6);
    }

    #[test]
    fn short_prefill_overflow_drains_too() {
        // Prompt fits the device pattern; decode pushes past it. The
        // overflow (never indexed at prefill) must be drainable.
        let pattern = StaticPattern { sink: 4, window: 8 };
        let mut c = filled(10, 2, pattern);
        for _ in 0..20 {
            c.append(&[0.0, 0.0], &[0.0, 0.0]);
        }
        // len=30 > 12: overflow = sink..window_start = 4..22.
        assert_eq!(c.overflow_ids(), (4..22).collect::<Vec<u32>>());
        c.advance_indexed(22);
        assert!(c.overflow_ids().is_empty());
        assert_eq!(c.indexed_ids(), (4..22).collect::<Vec<u32>>());
    }

    #[test]
    fn bytes_accounting() {
        let c = filled(1000, 64, StaticPattern { sink: 8, window: 16 });
        // 24 tokens on device, 976 on host; fp16 elements.
        assert_eq!(c.device_bytes(2), 24 * 2 * 64 * 2);
        assert_eq!(c.host_bytes(2), 976 * 2 * 64 * 2);
    }

    #[test]
    fn retire_oldest_bounds_indexed_tier() {
        let pattern = StaticPattern { sink: 8, window: 16 };
        let mut c = filled(100, 4, pattern);
        // Indexed tier: 8..84 (window start) = 76 live tokens.
        assert_eq!(c.indexed_len(), 76);
        let retired = c.retire_oldest_indexed(20);
        assert_eq!(retired, (8..28).collect::<Vec<u32>>());
        assert_eq!(c.indexed_len(), 56);
        assert_eq!(c.indexed_ids(), (28..84).collect::<Vec<u32>>());
        assert_eq!(c.retired_ids(), (8..28).collect::<Vec<u32>>());
        assert!(c.is_retired(10) && !c.is_retired(7) && !c.is_retired(30));
        // Four tiers still partition every token exactly once.
        let mut all: Vec<u32> = c.device_ids();
        all.extend(c.indexed_ids());
        all.extend(c.overflow_ids());
        all.extend(c.retired_ids());
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<u32>>());
        // Retiring past the indexed boundary clamps.
        let more = c.retire_oldest_indexed(1000);
        assert_eq!(more, (28..84).collect::<Vec<u32>>());
        assert_eq!(c.indexed_len(), 0);
    }

    #[test]
    fn truncate_clamps_every_boundary() {
        let pattern = StaticPattern { sink: 8, window: 16 };
        let mut c = filled(100, 4, pattern);
        for i in 0..40 {
            let k = vec![i as f32; 4];
            c.append(&k, &k);
        }
        c.advance_indexed(124);
        c.retire_oldest_indexed(10);
        c.truncate(60);
        assert_eq!(c.len(), 60);
        // Window start at len 60 is 44; indexed must clamp below it.
        assert_eq!(c.window_start(), 44);
        assert!(c.indexed_end() <= 44);
        let mut all: Vec<u32> = c.device_ids();
        all.extend(c.indexed_ids());
        all.extend(c.overflow_ids());
        all.extend(c.retired_ids());
        all.sort_unstable();
        assert_eq!(all, (0..60).collect::<Vec<u32>>(), "tiers must still partition");
        // Truncating below the pattern leaves everything on-device.
        c.truncate(20);
        assert_eq!(c.device_ids().len(), 20);
        assert!(c.indexed_ids().is_empty());
        assert!(c.retired_ids().is_empty());
    }

    #[test]
    fn indexed_end_clamps_to_short_cache() {
        // Regression: a cache shorter than the static pattern used to
        // report a drain boundary of `sink` (tokens it does not have).
        let pattern = StaticPattern { sink: 128, window: 512 };
        let c = filled(50, 4, pattern);
        assert_eq!(c.indexed_end(), 50);
        assert!(c.indexed_ids().is_empty());
        let empty = TieredKvCache::new(4, pattern);
        assert_eq!(empty.indexed_end(), 0);
        // At or above the sink, the boundary saturates at the sink as before.
        let c = filled(200, 4, pattern);
        assert_eq!(c.indexed_end(), 128);
        let c = filled(1000, 4, pattern);
        assert_eq!(c.indexed_end(), 1000 - 512);
    }

    #[test]
    fn indexed_keys_matrix_matches_ids() {
        let c = filled(40, 3, StaticPattern { sink: 2, window: 4 });
        let m = c.indexed_keys_matrix();
        let ids = c.indexed_ids();
        assert_eq!(m.rows(), ids.len());
        for (dense, &orig) in ids.iter().enumerate() {
            assert_eq!(m.row(dense), c.key(orig as usize));
        }
    }
}
