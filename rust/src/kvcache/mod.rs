//! KV-cache management: paged storage plus the device/host tiering of §3.3.
//!
//! RetrievalAttention splits each head's KV cache into two disjoint sets:
//!
//! * the **device set `W`** — the static pattern (attention-sink prefix +
//!   sliding local window, StreamingLLM-style) held in GPU memory and
//!   attended with the AOT FlashAttention artifact;
//! * the **host set `H`** — everything else, offloaded to CPU memory and
//!   organised by an ANNS index, retrieved per decode query.
//!
//! Tokens generated during decode enter the sliding window; tokens the
//! window slides past land in a small unindexed *overflow* buffer that is
//! linearly scanned (generation is short relative to the context, so this
//! buffer stays tiny; the paper's implementation behaves the same way —
//! the index is built once, at prefill).

pub mod paged;

use crate::tensor::Matrix;
use std::ops::Range;

/// The static device-resident pattern: `sink` initial tokens plus a
/// `window`-token sliding suffix (the paper uses 128 + 512 = 640).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StaticPattern {
    pub sink: usize,
    pub window: usize,
}

impl StaticPattern {
    /// The paper's default: 128 initial + 512 local tokens.
    pub const PAPER: StaticPattern = StaticPattern { sink: 128, window: 512 };

    pub fn total(&self) -> usize {
        self.sink + self.window
    }

    /// Device-resident index ranges at sequence length `len`:
    /// `[0, sink)` and `[len - window, len)`, clipped and deduplicated when
    /// the sequence is shorter than the pattern.
    pub fn device_ranges(&self, len: usize) -> (Range<usize>, Range<usize>) {
        if len <= self.total() {
            return (0..len, len..len);
        }
        (0..self.sink, len - self.window..len)
    }

    /// True iff token `i` (at current length `len`) is device-resident.
    pub fn on_device(&self, i: usize, len: usize) -> bool {
        let (a, b) = self.device_ranges(len);
        a.contains(&i) || b.contains(&i)
    }
}

/// Per-(layer, kv-head) tiered KV storage.
///
/// Keys and values are stored once, contiguously, on the host (Appendix C:
/// indexes in the same GQA group share one KV copy and address it by id).
/// Tier membership is computed from positions, so "moving" a token between
/// tiers is free — matching the paper's pointer-based design.
#[derive(Clone)]
pub struct TieredKvCache {
    d: usize,
    keys: Matrix,
    values: Matrix,
    pattern: StaticPattern,
    /// Sequence length at the moment the index was (or would be) built.
    prefill_len: usize,
}

impl TieredKvCache {
    pub fn new(d: usize, pattern: StaticPattern) -> Self {
        TieredKvCache {
            d,
            keys: Matrix::zeros(0, d),
            values: Matrix::zeros(0, d),
            pattern,
            prefill_len: 0,
        }
    }

    /// Append one (key, value) pair; returns its token position.
    pub fn append(&mut self, key: &[f32], value: &[f32]) -> usize {
        assert_eq!(key.len(), self.d);
        assert_eq!(value.len(), self.d);
        self.keys.push_row(key);
        self.values.push_row(value);
        self.keys.rows() - 1
    }

    /// Bulk-load the prefill KV and mark the prefill boundary.
    pub fn load_prefill(&mut self, keys: Matrix, values: Matrix) {
        assert_eq!(keys.cols(), self.d);
        assert_eq!(keys.rows(), values.rows());
        self.keys = keys;
        self.values = values;
        self.prefill_len = self.keys.rows();
    }

    /// Mark the current length as the prefill boundary (after appends).
    pub fn seal_prefill(&mut self) {
        self.prefill_len = self.keys.rows();
    }

    pub fn len(&self) -> usize {
        self.keys.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn pattern(&self) -> StaticPattern {
        self.pattern
    }

    pub fn prefill_len(&self) -> usize {
        self.prefill_len
    }

    #[inline]
    pub fn key(&self, i: usize) -> &[f32] {
        self.keys.row(i)
    }

    #[inline]
    pub fn value(&self, i: usize) -> &[f32] {
        self.values.row(i)
    }

    pub fn keys(&self) -> &Matrix {
        &self.keys
    }

    pub fn values(&self) -> &Matrix {
        &self.values
    }

    /// Token ids currently on the device (`W` of Algorithm 1).
    pub fn device_ids(&self) -> Vec<u32> {
        let (a, b) = self.pattern.device_ranges(self.len());
        a.chain(b).map(|i| i as u32).collect()
    }

    /// Host-side *indexed* ids: prefill tokens that are neither sink nor
    /// were inside the window at prefill time. These are the vectors the
    /// ANNS index is built over.
    pub fn indexed_ids(&self) -> Vec<u32> {
        if self.prefill_len <= self.pattern.total() {
            return Vec::new();
        }
        (self.pattern.sink..self.prefill_len - self.pattern.window).map(|i| i as u32).collect()
    }

    /// Host-side *overflow* ids: tokens the sliding window has passed over
    /// since prefill — on the host but not in the index; scanned linearly.
    pub fn overflow_ids(&self) -> Vec<u32> {
        let len = self.len();
        if len <= self.pattern.total() {
            return Vec::new();
        }
        let window_start = len - self.pattern.window;
        let indexed_end = if self.prefill_len > self.pattern.total() {
            self.prefill_len - self.pattern.window
        } else {
            self.pattern.sink.min(window_start)
        };
        (indexed_end.max(self.pattern.sink)..window_start).map(|i| i as u32).collect()
    }

    /// Copy the indexed host keys into a standalone matrix (for index
    /// construction). Ids in the returned matrix are *dense*; map back with
    /// `indexed_ids()[dense_id]`.
    pub fn indexed_keys_matrix(&self) -> Matrix {
        let ids = self.indexed_ids();
        let mut m = Matrix::zeros(0, self.d);
        for &i in &ids {
            m.push_row(self.keys.row(i as usize));
        }
        m
    }

    /// Device-tier bytes (2 tensors × fp16 in the paper's accounting —
    /// see [`crate::hw::kv_bytes_per_token`]; here the element size is a
    /// parameter so experiments can model fp16 while we store f32).
    pub fn device_bytes(&self, elt_size: usize) -> usize {
        self.device_ids().len() * 2 * self.d * elt_size
    }

    /// Host-tier bytes.
    pub fn host_bytes(&self, elt_size: usize) -> usize {
        (self.len() - self.device_ids().len()) * 2 * self.d * elt_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(len: usize, d: usize, pattern: StaticPattern) -> TieredKvCache {
        let mut c = TieredKvCache::new(d, pattern);
        for i in 0..len {
            let k: Vec<f32> = (0..d).map(|j| (i * d + j) as f32).collect();
            let v: Vec<f32> = (0..d).map(|j| -((i * d + j) as f32)).collect();
            c.append(&k, &v);
        }
        c.seal_prefill();
        c
    }

    #[test]
    fn short_sequence_all_on_device() {
        let c = filled(100, 4, StaticPattern { sink: 128, window: 512 });
        assert_eq!(c.device_ids().len(), 100);
        assert!(c.indexed_ids().is_empty());
        assert!(c.overflow_ids().is_empty());
    }

    #[test]
    fn tiers_partition_tokens() {
        let pattern = StaticPattern { sink: 8, window: 16 };
        let mut c = filled(100, 4, pattern);
        // Decode 10 more tokens.
        for i in 0..10 {
            let k = vec![i as f32; 4];
            c.append(&k, &k);
        }
        let dev = c.device_ids();
        let idxed = c.indexed_ids();
        let over = c.overflow_ids();
        let mut all: Vec<u32> = dev.iter().chain(&idxed).chain(&over).copied().collect();
        all.sort_unstable();
        let expect: Vec<u32> = (0..110).collect();
        assert_eq!(all, expect, "tiers must partition all tokens exactly once");
        // Window covers the newest tokens.
        assert!(dev.contains(&109));
        assert!(dev.contains(&0));
        // Overflow = prefill tokens the window slid past (100-16=84 .. 110-16=94).
        assert_eq!(over, (84..94).collect::<Vec<u32>>());
    }

    #[test]
    fn indexed_ids_stable_across_decode() {
        let pattern = StaticPattern { sink: 4, window: 8 };
        let mut c = filled(64, 2, pattern);
        let before = c.indexed_ids();
        for _ in 0..5 {
            c.append(&[0.0, 0.0], &[0.0, 0.0]);
        }
        assert_eq!(before, c.indexed_ids(), "index set must not change during decode");
    }

    #[test]
    fn device_ranges_clip() {
        let p = StaticPattern { sink: 128, window: 512 };
        let (a, b) = p.device_ranges(50);
        assert_eq!(a, 0..50);
        assert!(b.is_empty());
    }

    #[test]
    fn bytes_accounting() {
        let c = filled(1000, 64, StaticPattern { sink: 8, window: 16 });
        // 24 tokens on device, 976 on host; fp16 elements.
        assert_eq!(c.device_bytes(2), 24 * 2 * 64 * 2);
        assert_eq!(c.host_bytes(2), 976 * 2 * 64 * 2);
    }

    #[test]
    fn indexed_keys_matrix_matches_ids() {
        let c = filled(40, 3, StaticPattern { sink: 2, window: 4 });
        let m = c.indexed_keys_matrix();
        let ids = c.indexed_ids();
        assert_eq!(m.rows(), ids.len());
        for (dense, &orig) in ids.iter().enumerate() {
            assert_eq!(m.row(dense), c.key(orig as usize));
        }
    }
}
