//! Paged device-memory pool (vLLM-style PagedAttention bookkeeping).
//!
//! Models the GPU-side KV allocator: fixed-size pages, per-sequence page
//! tables, and a hard byte budget. This is the substrate behind the
//! `vLLM` baseline rows of Tables 4/7/8 — including their OOM behaviour,
//! which falls out of the same arithmetic the paper quotes (Table 1:
//! ~125 GB per 1M tokens for Llama-3-8B).

use std::collections::HashMap;

/// Error raised when the device budget cannot fit an allocation — the
/// "OOM" entries of Table 4.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutOfDeviceMemory {
    pub requested_pages: usize,
    pub free_pages: usize,
}

impl std::fmt::Display for OutOfDeviceMemory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "device OOM: requested {} pages, {} free", self.requested_pages, self.free_pages)
    }
}

impl std::error::Error for OutOfDeviceMemory {}

/// Fixed-page device KV pool with per-sequence page tables.
pub struct PagedPool {
    /// Tokens per page.
    page_tokens: usize,
    /// Bytes of KV per token (all layers/heads combined).
    bytes_per_token: usize,
    total_pages: usize,
    free: Vec<u32>,
    tables: HashMap<u64, Vec<u32>>,
    /// Tokens currently stored per sequence.
    seq_len: HashMap<u64, usize>,
}

impl PagedPool {
    /// `budget_bytes` of device memory, `bytes_per_token` of KV per token.
    pub fn new(budget_bytes: usize, bytes_per_token: usize, page_tokens: usize) -> Self {
        let page_bytes = bytes_per_token * page_tokens;
        let total_pages = budget_bytes / page_bytes.max(1);
        PagedPool {
            page_tokens,
            bytes_per_token,
            total_pages,
            free: (0..total_pages as u32).rev().collect(),
            tables: HashMap::new(),
            seq_len: HashMap::new(),
        }
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_bytes(&self) -> usize {
        (self.total_pages - self.free.len()) * self.page_tokens * self.bytes_per_token
    }

    /// Extend sequence `seq` by `tokens`, allocating pages as needed.
    pub fn extend(&mut self, seq: u64, tokens: usize) -> Result<(), OutOfDeviceMemory> {
        let len = self.seq_len.get(&seq).copied().unwrap_or(0);
        let have_pages = self.tables.get(&seq).map(|t| t.len()).unwrap_or(0);
        let need_pages = (len + tokens).div_ceil(self.page_tokens);
        let extra = need_pages.saturating_sub(have_pages);
        if extra > self.free.len() {
            return Err(OutOfDeviceMemory { requested_pages: extra, free_pages: self.free.len() });
        }
        let table = self.tables.entry(seq).or_default();
        for _ in 0..extra {
            table.push(self.free.pop().expect("checked above"));
        }
        *self.seq_len.entry(seq).or_insert(0) += tokens;
        Ok(())
    }

    /// Free all pages of a finished sequence.
    pub fn release(&mut self, seq: u64) {
        if let Some(table) = self.tables.remove(&seq) {
            self.free.extend(table);
        }
        self.seq_len.remove(&seq);
    }

    /// Physical page list of a sequence (diagnostics).
    pub fn page_table(&self, seq: u64) -> Option<&[u32]> {
        self.tables.get(&seq).map(|t| t.as_slice())
    }

    pub fn seq_tokens(&self, seq: u64) -> usize {
        self.seq_len.get(&seq).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocates_and_frees() {
        // 16 pages of 4 tokens, 1 byte/token.
        let mut pool = PagedPool::new(64, 1, 4);
        assert_eq!(pool.total_pages(), 16);
        pool.extend(1, 10).unwrap(); // 3 pages
        assert_eq!(pool.free_pages(), 13);
        assert_eq!(pool.page_table(1).unwrap().len(), 3);
        pool.extend(1, 2).unwrap(); // 12 tokens still 3 pages
        assert_eq!(pool.free_pages(), 13);
        pool.extend(1, 1).unwrap(); // 13 tokens -> 4 pages
        assert_eq!(pool.free_pages(), 12);
        pool.release(1);
        assert_eq!(pool.free_pages(), 16);
    }

    #[test]
    fn oom_when_budget_exceeded() {
        let mut pool = PagedPool::new(8, 1, 4); // 2 pages
        pool.extend(1, 8).unwrap();
        let err = pool.extend(2, 1).unwrap_err();
        assert_eq!(err.free_pages, 0);
        // Paper Table 4: vLLM at 24GB / 128K context => OOM. Same arithmetic:
        // Llama-3-8B KV is 131072 bytes/token and the fp16 weights already
        // hold ~16GB of the 24GB card, leaving ~8GB for KV: 8GB / 128KB =
        // 64K tokens < 128K.
        let weights = 16usize * (1 << 30);
        let mut gpu = PagedPool::new(24 * (1 << 30) - weights, 131_072, 16);
        assert!(gpu.extend(7, 128 * 1024).is_err(), "128K context must OOM on 24GB");
    }

    #[test]
    fn pages_not_shared_between_sequences() {
        let mut pool = PagedPool::new(64, 1, 4);
        pool.extend(1, 4).unwrap();
        pool.extend(2, 4).unwrap();
        let p1 = pool.page_table(1).unwrap().to_vec();
        let p2 = pool.page_table(2).unwrap().to_vec();
        assert!(p1.iter().all(|p| !p2.contains(p)));
    }
}
