//! Needle-in-a-haystack grids (Fig 5 / Fig 7 / Fig 8).

use super::tasks::passkey;
use super::Sample;
use crate::util::rng::Rng;

/// One grid cell: context length × depth, with `reps` samples.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub len: usize,
    pub depth: f32,
    pub samples: Vec<Sample>,
}

/// Build the needle grid: for each length and each of `depths` evenly
/// spaced depths, `reps` independent pass-key samples.
pub fn grid(seed: u64, lengths: &[usize], depths: usize, reps: usize) -> Vec<GridCell> {
    let mut rng = Rng::seed_from(seed);
    let mut cells = Vec::with_capacity(lengths.len() * depths);
    for &len in lengths {
        for di in 0..depths {
            let depth = if depths == 1 { 0.5 } else { di as f32 / (depths - 1) as f32 };
            let samples =
                (0..reps).map(|_| passkey(&mut rng.fork(di as u64), len, depth)).collect();
            cells.push(GridCell { len, depth, samples });
        }
    }
    cells
}

/// Render a pass/fail grid as the classic needle heat-map (rows = depth,
/// cols = length), given a per-cell score in [0,1].
pub fn render(cells: &[GridCell], scores: &[f32]) -> String {
    assert_eq!(cells.len(), scores.len());
    let mut lengths: Vec<usize> = cells.iter().map(|c| c.len).collect();
    lengths.sort_unstable();
    lengths.dedup();
    let mut depths: Vec<i32> = cells.iter().map(|c| (c.depth * 1000.0) as i32).collect();
    depths.sort_unstable();
    depths.dedup();

    let mut out = String::from("depth\\len |");
    for l in &lengths {
        out.push_str(&format!(" {:>6} |", short_len(*l)));
    }
    out.push('\n');
    for &dm in &depths {
        out.push_str(&format!("{:>9} |", format!("{:.0}%", dm as f32 / 10.0)));
        for &l in &lengths {
            let mut cell = String::from("      -");
            for (c, s) in cells.iter().zip(scores.iter()) {
                if c.len == l && (c.depth * 1000.0) as i32 == dm {
                    cell = format!(" {:>6}", format!("{:.0}", s * 100.0));
                }
            }
            out.push_str(&cell);
            out.push_str(" |");
        }
        out.push('\n');
    }
    out
}

fn short_len(l: usize) -> String {
    if l >= 1024 && l % 1024 == 0 {
        format!("{}K", l / 1024)
    } else {
        format!("{l}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_dimensions() {
        let cells = grid(1, &[256, 512], 5, 2);
        assert_eq!(cells.len(), 10);
        assert!(cells.iter().all(|c| c.samples.len() == 2));
        assert_eq!(cells[0].depth, 0.0);
        assert_eq!(cells[4].depth, 1.0);
    }

    #[test]
    fn samples_have_correct_length() {
        let cells = grid(2, &[300], 3, 1);
        for c in &cells {
            for s in &c.samples {
                assert_eq!(s.prompt.len(), 300);
            }
        }
    }

    #[test]
    fn render_contains_all_columns() {
        let cells = grid(3, &[256, 1024], 2, 1);
        let scores = vec![1.0; cells.len()];
        let table = render(&cells, &scores);
        assert!(table.contains("256"));
        assert!(table.contains("1K"));
        assert!(table.contains("100"));
    }
}
