//! ∞-Bench-style and RULER-style task generators.
//!
//! Every generator emits associative-recall prompts the induction model
//! provably solves under full attention; a method's measured accuracy is
//! then a pure function of whether its retrieval reaches the critical
//! tokens. Task parameters are chosen to mirror what made each paper task
//! easy or hard for the baselines:
//!
//! * `Retr.P` (pass-key): one pair, anywhere — easy for anything dynamic.
//! * `Retr.N` (number): one pair with a multi-token value (a chain of
//!   induction hops).
//! * `Retr.KV`: hundreds of pairs, query one — the task that drives
//!   Table 2's separation (block/static methods collapse to ~0).
//! * RULER's S/M/MQ/MV/VT families: needle variants with distractors,
//!   multiple queries, ambiguous values, and multi-hop chains.
//! * `CW`/`FW` aggregation: not retrieval-shaped; every attention method
//!   including full attention fails (the paper's Table 9 shows 1.0–1.2%
//!   for CW) — kept for fidelity of the suite's *shape*.

use super::{distinct_keys, distinct_values, filler, Sample};
use crate::util::rng::Rng;

/// Minimum offset for any planted needle (see `haystack_with`).
const PREAMBLE: usize = 4;

/// Insert `needle` into a filler haystack of total length `len` at `depth`
/// ∈ [0,1], followed by the query suffix `[sep key]`.
fn haystack_with(
    rng: &mut Rng,
    len: usize,
    needle: &[u32],
    depth: f32,
    query: &[u32],
) -> Vec<u32> {
    let body = len.saturating_sub(needle.len() + query.len()).max(1);
    // Needles never start before PREAMBLE: position 0's layer-1 output is
    // its own token (nothing precedes it), which makes a match at position
    // 0 self-referential — real benchmarks have a BOS/instruction preamble
    // for the same reason.
    let at = (((body as f32) * depth) as usize)
        .clamp(PREAMBLE, body.saturating_sub(1).max(PREAMBLE));
    let mut prompt = Vec::with_capacity(len);
    for _ in 0..at {
        prompt.push(filler(rng));
    }
    prompt.extend_from_slice(needle);
    while prompt.len() + query.len() < len {
        prompt.push(filler(rng));
    }
    prompt.extend_from_slice(query);
    prompt
}

/// Pass-key retrieval (`Retr.P`): a single key with a 2-token value hidden
/// in fillers; query the key, expect the value chain.
///
/// Values are at least two tokens in every accuracy task: the *first*
/// generated token is produced by the prefill's last hidden state, which
/// is exact full attention for every method (true of the paper's systems
/// too) — only from the second token on does decode-time retrieval
/// matter, so that is where the methods separate.
pub fn passkey(rng: &mut Rng, len: usize, depth: f32) -> Sample {
    number(rng, len, depth, 2)
}

/// Number retrieval (`Retr.N`): the value is a `digits`-token chain; the
/// model must follow the induction chain token by token.
pub fn number(rng: &mut Rng, len: usize, depth: f32, digits: usize) -> Sample {
    let key = distinct_keys(rng, 1)[0];
    let value = distinct_values(rng, digits);
    let mut needle = vec![key];
    needle.extend_from_slice(&value);
    let prompt = haystack_with(rng, len, &needle, depth, &[key]);
    Sample { prompt, expect: value, depth }
}

/// KV retrieval (`Retr.KV`): `pairs` distinct (key, value) pairs back to
/// back; query one uniformly. The critical pair moves with every sample —
/// the dynamic-sparsity stress test.
pub fn kv_retrieval(rng: &mut Rng, len: usize, pairs: usize) -> Sample {
    let keys = distinct_keys(rng, pairs);
    let values = distinct_values(rng, pairs * 2);
    let target = rng.below(pairs);
    let mut body = Vec::with_capacity(pairs * 3 + PREAMBLE);
    for _ in 0..PREAMBLE {
        body.push(filler(rng));
    }
    for (i, k) in keys.iter().enumerate() {
        body.push(*k);
        body.push(values[2 * i]);
        body.push(values[2 * i + 1]);
    }
    // Pad with fillers up to len, query at the end.
    let mut prompt = Vec::with_capacity(len);
    prompt.extend_from_slice(&body);
    while prompt.len() + 1 < len {
        prompt.push(filler(rng));
    }
    prompt.push(keys[target]);
    let depth = (3 * target) as f32 / len.max(1) as f32;
    Sample { prompt, expect: vec![values[2 * target], values[2 * target + 1]], depth }
}

/// RULER single-needle variants: S1 plain, S2 with repeated filler motifs,
/// S3 with `distractors` decoy needles (distinct keys).
pub fn ruler_single(rng: &mut Rng, len: usize, variant: u8, depth: f32) -> Sample {
    match variant {
        1 => passkey(rng, len, depth),

        2 => {
            // Repetitive haystack: harder for representative-vector methods
            // (blocks look identical).
            let key = distinct_keys(rng, 1)[0];
            let values = distinct_values(rng, 2);
            let motif: Vec<u32> = (0..8).map(|_| filler(rng)).collect();
            let mut prompt = Vec::with_capacity(len);
            let body = len - 4;
            let at = (body as f32 * depth) as usize;
            while prompt.len() < at {
                prompt.push(motif[prompt.len() % motif.len()]);
            }
            prompt.push(key);
            prompt.push(values[0]);
            prompt.push(values[1]);
            while prompt.len() + 1 < len {
                prompt.push(motif[prompt.len() % motif.len()]);
            }
            prompt.push(key);
            Sample { prompt, expect: values, depth }
        }
        _ => {
            // S3: decoy needles.
            let keys = distinct_keys(rng, 5);
            let values = distinct_values(rng, 10);
            let mut s = kv_like(rng, len, &keys, &values, 0, depth);
            s.depth = depth;
            s
        }
    }
}

/// Multi-needle (`M1`–`M3`): `needles` pairs at random depths; query one.
pub fn ruler_multi(rng: &mut Rng, len: usize, needles: usize) -> Sample {
    let keys = distinct_keys(rng, needles);
    let values = distinct_values(rng, needles * 2);
    let target = rng.below(needles);
    let depth = rng.f32();
    kv_like(rng, len, &keys, &values, target, depth)
}

/// Scatter pairs at random positions; query `keys[target]`.
fn kv_like(
    rng: &mut Rng,
    len: usize,
    keys: &[u32],
    values: &[u32],
    target: usize,
    target_depth: f32,
) -> Sample {
    // values holds 2 tokens per key.
    let mut prompt: Vec<u32> = (0..len - 1).map(|_| filler(rng)).collect();
    let slots = prompt.len().saturating_sub(3);
    for (i, k) in keys.iter().enumerate() {
        let at = if i == target {
            ((slots as f32) * target_depth) as usize
        } else {
            rng.below(slots.max(1))
        }
        .clamp(PREAMBLE, slots.saturating_sub(1).max(PREAMBLE));
        prompt[at] = *k;
        prompt[at + 1] = values[2 * i];
        prompt[at + 2] = values[2 * i + 1];
    }
    // Re-plant the target in case a later needle overwrote it.
    let at = ((slots as f32) * target_depth) as usize;
    let at = at.clamp(PREAMBLE, slots.saturating_sub(1).max(PREAMBLE));
    prompt[at] = keys[target];
    prompt[at + 1] = values[2 * target];
    prompt[at + 2] = values[2 * target + 1];
    prompt.push(keys[target]);
    Sample {
        prompt,
        expect: vec![values[2 * target], values[2 * target + 1]],
        depth: target_depth,
    }
}

/// Multi-query (`MQ`): same context, several queries — emitted as separate
/// samples sharing one prompt body (the harness prefills once per sample).
pub fn ruler_multi_query(rng: &mut Rng, len: usize, queries: usize) -> Vec<Sample> {
    let pairs = 8.max(queries);
    let keys = distinct_keys(rng, pairs);
    let values = distinct_values(rng, pairs * 2);
    let mut body: Vec<u32> = (0..len - 1).map(|_| filler(rng)).collect();
    let slots = body.len() - 3;
    let mut positions = Vec::new();
    for (i, k) in keys.iter().enumerate() {
        let at = PREAMBLE + rng.below(slots - PREAMBLE);
        body[at] = *k;
        body[at + 1] = values[2 * i];
        body[at + 2] = values[2 * i + 1];
        positions.push(at);
    }
    (0..queries)
        .map(|i| {
            let mut prompt = body.clone();
            prompt.push(keys[i]);
            Sample {
                prompt,
                expect: vec![values[2 * i], values[2 * i + 1]],
                depth: positions[i] as f32 / len as f32,
            }
        })
        .collect()
}

/// Multi-value (`MV`): one key bound to several values — genuinely
/// ambiguous for an induction head (attention mass splits), mirroring the
/// accuracy dips real models show.
pub fn ruler_multi_value(rng: &mut Rng, len: usize, bindings: usize) -> Sample {
    let key = distinct_keys(rng, 1)[0];
    let values = distinct_values(rng, bindings);
    let mut prompt: Vec<u32> = (0..len - 1).map(|_| filler(rng)).collect();
    let slots = prompt.len() - 2;
    for v in &values {
        let at = PREAMBLE + rng.below(slots - PREAMBLE);
        prompt[at] = key;
        prompt[at + 1] = *v;
    }
    prompt.push(key);
    // Any of the bound values counts; grade against the last binding (the
    // convention RULER uses). We expose the first as `expect` and let the
    // harness treat MV as approximate.
    Sample { prompt, expect: vec![values[0]], depth: 0.5 }
}

/// Variable tracking (`VT`): a chain k1→k2→…→k_h; query k1 and follow the
/// chain for `hops` generated tokens (multi-hop induction).
pub fn ruler_variable_tracking(rng: &mut Rng, len: usize, hops: usize) -> Sample {
    use crate::model::induction::SEP_TOKEN;
    let chain = distinct_keys(rng, hops + 1);
    let mut prompt: Vec<u32> = (0..len - 1).map(|_| filler(rng)).collect();
    let slots = prompt.len().saturating_sub(3);
    // Each link is [src, dst, SEP]: the SEP terminator absorbs the
    // spurious "token after dst" induction match (its unembedding column
    // is zero, so it can never win the argmax). Links are spaced >= 3
    // apart so they never overlap.
    let mut ats: Vec<usize> = Vec::new();
    while ats.len() < hops {
        let cand = PREAMBLE + rng.below(slots.saturating_sub(PREAMBLE).max(1));
        if ats.iter().all(|&a: &usize| a.abs_diff(cand) >= 3) {
            ats.push(cand);
            ats.sort_unstable();
        }
    }
    for (i, &at) in ats.iter().enumerate() {
        prompt[at] = chain[i];
        prompt[at + 1] = chain[i + 1];
        prompt[at + 2] = SEP_TOKEN;
    }
    prompt.push(chain[0]);
    Sample { prompt, expect: chain[1..].to_vec(), depth: 0.5 }
}

/// Aggregation (`CW`/`FW`): "most common word" style — not retrieval-
/// shaped; an induction head cannot aggregate counts, and neither can the
/// paper's models at 128K (Table 9: ~1%). Expect tokens are the true
/// answer; all methods are expected to fail.
pub fn ruler_aggregation(rng: &mut Rng, len: usize) -> Sample {
    let word = filler(rng);
    let mut prompt: Vec<u32> = (0..len - 1).map(|_| filler(rng)).collect();
    // Make `word` clearly the most frequent.
    for i in (PREAMBLE..prompt.len()).step_by(10) {
        prompt[i] = word;
    }
    let q = distinct_keys(rng, 1)[0];
    prompt.push(q);
    Sample { prompt, expect: vec![word], depth: 0.5 }
}

/// ∞-Bench realistic-task analogues. `Code.D` / `Math.F` / `En.QA` /
/// `En.MC` in the paper mostly probe information reachable from the
/// static pattern plus a weak global component; modeled here as needle
/// tasks whose critical pair sits in the *last window* with probability
/// `local_frac` and anywhere otherwise — reproducing the paper's pattern
/// that these columns barely separate methods.
pub fn realistic_analogue(rng: &mut Rng, len: usize, local_frac: f32) -> Sample {
    if rng.f32() < local_frac {
        // Critical info within the sliding window (StreamingLLM solves it).
        let depth = 1.0 - rng.f32() * 0.002;
        passkey(rng, len, depth.min(0.999))
    } else {
        let depth = rng.f32();
        passkey(rng, len, depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occurrences(hay: &[u32], token: u32) -> Vec<usize> {
        hay.iter().enumerate().filter(|(_, &t)| t == token).map(|(i, _)| i).collect()
    }

    #[test]
    fn passkey_structure() {
        let mut rng = Rng::seed_from(1);
        let s = passkey(&mut rng, 512, 0.5);
        assert_eq!(s.prompt.len(), 512);
        let key = *s.prompt.last().unwrap();
        let occ = occurrences(&s.prompt[..511], key);
        assert_eq!(occ.len(), 1, "key must appear exactly once in the body");
        assert_eq!(s.prompt[occ[0] + 1], s.expect[0], "value follows key");
    }

    #[test]
    fn number_chain_is_contiguous() {
        let mut rng = Rng::seed_from(2);
        let s = number(&mut rng, 1024, 0.3, 4);
        assert_eq!(s.expect.len(), 4);
        let key = *s.prompt.last().unwrap();
        let at = occurrences(&s.prompt[..1023], key)[0];
        for (i, &v) in s.expect.iter().enumerate() {
            assert_eq!(s.prompt[at + 1 + i], v);
        }
    }

    #[test]
    fn kv_retrieval_unique_keys() {
        let mut rng = Rng::seed_from(3);
        let s = kv_retrieval(&mut rng, 2048, 100);
        let key = *s.prompt.last().unwrap();
        let occ = occurrences(&s.prompt[..s.prompt.len() - 1], key);
        assert_eq!(occ.len(), 1);
        assert_eq!(s.prompt[occ[0] + 1], s.expect[0]);
    }

    #[test]
    fn variable_tracking_chain_causal() {
        let mut rng = Rng::seed_from(4);
        let s = ruler_variable_tracking(&mut rng, 1024, 3);
        assert_eq!(s.expect.len(), 3);
        // Each link (chain[i], chain[i+1]) must exist contiguously.
        let start = *s.prompt.last().unwrap();
        let mut cur = start;
        for &next in &s.expect {
            let occ = occurrences(&s.prompt[..s.prompt.len() - 1], cur);
            assert!(
                occ.iter().any(|&i| s.prompt[i + 1] == next),
                "link {cur}->{next} missing"
            );
            cur = next;
        }
    }

    #[test]
    fn multi_query_shares_body() {
        let mut rng = Rng::seed_from(5);
        let samples = ruler_multi_query(&mut rng, 512, 4);
        assert_eq!(samples.len(), 4);
        for s in &samples {
            assert_eq!(s.prompt.len(), 512);
            assert_eq!(&samples[0].prompt[..511], &s.prompt[..511]);
        }
        // Queries differ.
        assert_ne!(samples[0].prompt[511], samples[1].prompt[511]);
    }

    #[test]
    fn deterministic_generation() {
        let a = passkey(&mut Rng::seed_from(9), 256, 0.7);
        let b = passkey(&mut Rng::seed_from(9), 256, 0.7);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.expect, b.expect);
    }
}
