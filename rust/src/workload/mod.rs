//! Synthetic long-context workloads.
//!
//! With no benchmark datasets available (repro band 0/5), every task is a
//! generator that reproduces the *retrieval structure* of the paper's
//! suites — the property that actually discriminates between sparse
//! attention methods (DESIGN.md §2):
//!
//! * [`tasks`] — ∞-Bench-style and RULER-style task generators over the
//!   induction model's token conventions (associative-recall prompts whose
//!   ground-truth answer is a deterministic function of the prompt).
//! * [`needle`] — the needle-in-a-haystack grid (Fig 5 / Fig 7 / Fig 8).
//! * [`geometry`] — synthetic attention Q/K/V geometry for index-level
//!   experiments (Fig 3/6, Tables 4/5/8) without running a model: hidden
//!   states are shared, Q and K use *different* projections, which is the
//!   mechanism behind the paper's OOD observation.
//!
//! Token conventions (vocab 4096): fillers in [0, 2048), cue/key tokens in
//! [2048, 3072), value tokens in [3072, 4096). Keys and values are unique
//! within a prompt, so the induction chain is unambiguous unless a task
//! deliberately makes it ambiguous (MV).

pub mod geometry;
pub mod needle;
pub mod tasks;

use crate::util::rng::Rng;

/// Vocabulary partition bounds (must stay below the presets' vocab=4096).
pub const FILLER_BASE: u32 = 0;
pub const FILLER_COUNT: u32 = 2048;
pub const KEY_BASE: u32 = 2048;
pub const KEY_COUNT: u32 = 1024;
pub const VALUE_BASE: u32 = 3072;
pub const VALUE_COUNT: u32 = 1023; // 4095 is SEP_TOKEN (reserved)

/// One evaluation sample: a prompt, and the exact tokens a correct model
/// must generate (greedy), in order.
#[derive(Clone, Debug)]
pub struct Sample {
    pub prompt: Vec<u32>,
    pub expect: Vec<u32>,
    /// Depth of the critical information in [0, 1] (needle grid rows).
    pub depth: f32,
}

impl Sample {
    /// Grade a generation: fraction of expected tokens produced correctly
    /// (prefix match — one wrong token derails the chain, as in real
    /// greedy decoding).
    pub fn grade(&self, generated: &[u32]) -> f32 {
        if self.expect.is_empty() {
            return 1.0;
        }
        let mut ok = 0;
        for (e, g) in self.expect.iter().zip(generated.iter()) {
            if e == g {
                ok += 1;
            } else {
                break;
            }
        }
        ok as f32 / self.expect.len() as f32
    }

    pub fn passed(&self, generated: &[u32]) -> bool {
        self.grade(generated) >= 1.0
    }
}

/// Random filler token.
pub fn filler(rng: &mut Rng) -> u32 {
    FILLER_BASE + rng.below(FILLER_COUNT as usize) as u32
}

/// `n` distinct key tokens.
pub fn distinct_keys(rng: &mut Rng, n: usize) -> Vec<u32> {
    rng.sample_indices(KEY_COUNT as usize, n).into_iter().map(|i| KEY_BASE + i as u32).collect()
}

/// `n` distinct value tokens.
pub fn distinct_values(rng: &mut Rng, n: usize) -> Vec<u32> {
    rng.sample_indices(VALUE_COUNT as usize, n)
        .into_iter()
        .map(|i| VALUE_BASE + i as u32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_partition_disjoint() {
        assert_eq!(FILLER_BASE + FILLER_COUNT, KEY_BASE);
        assert_eq!(KEY_BASE + KEY_COUNT, VALUE_BASE);
        assert!(VALUE_BASE + VALUE_COUNT < 4096, "SEP token must stay reserved");
    }

    #[test]
    fn grade_prefix_semantics() {
        let s = Sample { prompt: vec![], expect: vec![1, 2, 3, 4], depth: 0.0 };
        assert_eq!(s.grade(&[1, 2, 3, 4]), 1.0);
        assert_eq!(s.grade(&[1, 2, 9, 4]), 0.5);
        assert_eq!(s.grade(&[9, 2, 3, 4]), 0.0);
        assert!(s.passed(&[1, 2, 3, 4, 7]));
    }

    #[test]
    fn distinct_helpers_are_distinct_and_in_range() {
        let mut rng = Rng::seed_from(1);
        let keys = distinct_keys(&mut rng, 100);
        let set: std::collections::HashSet<_> = keys.iter().collect();
        assert_eq!(set.len(), 100);
        assert!(keys.iter().all(|&k| (KEY_BASE..KEY_BASE + KEY_COUNT).contains(&k)));
        let vals = distinct_values(&mut rng, 50);
        assert!(vals.iter().all(|&v| (VALUE_BASE..VALUE_BASE + VALUE_COUNT).contains(&v)));
    }
}
