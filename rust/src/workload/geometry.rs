//! Synthetic attention Q/K/V geometry for index-level experiments.
//!
//! Fig 3/6 and the latency tables need realistic attention vectors at
//! scales (128K–1M keys) where running even the mini models' prefill is
//! wasteful. This generator reproduces the *mechanism* behind the paper's
//! OOD observation directly: queries and keys are different linear
//! projections of a shared hidden-state stream,
//!
//! ```text
//!   h_i ~ anisotropic gaussian state with slow drift (long documents
//!         have correlated topics);  k_i = h_i·W_k,  q_t = h_t'·W_q
//! ```
//!
//! so K forms tight topic clusters (long documents have segment-level
//! topical structure — the low intrinsic dimensionality that makes K→K
//! ANNS easy) and Q lives in a differently-oriented, biased ellipsoid —
//! Mahalanobis-far from K (verified by `attention::ood`, the Fig 3b
//! experiment) with true top-k spread across many clusters (what makes
//! Q→K hard for key-clustered indexes).

use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// A generated attention-geometry head: keys, values, queries.
#[derive(Clone)]
pub struct HeadGeometry {
    pub keys: Matrix,
    pub values: Matrix,
    /// Queries drawn from the same process as decode-time queries.
    pub queries: Matrix,
}

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct GeometryParams {
    /// Hidden-state width (the model's d_model analogue).
    pub hidden: usize,
    /// Head dimension of the emitted vectors.
    pub head_dim: usize,
    /// Drift rate of the hidden stream in [0,1] (0 = iid, 1 = frozen).
    pub drift: f32,
    /// Anisotropy: fraction of hidden dims with 4x the variance.
    pub anisotropy: f32,
    /// Number of topic clusters the hidden stream visits.
    pub topics: usize,
    /// Mean tokens per topic segment.
    pub segment: usize,
    /// Within-topic noise scale relative to the topic-center scale.
    pub topic_noise: f32,
    /// Query gain: ‖q‖ / ‖k‖ ratio. Real attention heads emit queries with
    /// systematically larger norms than keys.
    pub query_gain: f32,
    /// Magnitude of the fixed query-mean offset (the "attention bias"
    /// direction real heads carry). This offset plus the gain is what
    /// drives the >10x Mahalanobis gap of Fig 3b.
    pub query_offset: f32,
}

impl Default for GeometryParams {
    fn default() -> Self {
        GeometryParams {
            hidden: 256,
            head_dim: 64,
            drift: 0.95,
            anisotropy: 0.25,
            topics: 64,
            segment: 256,
            topic_noise: 0.35,
            query_gain: 2.0,
            query_offset: 6.0,
        }
    }
}

/// Generate one head's geometry: `n` keys/values and `nq` queries.
pub fn generate(params: &GeometryParams, n: usize, nq: usize, seed: u64) -> HeadGeometry {
    let mut rng = Rng::seed_from(seed);
    let hd = params.hidden;
    let dh = params.head_dim;
    let scale = 1.0 / (hd as f32).sqrt();
    let wk = Matrix::from_fn(hd, dh, |_, _| rng.normal() * scale);
    let wq = Matrix::from_fn(hd, dh, |_, _| rng.normal() * scale);
    let wv = Matrix::from_fn(hd, dh, |_, _| rng.normal() * scale);

    // Per-dim variance profile (anisotropic, shared by keys and queries —
    // the OOD comes from the projections, not the hidden states).
    let boost = (hd as f32 * params.anisotropy) as usize;
    let sigma: Vec<f32> = (0..hd).map(|i| if i < boost { 2.0 } else { 0.5 }).collect();

    // Topic centers: the low-dimensional cluster skeleton of the corpus.
    let centers = Matrix::from_fn(params.topics.max(1), hd, |_, c| rng.normal() * sigma[c]);

    let a = params.drift;
    let b = (1.0 - a * a).sqrt();
    let project = |h: &[f32], w: &Matrix| -> Vec<f32> {
        let mut out = vec![0.0f32; w.cols()];
        for (i, &hi) in h.iter().enumerate() {
            if hi != 0.0 {
                crate::tensor::axpy(hi, w.row(i), &mut out);
            }
        }
        out
    };
    // Hidden stream: topic center + AR(1) within-topic noise; topic
    // switches every ~segment tokens.
    let mut keys = Matrix::zeros(0, dh);
    let mut values = Matrix::zeros(0, dh);
    let mut topic = rng.below(params.topics.max(1));
    let mut noise = vec![0.0f32; hd];
    let mut h = vec![0.0f32; hd];
    for t in 0..n {
        if t % params.segment.max(1) == 0 {
            topic = rng.below(params.topics.max(1));
        }
        for ((ni, s), &c) in noise.iter_mut().zip(sigma.iter()).zip(centers.row(topic)) {
            *ni = a * *ni + b * rng.normal() * s;
            let _ = c;
        }
        for i in 0..hd {
            h[i] = centers[(topic, i)] + params.topic_noise * noise[i];
        }
        keys.push_row(&project(&h, &wk));
        values.push_row(&project(&h, &wv));
    }
    // Queries: same topic process, different realization, W_q projection.
    let mut hq = vec![0.0f32; hd];
    // Fixed query-bias direction (per head), unit-normalized then scaled.
    let mut bias: Vec<f32> = (0..dh).map(|_| rng.normal()).collect();
    let bn = crate::tensor::norm(&bias).max(1e-6);
    for v in bias.iter_mut() {
        *v *= params.query_offset / bn;
    }
    let mut queries = Matrix::zeros(0, dh);
    let mut qtopic = rng.below(params.topics.max(1));
    let mut qnoise = vec![0.0f32; hd];
    for t in 0..nq {
        // Queries hop topics faster (each decode step looks somewhere new).
        if t % 4 == 0 {
            qtopic = rng.below(params.topics.max(1));
        }
        for (ni, s) in qnoise.iter_mut().zip(sigma.iter()) {
            *ni = a * *ni + b * rng.normal() * s;
        }
        for i in 0..hd {
            hq[i] = centers[(qtopic, i)] + params.topic_noise * qnoise[i];
        }
        let mut q = project(&hq, &wq);
        for (qv, bv) in q.iter_mut().zip(bias.iter()) {
            *qv = *qv * params.query_gain + bv;
        }
        queries.push_row(&q);
    }
    HeadGeometry { keys, values, queries }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::ood::measure_ood;

    #[test]
    fn shapes() {
        let g = generate(&GeometryParams::default(), 500, 50, 1);
        assert_eq!(g.keys.rows(), 500);
        assert_eq!(g.values.rows(), 500);
        assert_eq!(g.queries.rows(), 50);
        assert_eq!(g.keys.cols(), 64);
    }

    #[test]
    fn queries_are_ood_relative_to_keys() {
        // The Fig 3b mechanism: Q must be Mahalanobis-far from K while
        // held-out keys are close.
        let g = generate(&GeometryParams::default(), 4000, 500, 2);
        let fit = Matrix::from_fn(3000, 64, |r, c| g.keys[(r, c)]);
        let holdout = Matrix::from_fn(900, 64, |r, c| g.keys[(3000 + r, c)]);
        let rep = measure_ood(&fit, &holdout, &g.queries);
        assert!(
            rep.gap() > 2.0,
            "expected OOD gap (paper reports >10x on real models), got {}",
            rep.gap()
        );
    }

    #[test]
    fn drift_creates_local_correlation() {
        let g = generate(&GeometryParams::default(), 1000, 10, 3);
        let near = crate::tensor::dot(g.keys.row(500), g.keys.row(501));
        let mut far_acc = 0.0;
        for i in 0..20 {
            far_acc += crate::tensor::dot(g.keys.row(500), g.keys.row(100 + i * 7)).abs();
        }
        let far = far_acc / 20.0;
        assert!(near.abs() > far * 0.8, "drift should correlate neighbors: near={near} far={far}");
    }

    #[test]
    fn deterministic() {
        let a = generate(&GeometryParams::default(), 100, 10, 5);
        let b = generate(&GeometryParams::default(), 100, 10, 5);
        assert_eq!(a.keys, b.keys);
        assert_eq!(a.queries, b.queries);
    }
}
