//! Durable spill-tier IO: every byte the session cache moves to or from
//! disk goes through these helpers (enforced by the `spill-direct-io`
//! rule in `cargo xtask lint` — no raw `std::fs::` anywhere else under
//! `store/`).
//!
//! The discipline is the classic storage-engine one:
//!
//! * **Atomic publication** — [`write_atomic`] writes `session-<id>.ras`
//!   as temp file → flush → fsync → rename. A reader (including a boot
//!   scan after a crash) can only ever observe a complete snapshot or no
//!   snapshot; a crash mid-write leaves a `.tmp` orphan that
//!   [`scan_dir`] deletes. A *failed* write removes its own temp file —
//!   no litter accumulates under repeated faults.
//! * **Quarantine, not deletion** — [`quarantine`] renames a snapshot
//!   that failed restore verification to `<name>.corrupt`. The bytes are
//!   evidence (and manual-recovery material); only the registry entry is
//!   dropped. Quarantined files are invisible to [`scan_dir`].
//! * **Bounded retry** — [`with_retries`] wraps transient-prone ops
//!   (open, write) in a bounded exponential-backoff loop, so a blip does
//!   not fail a park while a hard-down disk still surfaces promptly.
//!
//! Fault-injection sites: `spill.write` (temp-file creation/write),
//! `spill.commit` (between fsync and rename — the simulated
//! crash-before-publish), `spill.read` (restore-side open). See
//! docs/robustness.md.

use crate::util::failpoint;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Canonical spill path for a session id.
pub fn session_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("session-{id}.ras"))
}

/// Inverse of [`session_path`] on a file *name*: `session-<id>.ras` →
/// id. Temp, quarantine and foreign files all return `None`.
pub fn parse_session_name(name: &str) -> Option<u64> {
    name.strip_prefix("session-")?.strip_suffix(".ras")?.parse().ok()
}

/// Create the spill directory (and parents) if missing.
pub fn ensure_dir(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create spill dir {}", dir.display()))
}

/// Best-effort file removal (budget-accounting paths tolerate a file
/// that is already gone).
pub fn remove(path: &Path) {
    std::fs::remove_file(path).ok();
}

/// Best-effort removal of an (empty) spill directory.
pub fn remove_dir(dir: &Path) {
    std::fs::remove_dir(dir).ok();
}

/// Atomically publish a session snapshot: `write` serializes into a
/// buffered temp file in `dir`, which is then flushed, fsynced, and
/// renamed to `session-<id>.ras`. Returns the final path and the bytes
/// `write` reported. On any failure the temp file is removed and the
/// final path is untouched (either absent, or still the previous
/// snapshot — a re-park of the same id replaces atomically).
pub fn write_atomic(
    dir: &Path,
    id: u64,
    write: impl FnOnce(&mut dyn Write) -> Result<u64>,
) -> Result<(PathBuf, u64)> {
    let path = session_path(dir, id);
    let tmp = dir.join(format!("session-{id}.ras.tmp"));
    let attempt = (|| -> Result<u64> {
        failpoint::trigger("spill.write")?;
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("create spill temp {}", tmp.display()))?;
        let mut buf = std::io::BufWriter::new(file);
        let bytes = write(&mut buf)?;
        buf.flush().context("flush spill temp")?;
        let file = buf
            .into_inner()
            .map_err(|e| anyhow::anyhow!("flush spill temp {}: {}", tmp.display(), e.error()))?;
        // fsync before rename: the rename must never publish a name whose
        // bytes are still only in the page cache when the machine dies.
        file.sync_all().with_context(|| format!("fsync spill temp {}", tmp.display()))?;
        drop(file);
        failpoint::trigger("spill.commit")?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publish spill file {}", path.display()))?;
        // Best-effort directory fsync so the rename itself is durable.
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
        Ok(bytes)
    })();
    match attempt {
        Ok(bytes) => Ok((path, bytes)),
        Err(e) => {
            remove(&tmp);
            Err(e)
        }
    }
}

/// Open a spill file for restore (the instrumented read-side entry).
pub fn open_for_read(path: &Path) -> Result<std::fs::File> {
    failpoint::trigger("spill.read")?;
    std::fs::File::open(path).with_context(|| format!("open spill file {}", path.display()))
}

/// Quarantine a snapshot that failed restore verification: rename it to
/// `<name>.corrupt` and return where the bytes now live. Best-effort —
/// if even the rename fails (read-only filesystem) the original path is
/// returned and the file left in place; either way the caller drops the
/// registry entry, so the file can never be restored from again.
pub fn quarantine(path: &Path) -> PathBuf {
    let Some(name) = path.file_name() else {
        return path.to_path_buf();
    };
    let mut qname = name.to_os_string();
    qname.push(".corrupt");
    let qpath = path.with_file_name(qname);
    match std::fs::rename(path, &qpath) {
        Ok(()) => qpath,
        Err(_) => path.to_path_buf(),
    }
}

/// A parked snapshot rediscovered by a boot scan.
#[derive(Clone, Debug)]
pub struct ScannedSession {
    pub id: u64,
    pub path: PathBuf,
    /// On-disk size (the restart-recovery disk accounting).
    pub bytes: u64,
}

/// Scan a spill directory at boot: rediscover `session-<id>.ras`
/// snapshots (returned sorted by id for deterministic accounting),
/// delete orphaned `.tmp` files (a crash between write and rename), and
/// skip `.corrupt` quarantine files and anything foreign. A missing
/// directory is an empty scan, not an error.
pub fn scan_dir(dir: &Path) -> Result<Vec<ScannedSession>> {
    let mut out = Vec::new();
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(out),
    };
    for entry in rd.flatten() {
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if name.starts_with("session-") && name.ends_with(".ras.tmp") {
            // Crash litter: a temp file that never got renamed holds an
            // incomplete snapshot by construction. Its session — if it
            // exists at all — is the previous `.ras` next to it.
            remove(&path);
            continue;
        }
        let Some(id) = parse_session_name(name) else {
            continue;
        };
        let bytes = entry.metadata().map(|m| m.len()).unwrap_or(0);
        out.push(ScannedSession { id, path, bytes });
    }
    out.sort_by_key(|s| s.id);
    Ok(out)
}

/// Run `op` up to `1 + retries` times, sleeping `backoff_ms` (doubling
/// per attempt) between tries. Transient spill IO — a busy disk, an AV
/// scanner holding a handle — resolves inside the loop; a hard failure
/// surfaces the *last* error with the attempt count attached.
pub fn with_retries<T>(
    what: &str,
    retries: usize,
    backoff_ms: u64,
    mut op: impl FnMut() -> Result<T>,
) -> Result<T> {
    let mut attempt = 0usize;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if attempt < retries => {
                attempt += 1;
                if backoff_ms > 0 {
                    let exp = (attempt - 1).min(6) as u32;
                    std::thread::sleep(std::time::Duration::from_millis(backoff_ms << exp));
                }
                let _ = e; // retried: the next failure carries the story
            }
            Err(e) => {
                return Err(e.context(format!("{what} failed after {} attempt(s)", attempt + 1)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ra-spill-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn names_roundtrip() {
        let dir = PathBuf::from("/x");
        assert_eq!(session_path(&dir, 42), PathBuf::from("/x/session-42.ras"));
        assert_eq!(parse_session_name("session-42.ras"), Some(42));
        assert_eq!(parse_session_name("session-42.ras.tmp"), None);
        assert_eq!(parse_session_name("session-42.ras.corrupt"), None);
        assert_eq!(parse_session_name("other.ras"), None);
    }

    #[test]
    fn write_atomic_publishes_or_leaves_nothing() {
        let dir = tmpdir("atomic");
        let (path, bytes) = write_atomic(&dir, 7, |w| {
            w.write_all(b"snapshot bytes").unwrap();
            Ok(14)
        })
        .unwrap();
        assert_eq!(bytes, 14);
        assert_eq!(std::fs::read(&path).unwrap(), b"snapshot bytes");
        assert!(!dir.join("session-7.ras.tmp").exists(), "temp renamed away");
        // A failing serializer leaves neither temp nor final file...
        let err = write_atomic(&dir, 8, |_| anyhow::bail!("disk on fire"));
        assert!(err.is_err());
        assert!(!dir.join("session-8.ras.tmp").exists(), "failed write removes temp");
        assert!(!session_path(&dir, 8).exists());
        // ...and a failing RE-park keeps the previous snapshot intact.
        let err = write_atomic(&dir, 7, |_| anyhow::bail!("disk on fire"));
        assert!(err.is_err());
        assert_eq!(std::fs::read(&path).unwrap(), b"snapshot bytes");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scan_finds_sessions_cleans_tmp_skips_quarantine() {
        let dir = tmpdir("scan");
        std::fs::write(session_path(&dir, 3), b"ccc").unwrap();
        std::fs::write(session_path(&dir, 1), b"a").unwrap();
        std::fs::write(dir.join("session-9.ras.tmp"), b"partial").unwrap();
        std::fs::write(dir.join("session-2.ras.corrupt"), b"bad").unwrap();
        std::fs::write(dir.join("notes.txt"), b"x").unwrap();
        let scanned = scan_dir(&dir).unwrap();
        let ids: Vec<u64> = scanned.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1, 3], "sorted, quarantine and foreign files skipped");
        assert_eq!(scanned[1].bytes, 3);
        assert!(!dir.join("session-9.ras.tmp").exists(), "orphan temp deleted");
        assert!(dir.join("session-2.ras.corrupt").exists(), "quarantine preserved");
        // Missing directory scans empty.
        assert!(scan_dir(Path::new("/nonexistent/ra-spill")).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantine_renames_and_preserves_bytes() {
        let dir = tmpdir("quar");
        let path = session_path(&dir, 5);
        std::fs::write(&path, b"garbled").unwrap();
        let q = quarantine(&path);
        assert_eq!(q, dir.join("session-5.ras.corrupt"));
        assert!(!path.exists());
        assert_eq!(std::fs::read(&q).unwrap(), b"garbled");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retries_are_bounded_and_counted() {
        let mut calls = 0;
        let ok: Result<u32> = with_retries("op", 3, 0, || {
            calls += 1;
            if calls < 3 {
                anyhow::bail!("transient");
            }
            Ok(99)
        });
        assert_eq!(ok.unwrap(), 99);
        assert_eq!(calls, 3, "succeeded on the third attempt");
        let mut calls = 0;
        let err: Result<u32> = with_retries("op", 2, 0, || {
            calls += 1;
            anyhow::bail!("hard down")
        });
        let msg = format!("{:#}", err.unwrap_err());
        assert_eq!(calls, 3, "1 + retries attempts");
        assert!(msg.contains("after 3 attempt(s)"), "{msg}");
        assert!(msg.contains("hard down"), "{msg}");
    }
}
