//! The snapshot wire codec: little-endian, length-prefixed primitives.
//!
//! Everything the persistence subsystem writes goes through these two
//! types, so the on-disk format has exactly one definition. The codec is
//! deliberately dumb — fixed-width little-endian integers, `u64` length
//! prefixes, raw f32 payloads — because the snapshot's value is in *what*
//! is serialized (a replay-free structural image of the session), not in
//! clever encoding. Corruption is detected by the magic/version header and
//! by per-field sanity limits at the call sites, never by trusting a
//! length prefix to allocate unbounded memory: [`SnapReader::u32s`] and
//! friends cap a single vector at [`MAX_VEC_LEN`] elements.
//!
//! Both endpoints additionally maintain a **running FNV-1a/64 checksum**
//! over every byte they move. A v3 snapshot closes with a checksummed
//! footer ([`SnapWriter::write_footer`] / [`SnapReader::verify_footer`]):
//! footer magic, the payload length, and the payload checksum. The footer
//! turns "parse happened to succeed" into "these are bit-for-bit the
//! bytes that were written" — a truncated or bit-flipped spill file fails
//! the verify cleanly instead of restoring a subtly wrong index.

use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Upper bound on a single length-prefixed vector (1G elements): a
/// corrupted prefix fails loudly instead of attempting a huge allocation.
pub const MAX_VEC_LEN: u64 = 1 << 30;

/// Elements per stack-buffered encode/decode chunk (16 KB of bytes).
const CHUNK_ELEMS: usize = 4096;

/// Footer magic ("RetrievalAttention Snapshot Footer"). Distinct from the
/// header magic so a truncated-at-zero file can never alias a footer.
pub const FOOTER_MAGIC: &[u8; 4] = b"RASF";

/// On-disk footer size: magic + payload length (u64) + checksum (u64).
pub const FOOTER_LEN: u64 = 4 + 8 + 8;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a(mut h: u64, data: &[u8]) -> u64 {
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Byte-counting, checksumming writer over any `io::Write` sink.
pub struct SnapWriter<'a> {
    w: &'a mut dyn Write,
    bytes: u64,
    sum: u64,
}

impl<'a> SnapWriter<'a> {
    pub fn new(w: &'a mut dyn Write) -> SnapWriter<'a> {
        SnapWriter { w, bytes: 0, sum: FNV_OFFSET }
    }

    /// Bytes written so far (the done-event's `snapshot_bytes`).
    pub fn bytes_written(&self) -> u64 {
        self.bytes
    }

    /// Running FNV-1a/64 over every byte written so far.
    pub fn checksum(&self) -> u64 {
        self.sum
    }

    pub fn raw(&mut self, data: &[u8]) -> Result<()> {
        self.w.write_all(data).context("snapshot write")?;
        self.bytes += data.len() as u64;
        self.sum = fnv1a(self.sum, data);
        Ok(())
    }

    /// Close a v3 snapshot: capture (payload length, payload checksum)
    /// and append the footer. Must be the writer's last call — anything
    /// written after it would sit outside the verified region.
    pub fn write_footer(&mut self) -> Result<()> {
        let (len, sum) = (self.bytes, self.sum);
        self.raw(FOOTER_MAGIC)?;
        self.raw(&len.to_le_bytes())?;
        self.raw(&sum.to_le_bytes())
    }

    pub fn u8(&mut self, v: u8) -> Result<()> {
        self.raw(&[v])
    }

    pub fn bool(&mut self, v: bool) -> Result<()> {
        self.u8(v as u8)
    }

    pub fn u32(&mut self, v: u32) -> Result<()> {
        self.raw(&v.to_le_bytes())
    }

    pub fn u64(&mut self, v: u64) -> Result<()> {
        self.raw(&v.to_le_bytes())
    }

    pub fn usize(&mut self, v: usize) -> Result<()> {
        self.u64(v as u64)
    }

    pub fn f32(&mut self, v: f32) -> Result<()> {
        self.raw(&v.to_le_bytes())
    }

    pub fn f64(&mut self, v: f64) -> Result<()> {
        self.raw(&v.to_le_bytes())
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) -> Result<()> {
        self.u64(s.len() as u64)?;
        self.raw(s.as_bytes())
    }

    /// Length-prefixed `u32` vector. Encoded through a fixed stack chunk,
    /// not a full intermediate copy: a 128K-row store payload would
    /// otherwise allocate its own size over again per matrix while
    /// parking, on the serving worker thread.
    pub fn u32s(&mut self, v: &[u32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        let mut buf = [0u8; CHUNK_ELEMS * 4];
        for chunk in v.chunks(CHUNK_ELEMS) {
            let mut n = 0;
            for &x in chunk {
                buf[n..n + 4].copy_from_slice(&x.to_le_bytes());
                n += 4;
            }
            self.raw(&buf[..n])?;
        }
        Ok(())
    }

    /// Length-prefixed byte vector (tombstone bitsets, node levels).
    pub fn bytes(&mut self, v: &[u8]) -> Result<()> {
        self.u64(v.len() as u64)?;
        self.raw(v)
    }

    /// Length-prefixed `f32` vector (chunked like [`SnapWriter::u32s`]).
    pub fn f32s(&mut self, v: &[f32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        let mut buf = [0u8; CHUNK_ELEMS * 4];
        for chunk in v.chunks(CHUNK_ELEMS) {
            let mut n = 0;
            for &x in chunk {
                buf[n..n + 4].copy_from_slice(&x.to_le_bytes());
                n += 4;
            }
            self.raw(&buf[..n])?;
        }
        Ok(())
    }

    /// Row-major matrix: rows, cols, then the f32 payload.
    pub fn matrix(&mut self, m: &Matrix) -> Result<()> {
        self.u64(m.rows() as u64)?;
        self.u64(m.cols() as u64)?;
        self.f32s(m.as_slice())
    }
}

/// Checked, checksumming reader over any `io::Read` source.
pub struct SnapReader<'a> {
    r: &'a mut dyn Read,
    bytes: u64,
    sum: u64,
}

impl<'a> SnapReader<'a> {
    pub fn new(r: &'a mut dyn Read) -> SnapReader<'a> {
        SnapReader { r, bytes: 0, sum: FNV_OFFSET }
    }

    /// Bytes consumed so far.
    pub fn bytes_read(&self) -> u64 {
        self.bytes
    }

    pub fn raw(&mut self, buf: &mut [u8]) -> Result<()> {
        self.r.read_exact(buf).context("snapshot read (truncated?)")?;
        self.bytes += buf.len() as u64;
        self.sum = fnv1a(self.sum, buf);
        Ok(())
    }

    /// Verify a v3 footer against everything read so far. Call exactly
    /// once, after the last payload field: captures (bytes, checksum),
    /// then reads and checks the footer. Any mismatch — missing magic,
    /// length skew (truncation that still parsed), checksum skew (bit
    /// flips) — is a clean `Err`, never a panic.
    pub fn verify_footer(&mut self) -> Result<()> {
        let (len, sum) = (self.bytes, self.sum);
        let mut magic = [0u8; 4];
        self.raw(&mut magic).context("snapshot footer missing (truncated?)")?;
        if &magic != FOOTER_MAGIC {
            bail!("snapshot footer magic mismatch (corrupt or truncated file)");
        }
        let mut b = [0u8; 8];
        self.raw(&mut b)?;
        let want_len = u64::from_le_bytes(b);
        self.raw(&mut b)?;
        let want_sum = u64::from_le_bytes(b);
        if want_len != len {
            bail!("snapshot payload length mismatch: footer says {want_len}, read {len}");
        }
        if want_sum != sum {
            bail!("snapshot checksum mismatch: footer {want_sum:#018x}, computed {sum:#018x}");
        }
        Ok(())
    }

    pub fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.raw(&mut b)?;
        Ok(b[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.raw(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.raw(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn f32(&mut self) -> Result<f32> {
        let mut b = [0u8; 4];
        self.raw(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }

    pub fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.raw(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }

    fn checked_len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        if n > MAX_VEC_LEN {
            bail!("snapshot vector length {n} exceeds sanity bound");
        }
        Ok(n as usize)
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.checked_len()?;
        let mut buf = vec![0u8; n];
        self.raw(&mut buf)?;
        String::from_utf8(buf).context("snapshot string is not UTF-8")
    }

    /// Decoded through a fixed stack chunk: no transient byte buffer the
    /// size of the payload (mirrors [`SnapWriter::u32s`]).
    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.checked_len()?;
        // Capacity capped: a corrupted length should fail on the first
        // short read, not commit a giant allocation up front.
        let mut out = Vec::with_capacity(n.min(1 << 22));
        let mut buf = [0u8; CHUNK_ELEMS * 4];
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(CHUNK_ELEMS);
            self.raw(&mut buf[..take * 4])?;
            out.extend(
                buf[..take * 4]
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])),
            );
            remaining -= take;
        }
        Ok(out)
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.checked_len()?;
        let mut buf = vec![0u8; n];
        self.raw(&mut buf)?;
        Ok(buf)
    }

    /// Decoded through a fixed stack chunk (see [`SnapReader::u32s`]).
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.checked_len()?;
        // Capacity capped: a corrupted length should fail on the first
        // short read, not commit a giant allocation up front.
        let mut out = Vec::with_capacity(n.min(1 << 22));
        let mut buf = [0u8; CHUNK_ELEMS * 4];
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(CHUNK_ELEMS);
            self.raw(&mut buf[..take * 4])?;
            out.extend(
                buf[..take * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
            );
            remaining -= take;
        }
        Ok(out)
    }

    pub fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.usize()?;
        let cols = self.usize()?;
        let data = self.f32s()?;
        // checked_mul: corrupted dims must fail cleanly, not overflow.
        let want = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow::anyhow!("snapshot matrix dims overflow: {rows}x{cols}"))?;
        if data.len() != want {
            bail!("snapshot matrix payload {} != {rows}x{cols}", data.len());
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip() {
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut w = SnapWriter::new(&mut buf);
            w.u8(7).unwrap();
            w.bool(true).unwrap();
            w.u32(0xDEADBEEF).unwrap();
            w.u64(u64::MAX - 3).unwrap();
            w.f32(-1.5).unwrap();
            w.f64(std::f64::consts::PI).unwrap();
            w.str("snapshot").unwrap();
            w.u32s(&[1, 2, 3]).unwrap();
            w.bytes(&[9, 8]).unwrap();
            w.f32s(&[0.25, -0.5]).unwrap();
            w.matrix(&Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])).unwrap();
            assert_eq!(w.bytes_written(), buf.len() as u64);
        }
        let mut src = buf.as_slice();
        let mut r = SnapReader::new(&mut src);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.str().unwrap(), "snapshot");
        assert_eq!(r.u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.bytes().unwrap(), vec![9, 8]);
        assert_eq!(r.f32s().unwrap(), vec![0.25, -0.5]);
        let m = r.matrix().unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn truncated_input_errors() {
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut w = SnapWriter::new(&mut buf);
            w.u32s(&[1, 2, 3, 4]).unwrap();
        }
        buf.truncate(buf.len() - 2);
        let mut src = buf.as_slice();
        let mut r = SnapReader::new(&mut src);
        assert!(r.u32s().is_err());
        // Absurd length prefixes are rejected before allocation.
        let mut bogus: Vec<u8> = Vec::new();
        {
            let mut w = SnapWriter::new(&mut bogus);
            w.u64(u64::MAX).unwrap();
        }
        let mut src = bogus.as_slice();
        let mut r = SnapReader::new(&mut src);
        assert!(r.u32s().is_err());
    }

    fn footered_payload() -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        let mut w = SnapWriter::new(&mut buf);
        w.u32s(&[10, 20, 30]).unwrap();
        w.str("tail").unwrap();
        w.write_footer().unwrap();
        buf
    }

    #[test]
    fn footer_roundtrips_and_detects_corruption() {
        let buf = footered_payload();
        // Clean read: payload fields then a passing verify.
        let mut src = buf.as_slice();
        let mut r = SnapReader::new(&mut src);
        assert_eq!(r.u32s().unwrap(), vec![10, 20, 30]);
        assert_eq!(r.str().unwrap(), "tail");
        r.verify_footer().unwrap();
        assert_eq!(r.bytes_read(), buf.len() as u64);
        // Any single bit flip in the payload fails the checksum (or the
        // parse itself); a flip in the footer fails the footer check.
        for byte in 0..buf.len() {
            let mut evil = buf.clone();
            evil[byte] ^= 0x10;
            let mut src = evil.as_slice();
            let mut r = SnapReader::new(&mut src);
            let verdict = r
                .u32s()
                .and_then(|_| r.str())
                .and_then(|_| r.verify_footer());
            assert!(verdict.is_err(), "bit flip at byte {byte} went undetected");
        }
    }

    #[test]
    fn footer_detects_truncation_at_every_length() {
        let buf = footered_payload();
        for keep in 0..buf.len() {
            let mut src = &buf[..keep];
            let mut r = SnapReader::new(&mut src);
            let verdict = r
                .u32s()
                .and_then(|_| r.str())
                .and_then(|_| r.verify_footer());
            assert!(verdict.is_err(), "truncation to {keep} bytes went undetected");
        }
    }
}
