//! The session persistence subsystem: versioned binary snapshots of a
//! session's host state, and the disk-spilling multi-turn session cache
//! built on top of them.
//!
//! RetrievalAttention's premise is that the KV state worth keeping is too
//! big for the GPU, so it lives in CPU memory behind ANN indexes — but at
//! serving scale CPU RAM is just the next tier to overflow, and a session
//! that cannot outlive its request re-pays the full prefill *and* the full
//! index build on every chat turn. This module is the storage-engine layer
//! (RetroInfer's "the KV cache is a vector storage engine", taken across
//! the request boundary):
//!
//! * [`codec`] — the little-endian snapshot wire codec.
//! * Snapshot format — [`Engine::snapshot_session`] /
//!   [`Engine::restore_session`] (in `model::engine`) write/read a
//!   **replay-free structural image**: maintenance is flushed first so the
//!   image is single-generation — `SegmentedStore` chunks (mirrors rebuilt
//!   deterministically from the quant mode), per-GQA-group dense→absolute
//!   id maps with their store generations, and all four index families
//!   serialized structurally (flat/IVF id+vector lists, HNSW adjacency +
//!   level-draw RNG stream, RoarGraph CSR + patch/extra overlays). A
//!   restored session therefore answers its next decode step with zero
//!   re-prefill and zero index-rebuild work, and its searches are
//!   bit-identical to the source session's.
//! * [`cache`] — the coordinator-level session registry's storage half:
//!   finished sessions stay resident up to `serving.session_cache.
//!   max_resident_bytes`, LRU-park to `spill_dir` through the snapshot
//!   format, resume transparently on the next turn, and reject with
//!   backpressure when `max_disk_bytes` is exhausted.
//! * [`spill`] — the durable spill-tier IO discipline under the cache:
//!   atomic write-temp → fsync → rename publication, quarantine of
//!   corrupt snapshots, boot-time directory scans for restart recovery,
//!   and bounded retry for transient IO (see docs/robustness.md).
//!
//! ## Format version policy
//!
//! Every snapshot opens with [`MAGIC`] + [`VERSION`]. The version bumps on
//! ANY layout change. Readers accept the current version plus a
//! read-compat path for the immediately preceding one ([`V2`] images have
//! no checksummed footer; anything older is refused outright — a parked
//! session from another build re-pays its prefill rather than risk a
//! silently-misparsed index). Family and retriever tags are append-only:
//! tags are never reused or renumbered within a version.
//!
//! v2 added, immediately after the `had_removals` flag: the per-head
//! policy vector ([`save_policy`]), the session's released index bytes,
//! and any in-flight calibration pass. Streaming heads persist in the
//! retriever section as a tag plus two window lengths — their index
//! state does not exist, which is exactly the snapshot-bytes saving.
//!
//! v3 (this version) appends the checksummed footer
//! ([`codec::SnapWriter::write_footer`]): footer magic + payload length +
//! FNV-1a/64 payload checksum. The payload layout is byte-identical to
//! v2 — only the trailer differs — which is what makes the v2 read-compat
//! path free: restore parses the same fields and simply skips the footer
//! verify. The footer is what lets the durable spill tier distinguish "a
//! snapshot this build wrote, bit-for-bit" from "a file that happens to
//! parse", so crash-recovery boot scans can trust what they find.
//!
//! [`Engine::snapshot_session`]: crate::model::Engine::snapshot_session
//! [`Engine::restore_session`]: crate::model::Engine::restore_session

pub mod cache;
pub mod codec;
pub mod spill;

pub use cache::{ResumedSession, SessionCache, SessionCacheStats};

use crate::baselines::GroupShared;
use crate::index::KeyStore;
use crate::kernel::QuantMode;
use anyhow::{bail, Result};
use codec::{SnapReader, SnapWriter};
use std::sync::Arc;

/// Snapshot file magic ("RetrievalAttention Session Snapshot").
pub const MAGIC: &[u8; 4] = b"RASS";

/// Current snapshot format version (see the module-level version policy).
pub const VERSION: u32 = 3;

/// The previous format version, still readable (and writable via
/// [`crate::model::Engine::snapshot_session_versioned`] for the
/// cross-version restore test): v2 has no checksummed footer.
pub const V2: u32 = 2;

fn quant_tag(mode: QuantMode) -> u8 {
    match mode {
        QuantMode::Off => 0,
        QuantMode::Fp16 => 1,
        QuantMode::Int8 => 2,
    }
}

fn quant_from_tag(tag: u8) -> Result<QuantMode> {
    Ok(match tag {
        0 => QuantMode::Off,
        1 => QuantMode::Fp16,
        2 => QuantMode::Int8,
        other => bail!("unknown quant-mode tag {other} in snapshot"),
    })
}

/// Serialize a segmented key store chunk-by-chunk: the restore preserves
/// segment boundaries exactly, and the quantized mirrors are rebuilt
/// deterministically from the mode ([`crate::kernel::QuantChunk::build`]
/// is a pure function of the chunk payload), so the round trip is
/// bit-identical including scan-tier scores.
pub fn save_store(w: &mut SnapWriter<'_>, store: &KeyStore) -> Result<()> {
    w.usize(store.cols())?;
    w.u8(quant_tag(store.quant_mode()))?;
    w.usize(store.segment_count())?;
    for seg in store.segments() {
        w.matrix(seg)?;
    }
    Ok(())
}

/// Inverse of [`save_store`].
pub fn load_store(r: &mut SnapReader<'_>) -> Result<KeyStore> {
    let cols = r.usize()?;
    let quant = quant_from_tag(r.u8()?)?;
    let n_segments = r.usize()?;
    // Capacity capped: a corrupted segment count fails on the first
    // short matrix read instead of committing a giant allocation.
    let mut chunks = Vec::with_capacity(n_segments.min(4096));
    for _ in 0..n_segments {
        chunks.push(r.matrix()?);
    }
    Ok(KeyStore::from_chunks(cols, chunks, quant))
}

/// Serialize one GQA group's shared state: the segmented key store plus
/// the generation-stamped dense→absolute id map. Written once per group
/// (Appendix C's single-copy layout survives the snapshot).
pub fn save_group(w: &mut SnapWriter<'_>, group: &GroupShared) -> Result<()> {
    let store = group.keys();
    let map = group.id_map();
    save_store(w, &store)?;
    w.u64(map.store_gen)?;
    w.u32s(&map.ids)?;
    Ok(())
}

/// Inverse of [`save_group`]: the restored group comes back under the
/// saved store generation, so restored index fronts pair with it exactly.
/// The id map may be LONGER than the store — groups whose heads never
/// read keys (Full / StreamingLLM) grow the map on drains without
/// growing the store — but never shorter (an index over unmapped rows
/// would return unmappable dense ids).
pub fn load_group(r: &mut SnapReader<'_>) -> Result<Arc<GroupShared>> {
    let store = load_store(r)?;
    let store_gen = r.u64()?;
    let ids = r.u32s()?;
    if ids.len() < store.rows() {
        bail!(
            "group snapshot: id map ({}) shorter than store ({} rows)",
            ids.len(),
            store.rows()
        );
    }
    Ok(GroupShared::restore(store, ids, store_gen))
}

/// Per-head policy tags (append-only, like the retriever tags).
const POLICY_RETRIEVAL: u8 = 0;
const POLICY_STREAMING: u8 = 1;

/// Serialize the per-(layer, q_head) policy vector: one tag per head,
/// streaming heads followed by their two window lengths.
pub fn save_policy(w: &mut SnapWriter<'_>, policy: &crate::policy::PolicyMap) -> Result<()> {
    for layer in &policy.heads {
        for p in layer {
            match *p {
                crate::policy::HeadPolicy::Retrieval => w.u8(POLICY_RETRIEVAL)?,
                crate::policy::HeadPolicy::Streaming { sinks, window } => {
                    w.u8(POLICY_STREAMING)?;
                    w.usize(sinks)?;
                    w.usize(window)?;
                }
            }
        }
    }
    Ok(())
}

/// Inverse of [`save_policy`] for a known engine geometry.
pub fn load_policy(
    r: &mut SnapReader<'_>,
    layers: usize,
    q_heads: usize,
) -> Result<crate::policy::PolicyMap> {
    let mut policy = crate::policy::PolicyMap::all_retrieval(layers, q_heads);
    for layer in 0..layers {
        for h in 0..q_heads {
            match r.u8()? {
                POLICY_RETRIEVAL => {}
                POLICY_STREAMING => {
                    let sinks = r.usize()?;
                    let window = r.usize()?;
                    policy.set(
                        layer,
                        h,
                        crate::policy::HeadPolicy::Streaming { sinks, window },
                    );
                }
                other => bail!("unknown head-policy tag {other} in snapshot"),
            }
        }
    }
    Ok(policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn store_roundtrip_preserves_chunks_and_mirrors() {
        let mut rng = Rng::seed_from(3);
        let base = KeyStore::from_matrix(Matrix::from_fn(96, 16, |_, _| rng.normal()));
        let mut store = base.with_quant(QuantMode::Int8);
        for _ in 0..5 {
            store = store.append_rows(Matrix::from_fn(8, 16, |_, _| rng.normal()));
        }
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut w = SnapWriter::new(&mut buf);
            save_store(&mut w, &store).unwrap();
        }
        let mut src = buf.as_slice();
        let mut r = SnapReader::new(&mut src);
        let back = load_store(&mut r).unwrap();
        assert_eq!(back.rows(), store.rows());
        assert_eq!(back.segment_count(), store.segment_count());
        assert_eq!(back.quant_mode(), store.quant_mode());
        assert_eq!(back.mirrored_segments(), store.mirrored_segments());
        let q: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        for i in 0..store.rows() {
            assert_eq!(back.row(i), store.row(i), "row {i} diverged");
            assert_eq!(
                back.score(&q, i).to_bits(),
                store.score(&q, i).to_bits(),
                "scan-tier score {i} diverged"
            );
        }
    }

    #[test]
    fn policy_roundtrip_preserves_mixed_assignment() {
        use crate::policy::{HeadPolicy, PolicyMap};
        let mut policy = PolicyMap::all_retrieval(2, 4);
        policy.set(0, 1, HeadPolicy::Streaming { sinks: 16, window: 64 });
        policy.set(1, 3, HeadPolicy::Streaming { sinks: 8, window: 32 });
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut w = SnapWriter::new(&mut buf);
            save_policy(&mut w, &policy).unwrap();
        }
        let mut src = buf.as_slice();
        let mut r = SnapReader::new(&mut src);
        assert_eq!(load_policy(&mut r, 2, 4).unwrap(), policy);
    }

    #[test]
    fn group_roundtrip_keeps_generation_and_map() {
        let mut rng = Rng::seed_from(9);
        let store = KeyStore::from_matrix(Matrix::from_fn(32, 8, |_, _| rng.normal()));
        let ids: Vec<u32> = (0..32u32).map(|i| i + 640).collect();
        let group = GroupShared::restore(store, ids.clone(), 3);
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut w = SnapWriter::new(&mut buf);
            save_group(&mut w, &group).unwrap();
        }
        let mut src = buf.as_slice();
        let mut r = SnapReader::new(&mut src);
        let back = load_group(&mut r).unwrap();
        assert_eq!(back.store_generation(), 3);
        assert_eq!(back.id_map().ids, ids);
        assert_eq!(back.keys().rows(), 32);
        assert_eq!(back.keys().row(7), group.keys().row(7));
    }
}
