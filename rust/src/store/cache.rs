//! The disk-spilling multi-turn session cache: the storage half of the
//! coordinator's session registry.
//!
//! A finished turn's [`Session`] is inserted here instead of dropped. It
//! stays **resident** (decode-ready, zero resume cost) until the RAM
//! budget (`serving.session_cache.max_resident_bytes`) overflows, at which
//! point the least-recently-used session is **parked**: snapshotted to
//! `spill_dir` through the versioned format and freed from RAM. The next
//! turn resumes it transparently — resident hit, disk restore, or a
//! definitive miss. When parking would exceed `max_disk_bytes` the insert
//! fails with backpressure instead of silently dropping state: the caller
//! rejects the request, exactly like the admission queue rejects past
//! `max_queue`.
//!
//! Lifecycle of a session id through this cache:
//!
//! ```text
//! active (decoding) → resident (RAM, LRU) → parked (disk) → resumed ↺
//!                                   └────────── closed / evicted ──┘
//! ```
//!
//! The spill tier is **durable** (ROADMAP item 4(b)): parks publish
//! atomically (write-temp → fsync → rename, via [`super::spill`]), every
//! snapshot carries the v3 checksummed footer, and construction runs a
//! **boot scan** that re-registers `session-<id>.ras` files already in a
//! configured `spill_dir` — parked sessions survive a crash or deploy. A
//! snapshot that fails restore verification is **quarantined** (renamed
//! `.corrupt`, entry dropped, clean error) — never a panic, and never a
//! silent half-restored session. Transient IO (open/write) retries with
//! bounded backoff (`spill_retries` / `spill_retry_backoff_ms`) before
//! surfacing. Scratch behaviour — delete everything on drop — is the
//! opt-in `ephemeral_spill` knob, and is forced only when `spill_dir` is
//! empty (the per-process temp directory can never be rediscovered).
//!
//! One cache per replica worker: sessions never cross replica boundaries
//! (the router pins a session id to its replica), so no locking is needed
//! — the worker thread owns the whole registry.

use super::spill;
use crate::config::SessionCacheConfig;
use crate::model::{Engine, Session};
use crate::util::sync::{AtomicU64, Ordering};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

/// Cumulative registry counters, surfaced through the done event.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionCacheStats {
    /// Sessions parked to disk (LRU spills).
    pub parks: u64,
    /// Sessions resumed from disk.
    pub resumes: u64,
    /// Total snapshot bytes written across all parks.
    pub park_bytes_total: u64,
    /// Inserts refused because the disk budget was exhausted.
    pub backpressure_rejects: u64,
    /// Parked sessions re-registered by the boot scan (restart recovery).
    pub recovered: u64,
    /// Corrupt snapshots quarantined on a failed restore.
    pub quarantines: u64,
}

struct Resident {
    sess: Session,
    bytes: usize,
    last_used: u64,
}

struct Parked {
    path: PathBuf,
    bytes: u64,
}

/// A session handed back for its next turn.
pub struct ResumedSession {
    pub sess: Session,
    /// True when the session was parked and came back through a snapshot.
    pub from_disk: bool,
    /// Wall-clock of the disk restore (0 for resident hits).
    pub resume_s: f64,
    /// On-disk snapshot size the session was restored from (0 for
    /// resident hits).
    pub snapshot_bytes: u64,
}

/// The per-replica session registry storage (see module docs).
pub struct SessionCache {
    cfg: SessionCacheConfig,
    spill_dir: PathBuf,
    /// Delete parked snapshots (and the dir) on drop. Forced on for the
    /// per-process default dir; the knob for configured dirs.
    ephemeral: bool,
    resident: HashMap<u64, Resident>,
    parked: HashMap<u64, Parked>,
    disk_bytes: u64,
    clock: u64,
    pub stats: SessionCacheStats,
}

impl SessionCache {
    pub fn new(cfg: SessionCacheConfig) -> SessionCache {
        let (spill_dir, ephemeral) = if cfg.spill_dir.is_empty() {
            // Per-instance default: two replicas of one process must not
            // collide on `session-<id>.ras` names (the router pins ids to
            // replicas, but nothing forces distinct configured dirs).
            // Relaxed (allowlisted counter): only uniqueness matters.
            // Always ephemeral: no future boot could ever find this dir.
            static SEQ: AtomicU64 = AtomicU64::new(0);
            let seq = SEQ.fetch_add(1, Ordering::Relaxed);
            let dir =
                std::env::temp_dir().join(format!("ra-sessions-{}-{seq}", std::process::id()));
            (dir, true)
        } else {
            (PathBuf::from(&cfg.spill_dir), cfg.ephemeral_spill)
        };
        let mut cache = SessionCache {
            cfg,
            spill_dir,
            ephemeral,
            resident: HashMap::new(),
            parked: HashMap::new(),
            disk_bytes: 0,
            clock: 0,
            stats: SessionCacheStats::default(),
        };
        cache.boot_scan();
        cache
    }

    /// Restart recovery: re-register parked snapshots already present in
    /// the spill dir (a previous process parked them, then crashed or
    /// deployed away). Registration is by name and size only — the
    /// snapshot's integrity is proven by its checksummed footer on the
    /// resume path, where a bad file is quarantined instead of trusted.
    /// Orphaned `.tmp` files (a crash mid-publish) are deleted by the
    /// scan; quarantined `.corrupt` files are left untouched.
    fn boot_scan(&mut self) {
        let scanned = spill::scan_dir(&self.spill_dir).unwrap_or_default();
        for s in scanned {
            self.disk_bytes += s.bytes;
            self.parked.insert(s.id, Parked { path: s.path, bytes: s.bytes });
            self.stats.recovered += 1;
        }
        if self.stats.recovered > 0 {
            crate::telemetry::registry()
                .counter("store.sessions_recovered_total")
                .add(self.stats.recovered);
        }
    }

    /// Where this cache parks sessions (resolved once at construction;
    /// a respawned replica worker re-opens the same directory).
    pub fn spill_dir(&self) -> &std::path::Path {
        &self.spill_dir
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    /// Bytes currently parked on disk.
    pub fn disk_bytes(&self) -> u64 {
        self.disk_bytes
    }

    /// Whether a session id is known (resident or parked).
    pub fn contains(&self, id: u64) -> bool {
        self.resident.contains_key(&id) || self.parked.contains_key(&id)
    }

    fn resident_bytes(&self) -> usize {
        self.resident.values().map(|e| e.bytes).sum()
    }

    /// Retain a finished turn's session for the next one, then LRU-park
    /// anything past the RAM budget. A re-inserted id supersedes its
    /// previous state (the turn that just finished IS the session now).
    /// Errors mean backpressure: the disk budget is exhausted and the
    /// registry refused to grow — the caller should reject the request.
    /// On error the NEW session is dropped (it was never promised to the
    /// client — its request fails) so the resident set cannot creep past
    /// the budget one rejected session at a time; previously-retained
    /// sessions are never sacrificed to admit a new one.
    pub fn insert(&mut self, engine: &Engine, id: u64, sess: Session) -> Result<()> {
        self.drop_parked(id);
        self.clock += 1;
        let bytes = sess.state_bytes();
        self.resident.insert(id, Resident { sess, bytes, last_used: self.clock });
        let spilled = self.spill_over_budget(engine);
        if spilled.is_err() {
            self.resident.remove(&id);
        }
        // The failing park names its victim: "insert rejected" alone is
        // useless when the session that overflowed the budget is not the
        // one that was being parked.
        spilled.with_context(|| format!("insert of session {id} forced a failing spill"))
    }

    fn spill_over_budget(&mut self, engine: &Engine) -> Result<()> {
        while self.resident_bytes() > self.cfg.max_resident_bytes {
            let victim = self.resident.iter().min_by_key(|(_, e)| e.last_used).map(|(&id, _)| id);
            // An empty resident set has zero resident_bytes, so a missing
            // victim means the loop condition is about to go false anyway.
            let Some(victim) = victim else { break };
            self.park(engine, victim)
                .with_context(|| format!("parking LRU victim session {victim}"))?;
        }
        Ok(())
    }

    /// Park one resident session to disk via the snapshot format, through
    /// the atomic spill-publication path (temp → fsync → rename): a crash
    /// at any point leaves either the complete snapshot or nothing, and a
    /// failed write leaves no temp litter. Transient IO errors retry with
    /// bounded backoff before the park fails; a failed park puts the
    /// session back resident — a park must never lose state.
    fn park(&mut self, engine: &Engine, id: u64) -> Result<u64> {
        let mut entry = self.resident.remove(&id).context("park: unknown session")?;
        // Estimate-based pre-check: when the budget is already exhausted,
        // reject before serializing anything — a full snapshot write that
        // is then deleted would transiently overshoot the disk budget (the
        // very thing it bounds) and repeat that waste on every later turn.
        // `state_bytes` tracks the snapshot size to within its index/KV
        // accounting, so the exact post-write check below rarely fires.
        if self.disk_bytes.saturating_add(entry.bytes as u64) > self.cfg.max_disk_bytes as u64 {
            let est = entry.bytes;
            self.resident.insert(id, entry);
            self.stats.backpressure_rejects += 1;
            crate::telemetry::registry().counter("store.backpressure_rejects_total").inc();
            bail!(
                "session cache disk budget exhausted (backpressure): {} + ~{est} > {} bytes",
                self.disk_bytes,
                self.cfg.max_disk_bytes
            );
        }
        let written = spill::ensure_dir(&self.spill_dir).and_then(|()| {
            spill::with_retries(
                "park session snapshot",
                self.cfg.spill_retries,
                self.cfg.spill_retry_backoff_ms,
                || {
                    spill::write_atomic(&self.spill_dir, id, |w| {
                        engine.snapshot_session(&mut entry.sess, w)
                    })
                },
            )
        });
        // A failed write (disk genuinely full, I/O error) must never lose
        // the session: put it back resident and surface the error.
        let (path, bytes) = match written {
            Ok(pb) => pb,
            Err(e) => {
                self.resident.insert(id, entry);
                self.stats.backpressure_rejects += 1;
                crate::telemetry::registry().counter("store.backpressure_rejects_total").inc();
                return Err(e);
            }
        };
        if self.disk_bytes.saturating_add(bytes) > self.cfg.max_disk_bytes as u64 {
            // Backpressure: undo the write, keep the session resident, and
            // surface the rejection — never silently lose session state.
            spill::remove(&path);
            self.resident.insert(id, entry);
            self.stats.backpressure_rejects += 1;
            crate::telemetry::registry().counter("store.backpressure_rejects_total").inc();
            bail!(
                "session cache disk budget exhausted (backpressure): {} + {bytes} > {} bytes",
                self.disk_bytes,
                self.cfg.max_disk_bytes
            );
        }
        self.parked.insert(id, Parked { path, bytes });
        self.disk_bytes += bytes;
        self.stats.parks += 1;
        self.stats.park_bytes_total += bytes;
        let reg = crate::telemetry::registry();
        reg.counter("store.parks_total").inc();
        reg.counter("store.park_bytes_total").add(bytes);
        Ok(bytes)
    }

    /// Hand a session back for its next turn: resident hit (free), disk
    /// resume (snapshot restore, no re-prefill, no index rebuild), or
    /// `None` for an unknown id.
    ///
    /// Disk-path failure semantics: an **open** failure is treated as
    /// transient — retried with backoff, and on final failure the parked
    /// entry stays registered (its snapshot is intact; the caller can
    /// retry the turn). A failure **inside the restore** — bad magic,
    /// refused version, parse error, checksum/footer mismatch — is
    /// corruption: the file is quarantined (`.corrupt`, bytes preserved
    /// for diagnosis), the entry is dropped, and a clean error surfaces.
    /// The caller fails the one request; the replica keeps serving.
    pub fn take(&mut self, engine: &Engine, id: u64) -> Result<Option<ResumedSession>> {
        self.clock += 1;
        if let Some(e) = self.resident.remove(&id) {
            return Ok(Some(ResumedSession {
                sess: e.sess,
                from_disk: false,
                resume_s: 0.0,
                snapshot_bytes: 0,
            }));
        }
        let Some(p) = self.parked.get(&id) else {
            return Ok(None);
        };
        let (path, bytes) = (p.path.clone(), p.bytes);
        // Transient-shaped injection point for the whole resume step.
        crate::util::failpoint::trigger("session.restore")?;
        let t = Instant::now();
        let file = spill::with_retries(
            "open parked snapshot",
            self.cfg.spill_retries,
            self.cfg.spill_retry_backoff_ms,
            || spill::open_for_read(&path),
        )?;
        let mut buf = std::io::BufReader::new(file);
        let sess = match engine.restore_session(&mut buf) {
            Ok(sess) => sess,
            Err(e) => {
                let q = spill::quarantine(&path);
                self.parked.remove(&id);
                self.disk_bytes = self.disk_bytes.saturating_sub(bytes);
                self.stats.quarantines += 1;
                crate::telemetry::registry()
                    .counter("store.snapshots_quarantined_total")
                    .inc();
                crate::telemetry::flightrec(
                    "quarantine",
                    format!("session {id} snapshot failed restore; moved to {}", q.display()),
                );
                return Err(e.context(format!(
                    "session {id} snapshot failed restore; quarantined at {}",
                    q.display()
                )));
            }
        };
        self.parked.remove(&id);
        spill::remove(&path);
        self.disk_bytes = self.disk_bytes.saturating_sub(bytes);
        self.stats.resumes += 1;
        crate::telemetry::registry().counter("store.resumes_total").inc();
        Ok(Some(ResumedSession {
            sess,
            from_disk: true,
            resume_s: t.elapsed().as_secs_f64(),
            snapshot_bytes: bytes,
        }))
    }

    /// Close a session (the explicit `close` verb): drop it from RAM and
    /// disk. Returns whether the id was known.
    pub fn close(&mut self, id: u64) -> bool {
        let was_resident = self.resident.remove(&id).is_some();
        let was_parked = self.drop_parked(id);
        was_resident || was_parked
    }

    fn drop_parked(&mut self, id: u64) -> bool {
        if let Some(p) = self.parked.remove(&id) {
            spill::remove(&p.path);
            self.disk_bytes = self.disk_bytes.saturating_sub(p.bytes);
            true
        } else {
            false
        }
    }
}

impl Drop for SessionCache {
    fn drop(&mut self) {
        // Durable tier (the default for a configured spill_dir): parked
        // snapshots OUTLIVE this process — the next boot's scan
        // re-registers them. Only the opt-in ephemeral mode (and the
        // per-process temp default, which no boot could rediscover)
        // cleans up after itself.
        if !self.ephemeral {
            return;
        }
        let ids: Vec<u64> = self.parked.keys().copied().collect();
        for id in ids {
            self.drop_parked(id);
        }
        spill::remove_dir(&self.spill_dir);
    }
}
