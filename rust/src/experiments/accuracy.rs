//! Accuracy experiments: Tables 2/3/9/10/11, Figures 5/8.
//!
//! All run the induction model end-to-end through the serving engine; a
//! method's score is a pure function of whether decode-time retrieval
//! reaches the critical tokens (DESIGN.md §2). Context lengths are scaled
//! from the paper's 128K by the factor printed in each report.

use super::harness::*;
use super::ExpCtx;
use crate::attention::budget::BudgetPolicy;
use crate::config::Method;
use crate::index::{roargraph::{RoarGraph, RoarParams}, SearchParams, VectorIndex};
use crate::model::Engine;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::workload::{geometry, needle, tasks, Sample};
use anyhow::Result;

fn ctx_len(ctx: &ExpCtx) -> usize {
    if ctx.full {
        8192
    } else {
        2048
    }
}

fn n_samples(ctx: &ExpCtx) -> usize {
    if ctx.full {
        20
    } else {
        6
    }
}

/// Table 2: ∞-Bench-style tasks × methods.
pub fn table2(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "table2",
        "∞-Bench-style accuracy (induction model; paper Table 2)",
        ctx,
    );
    let len = ctx_len(ctx);
    let ns = n_samples(ctx);
    rep.para(&format!(
        "Context {len} tokens (paper: 128K; substitution per DESIGN.md §2). \
         Tasks are structural analogues: Retr.N/P/KV are exact reproductions \
         of the retrieval structure; Code.D/Math.F/En.QA/En.MC are \
         local-information analogues (methods barely separate on them in \
         the paper too)."
    ));
    let engine = Engine::from_config(accuracy_config(ctx, Method::Full))?;
    let mut rng = Rng::seed_from(ctx.seed);

    // Task name -> samples.
    let task_list: Vec<(&str, Vec<Sample>)> = vec![
        (
            "Retr.N",
            (0..ns)
                .map(|_| {
                    let d = rng_depth(&mut rng);
                    tasks::number(&mut rng, len, d, 4)
                })
                .collect(),
        ),
        (
            "Retr.P",
            (0..ns)
                .map(|_| {
                    let d = rng_depth(&mut rng);
                    tasks::passkey(&mut rng, len, d)
                })
                .collect(),
        ),
        ("Retr.KV", (0..ns).map(|_| tasks::kv_retrieval(&mut rng, len, len / 16)).collect()),
        ("Code.D", (0..ns).map(|_| tasks::realistic_analogue(&mut rng, len, 0.8)).collect()),
        ("Math.F", (0..ns).map(|_| tasks::realistic_analogue(&mut rng, len, 0.8)).collect()),
        ("En.QA", (0..ns).map(|_| tasks::realistic_analogue(&mut rng, len, 0.5)).collect()),
        ("En.MC", (0..ns).map(|_| tasks::realistic_analogue(&mut rng, len, 0.8)).collect()),
    ];

    // Prefill once per sample; evaluate every method on the same bases.
    let mut bases_per_task = Vec::new();
    for (name, samples) in task_list {
        bases_per_task.push((name, prefill_bases(&engine, samples)?));
    }

    let mut rows = Vec::new();
    let mut summary = Value::obj();
    for &method in TABLE2_METHODS {
        let mut row = vec![method.label().to_string()];
        let mut avg = 0.0f32;
        for (_, bases) in &bases_per_task {
            let (score, _) = eval_method(&engine, bases, method)?;
            row.push(fmt_pct(score));
            avg += score;
        }
        let avg = avg / bases_per_task.len() as f32;
        row.push(fmt_pct(avg));
        summary.set(method.label(), avg as f64);
        rows.push(row);
    }
    let mut header = vec!["Method"];
    header.extend(bases_per_task.iter().map(|(n, _)| *n));
    header.push("Avg.");
    rep.table(&header, &rows);
    rep.para(
        "Paper-shape checks: StreamingLLM collapses on Retr.* (static \
         window misses the needle); SnapKV/InfLLM/Quest lose Retr.KV \
         (static or block-granular); Flat/IVF/RetrievalAttention track \
         FullAttention.",
    );
    rep.write_json(ctx, &summary)?;
    rep.write(ctx)
}

fn rng_depth(rng: &mut Rng) -> f32 {
    0.05 + 0.9 * rng.f32()
}

/// Table 3: RULER-style average accuracy vs context length.
pub fn table3(ctx: &ExpCtx) -> Result<()> {
    let mut rep =
        Report::new("table3", "RULER-style accuracy vs context length (paper Table 3)", ctx);
    let lengths: Vec<usize> =
        if ctx.full { vec![1024, 2048, 4096, 8192] } else { vec![768, 1536, 3072] };
    let ns = if ctx.full { 8 } else { 4 };
    rep.para(&format!(
        "Lengths {:?} (paper: 4K–128K; scale factor ≈ 1/16 per DESIGN.md §2). \
         Score = mean over the RULER task family (S1–S3, M1, MQ, VT).",
        lengths
    ));
    let engine = Engine::from_config(accuracy_config(ctx, Method::Full))?;
    let methods = [
        Method::Full,
        Method::StreamingLlm,
        Method::SnapKv,
        Method::InfLlm,
        Method::Flat,
        Method::Ivf,
        Method::RetrievalAttention,
    ];

    let mut per_method: Vec<Vec<f32>> = vec![Vec::new(); methods.len()];
    for &len in &lengths {
        let mut rng = Rng::seed_from(ctx.seed ^ len as u64);
        let mut samples = Vec::new();
        for i in 0..ns {
            let d = rng_depth(&mut rng);
            samples.push(match i % 6 {
                0 => tasks::ruler_single(&mut rng, len, 1, d),
                1 => tasks::ruler_single(&mut rng, len, 2, d),
                2 => tasks::ruler_single(&mut rng, len, 3, d),
                3 => tasks::ruler_multi(&mut rng, len, 4),
                4 => tasks::ruler_variable_tracking(&mut rng, len, 2),
                _ => tasks::kv_retrieval(&mut rng, len, len / 32),
            });
        }
        let bases = prefill_bases(&engine, samples)?;
        for (mi, &m) in methods.iter().enumerate() {
            let (score, _) = eval_method(&engine, &bases, m)?;
            per_method[mi].push(score);
        }
    }
    let mut rows = Vec::new();
    for (mi, &m) in methods.iter().enumerate() {
        let mut row = vec![m.label().to_string()];
        row.extend(per_method[mi].iter().map(|&s| fmt_pct(s)));
        let avg: f32 = per_method[mi].iter().sum::<f32>() / lengths.len() as f32;
        row.push(fmt_pct(avg));
        rows.push(row);
    }
    let mut header: Vec<String> = vec!["Method".into()];
    header.extend(lengths.iter().map(|l| format!("{l}")));
    header.push("Avg.".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    rep.table(&header_refs, &rows);
    rep.write(ctx)
}

/// Fig 5 (and Fig 7's per-method grids): needle-in-a-haystack.
pub fn fig5(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new("fig5", "Needle-in-a-haystack grids (paper Fig 5/7)", ctx);
    let lengths: Vec<usize> =
        if ctx.full { vec![1024, 2048, 4096, 8192] } else { vec![768, 1536, 3072] };
    let depths = if ctx.full { 7 } else { 5 };
    let reps = if ctx.full { 3 } else { 1 };
    let engine = Engine::from_config(accuracy_config(ctx, Method::Full))?;
    let cells = needle::grid(ctx.seed, &lengths, depths, reps);

    // Prefill each cell's samples once.
    let mut bases = Vec::new();
    for c in &cells {
        bases.push(prefill_bases(&engine, c.samples.clone())?);
    }
    for method in [Method::RetrievalAttention, Method::StreamingLlm, Method::Flat] {
        let mut scores = Vec::with_capacity(cells.len());
        for b in &bases {
            let (score, _) = eval_method(&engine, b, method)?;
            scores.push(score / 100.0);
        }
        rep.para(&format!("**{}**", method.label()));
        rep.code_block(&needle::render(&cells, &scores));
    }
    rep.para(
        "Paper shape: RetrievalAttention passes at every depth/length; \
         StreamingLLM passes only where the needle falls inside its static \
         pattern (bottom rows = depth ~100%).",
    );
    rep.write(ctx)
}

/// Fig 8: 250K–1M needle, index level.
///
/// Running the engine at 1M tokens is memory-prohibitive here, but the
/// pass/fail mechanism at those lengths is purely whether the index
/// retrieves the needle key — measured directly on synthetic geometry
/// with a planted needle.
pub fn fig8(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new("fig8", "Extreme-length needle, index level (paper Fig 8)", ctx);
    let lengths: Vec<usize> = if ctx.full {
        vec![250_000, 500_000, 750_000, 1_000_000]
    } else {
        vec![100_000, 250_000]
    };
    let depths = [0.1f32, 0.5, 0.9];
    let mut rows = Vec::new();
    for &n in &lengths {
        let mut row = vec![format!("{}K", n / 1000)];
        for &depth in &depths {
            let g = geometry::generate(
                &geometry::GeometryParams::default(),
                n,
                512,
                ctx.seed ^ n as u64,
            );
            // Plant a needle key strongly matched by a fresh query.
            let mut keys = g.keys;
            let at = ((n as f32) * depth) as usize;
            let mut rng = Rng::seed_from(ctx.seed ^ (n + at) as u64);
            let q: Vec<f32> = (0..keys.cols()).map(|_| rng.normal()).collect();
            let strong: Vec<f32> = q.iter().map(|&v| v * 3.0).collect();
            keys.row_mut(at).copy_from_slice(&strong);
            let keys = std::sync::Arc::new(keys);
            let index = RoarGraph::build(
                keys.clone(),
                &g.queries,
                RoarParams { kb: 32, m: 32, repair_sample: 256, ..RoarParams::default() },
            );
            let r = index.search(&q, 100, &SearchParams { ef: 128, nprobe: 0 });
            let hit = r.ids.contains(&(at as u32));
            row.push(if hit { "pass".into() } else { "FAIL".into() });
        }
        rows.push(row);
    }
    rep.table(&["Length", "depth 10%", "depth 50%", "depth 90%"], &rows);
    rep.para("Paper shape: all cells pass up to 1M (Fig 8).");
    rep.write(ctx)
}

/// Table 9: RULER per-task at the longest context, extra baselines.
pub fn table9(ctx: &ExpCtx) -> Result<()> {
    let mut rep =
        Report::new("table9", "RULER per-task, extra baselines (paper Table 9)", ctx);
    let len = ctx_len(ctx);
    let ns = if ctx.full { 8 } else { 3 };
    let engine = Engine::from_config(accuracy_config(ctx, Method::Full))?;
    let mut rng = Rng::seed_from(ctx.seed ^ 9);

    let task_list: Vec<(&str, Vec<Sample>)> = vec![
        (
            "S1",
            (0..ns)
                .map(|_| {
                    let d = rng_depth(&mut rng);
                    tasks::ruler_single(&mut rng, len, 1, d)
                })
                .collect(),
        ),
        (
            "S2",
            (0..ns)
                .map(|_| {
                    let d = rng_depth(&mut rng);
                    tasks::ruler_single(&mut rng, len, 2, d)
                })
                .collect(),
        ),
        (
            "S3",
            (0..ns)
                .map(|_| {
                    let d = rng_depth(&mut rng);
                    tasks::ruler_single(&mut rng, len, 3, d)
                })
                .collect(),
        ),
        ("M1", (0..ns).map(|_| tasks::ruler_multi(&mut rng, len, 4)).collect()),
        ("MQ", tasks::ruler_multi_query(&mut rng, len, ns)),
        ("MV", (0..ns).map(|_| tasks::ruler_multi_value(&mut rng, len, 3)).collect()),
        ("VT", (0..ns).map(|_| tasks::ruler_variable_tracking(&mut rng, len, 2)).collect()),
        ("CW", (0..ns).map(|_| tasks::ruler_aggregation(&mut rng, len)).collect()),
        ("KV", (0..ns).map(|_| tasks::kv_retrieval(&mut rng, len, len / 16)).collect()),
    ];
    let mut bases_per_task = Vec::new();
    for (name, samples) in task_list {
        bases_per_task.push((name, prefill_bases(&engine, samples)?));
    }
    let methods = [Method::Full, Method::InfiniGen, Method::Quest, Method::RetrievalAttention];
    let mut rows = Vec::new();
    for &m in &methods {
        let mut row = vec![m.label().to_string()];
        let mut avg = 0.0;
        for (_, bases) in &bases_per_task {
            let (score, _) = eval_method(&engine, bases, m)?;
            row.push(fmt_pct(score));
            avg += score;
        }
        row.push(fmt_pct(avg / bases_per_task.len() as f32));
        rows.push(row);
    }
    let mut header = vec!["Method"];
    header.extend(bases_per_task.iter().map(|(n, _)| *n));
    header.push("Avg.");
    rep.table(&header, &rows);
    rep.para(
        "Paper shape: Quest/InfiniGen drop hard on multi-needle and KV \
         tasks; ours stays near full attention. CW is ~0 for everyone \
         (aggregation is not retrieval-shaped; paper Table 9 shows ~1%).",
    );
    rep.write(ctx)
}

/// Table 10: uniform vs PyramidKV-style per-layer budget.
pub fn table10(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new("table10", "Per-layer retrieval budget (paper Table 10)", ctx);
    let len = ctx_len(ctx);
    let ns = n_samples(ctx);
    let engine = Engine::from_config(accuracy_config(ctx, Method::Full))?;
    let mut rng = Rng::seed_from(ctx.seed ^ 10);
    let samples: Vec<Sample> =
        (0..ns).map(|_| tasks::kv_retrieval(&mut rng, len, len / 16)).collect();
    let bases = prefill_bases(&engine, samples)?;

    let mut rows = Vec::new();
    for (label, budget) in [
        ("Uniform k=32", BudgetPolicy::Uniform { k: 32 }),
        ("PyramidKV beta=3", BudgetPolicy::Pyramid { k: 32, beta: 3.0 }),
    ] {
        let mut cfg = accuracy_config(ctx, Method::RetrievalAttention);
        cfg.retrieval.budget = budget;
        let eng2 = Engine::from_config(cfg)?;
        let (score, _) = eval_method(&eng2, &bases, Method::RetrievalAttention)?;
        rows.push(vec![label.to_string(), fmt_pct(score)]);
    }
    let (full_score, _) = eval_method(&engine, &bases, Method::Full)?;
    rows.insert(0, vec!["FullAttention".into(), fmt_pct(full_score)]);
    rep.table(&["Budget policy", "Retr.KV"], &rows);
    rep.para("Paper shape: pyramid is within noise of uniform (Tab 10: 16.0 vs 14.5 on Retr.KV).");
    rep.write(ctx)
}

/// Table 11: deeper-model proxy — accuracy on KV retrieval + decode latency.
pub fn table11(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "table11",
        "Deep-model proxy (paper Table 11, Llama-3-70B)",
        ctx,
    );
    rep.para(
        "Substitution: accuracy uses the induction model at 2x context; \
         latency uses the deeper yi9-mini preset (6 layers) with synthetic \
         128K-scaled geometry — the 70B original is unavailable (DESIGN.md §2).",
    );
    let len = 2 * ctx_len(ctx);
    let ns = if ctx.full { 10 } else { 4 };
    let engine = Engine::from_config(accuracy_config(ctx, Method::Full))?;
    let mut rng = Rng::seed_from(ctx.seed ^ 11);
    let samples: Vec<Sample> =
        (0..ns).map(|_| tasks::kv_retrieval(&mut rng, len, len / 16)).collect();
    let bases = prefill_bases(&engine, samples)?;
    let methods = [
        Method::Full,
        Method::StreamingLlm,
        Method::Quest,
        Method::Flat,
        Method::RetrievalAttention,
    ];
    let ctx_len = if ctx.full { 32768 } else { 8192 };
    let lat = super::latency::method_latencies(ctx, "yi9-mini", ctx_len, &methods)?;
    let mut rows = Vec::new();
    for (i, &m) in methods.iter().enumerate() {
        let (score, _) = eval_method(&engine, &bases, m)?;
        rows.push(vec![m.label().to_string(), fmt_pct(score), fmt_s(lat[i])]);
    }
    rep.table(&["Method", "KV-retrieval acc", "Decode latency (s)"], &rows);
    rep.para(
        "Paper shape: ours ≈ Flat accuracy at a fraction of its latency; \
         Quest far below; StreamingLLM at zero.",
    );
    rep.write(ctx)
}
