//! Fig 2: dynamic sparsity profiling.

use super::harness::*;
use super::ExpCtx;
use crate::attention::sparsity::profile_head;
use crate::workload::geometry::{self, GeometryParams};
use anyhow::Result;

/// Fig 2: recovery ratio of top-k critical tokens per head; dynamic
/// (per-query top-k) vs static (first query's top-k reused).
pub fn fig2(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "fig2",
        "Dynamic sparsity: top-k recovery ratio per head (paper Fig 2)",
        ctx,
    );
    let n = if ctx.full { 100_000 } else { 20_000 };
    let k = if ctx.full { 1000 } else { 200 };
    let decode_steps = 20;
    let heads = if ctx.full { 32 } else { 12 };
    rep.para(&format!(
        "{n} keys per head, top-{k}, {decode_steps} consecutive decode \
         queries, {heads} synthetic heads (paper: 100K tokens, top-1000, \
         20 decode steps, all layers/heads of Llama-3-8B)."
    ));

    let profiles: Vec<(f32, f32)> = crate::util::parallel::par_map_range(heads, |h| {
        // Vary sharpness across "heads" like real layers do.
        let drift = 0.90 + 0.08 * (h as f32 / heads as f32);
        let g = geometry::generate(
            &GeometryParams { drift, ..Default::default() },
            n,
            decode_steps,
            ctx.seed ^ h as u64,
        );
        // Scale 0.35: the synthetic geometry's logit spread at 1/sqrt(64)
        // under-concentrates relative to real trained attention; 0.35
        // calibrates the top-1% recovery into the regime the paper
        // observes (~0.9 dynamic). The dynamic-vs-static *gap* — the
        // actual Fig 2 claim — is scale-robust (asserted below).
        let prof = profile_head(&g.queries, &g.keys, k, 0.35);
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        (mean(&prof.dynamic), mean(&prof.static_first))
    });

    let mut rows = Vec::new();
    for (h, (dyn_r, stat_r)) in profiles.iter().enumerate() {
        rows.push(vec![
            format!("head {h}"),
            format!("{:.3}", dyn_r),
            format!("{:.3}", stat_r),
        ]);
    }
    let mean_dyn: f32 = profiles.iter().map(|p| p.0).sum::<f32>() / heads as f32;
    let mean_stat: f32 = profiles.iter().map(|p| p.1).sum::<f32>() / heads as f32;
    rows.push(vec!["**mean**".into(), format!("**{mean_dyn:.3}**"), format!("**{mean_stat:.3}**")]);
    rep.table(&["Head", "Dynamic top-k recovery", "Static (first-query) recovery"], &rows);
    rep.para(&format!(
        "Paper shape (Fig 2): dynamic ≈0.89 vs static ≈0.71 — measured \
         here: {mean_dyn:.2} vs {mean_stat:.2}. Dynamic ≥ static always \
         (proved in attention::sparsity tests); the gap is the motivation \
         for per-query retrieval."
    ));
    anyhow::ensure!(mean_dyn > mean_stat, "dynamic must beat static");
    rep.write(ctx)
}
