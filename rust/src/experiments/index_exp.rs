//! Index-level experiments: Fig 3a, Fig 3b, Fig 6.

use super::harness::*;
use super::ExpCtx;
use crate::attention::ood::measure_ood;
use crate::index::{
    exact_topk, flat::FlatIndex, hnsw::{HnswIndex, HnswParams}, ivf::IvfIndex,
    roargraph::{RoarGraph, RoarParams}, SearchParams, VectorIndex,
};
use crate::tensor::Matrix;
use crate::workload::geometry::{self, GeometryParams};
use anyhow::Result;
use std::sync::Arc;

/// Sweep an index over a knob and report (scan fraction, recall@100).
fn sweep(
    index: &dyn VectorIndex,
    queries: &Matrix,
    truths: &[Vec<u32>],
    params_list: &[SearchParams],
) -> Vec<(f64, f64)> {
    params_list
        .iter()
        .map(|p| {
            let mut recall = 0.0f64;
            let mut scanned = 0usize;
            for (qi, truth) in truths.iter().enumerate() {
                let r = index.search(queries.row(qi), truth.len(), p);
                recall += r.recall_against(truth) as f64;
                scanned += r.scanned;
            }
            let nq = truths.len();
            (scanned as f64 / (nq * index.len()) as f64, recall / nq as f64)
        })
        .collect()
}

fn truths_for(keys: &Matrix, queries: &Matrix, k: usize) -> Vec<Vec<u32>> {
    crate::util::parallel::par_map_range(queries.rows(), |qi| exact_topk(keys, queries.row(qi), k))
}

/// Fig 3a: Q→K vs K→K recall-vs-scan for conventional indexes.
pub fn fig3a(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "fig3a",
        "Recall vs scan fraction: Q→K vs K→K, IVF & HNSW (paper Fig 3a)",
        ctx,
    );
    let n = if ctx.full { 131_072 } else { 16_384 };
    let nq = 64;
    rep.para(&format!("{n} keys per geometry (paper: 128K from Yi-9B / Llama-3-8B dumps)."));

    let mut rows = Vec::new();
    for (gname, seed) in [("llama3-geom", 1u64), ("yi9-geom", 2u64)] {
        let g = geometry::generate(&GeometryParams::default(), n + nq, 256, ctx.seed ^ seed);
        let keys = Arc::new(Matrix::from_fn(n, 64, |r, c| g.keys[(r, c)]));
        // K→K queries: held-out keys. Q→K queries: real query vectors.
        let kq = Matrix::from_fn(nq, 64, |r, c| g.keys[(n + r, c)]);
        let qq = Matrix::from_fn(nq, 64, |r, c| g.queries[(r, c)]);

        let ivf = IvfIndex::build(keys.clone(), None, ctx.seed);
        let hnsw = HnswIndex::build(keys.clone(), HnswParams::default());
        let nlist = ivf.nlist();
        let ivf_sweep: Vec<SearchParams> = [1usize, 4, 16, 64, 256, nlist]
            .iter()
            .map(|&p| SearchParams { ef: 0, nprobe: p.min(nlist) })
            .collect();
        let hnsw_sweep: Vec<SearchParams> =
            [16usize, 64, 256, 1024].iter().map(|&e| SearchParams { ef: e, nprobe: 0 }).collect();

        for (dir, queries) in [("Q->K", &qq), ("K->K", &kq)] {
            let truths = truths_for(&keys, queries, 100);
            for (idx_name, curve) in [
                ("IVF", sweep(&ivf, queries, &truths, &ivf_sweep)),
                ("HNSW", sweep(&hnsw, queries, &truths, &hnsw_sweep)),
            ] {
                for (frac, recall) in curve {
                    rows.push(vec![
                        gname.to_string(),
                        idx_name.to_string(),
                        dir.to_string(),
                        format!("{:.4}", frac),
                        format!("{:.3}", recall),
                    ]);
                }
            }
        }
    }
    rep.table(&["Geometry", "Index", "Direction", "Scan fraction", "Recall@100"], &rows);
    rep.para(
        "Paper shape (Fig 3a): K→K reaches recall ≥0.95 scanning 1–5%; \
         Q→K needs 30–50% for IVF and HNSW plateaus below 0.95 (local \
         optima under OOD).",
    );
    rep.write(ctx)
}

/// Fig 3b: Mahalanobis distance of Q and held-out K to the K distribution.
pub fn fig3b(ctx: &ExpCtx) -> Result<()> {
    let mut rep =
        Report::new("fig3b", "Mahalanobis OOD distances (paper Fig 3b)", ctx);
    let n = if ctx.full { 40_000 } else { 10_000 };
    let mut rows = Vec::new();
    for (gname, seed) in [("llama3-geom", 11u64), ("yi9-geom", 12u64)] {
        let g = geometry::generate(&GeometryParams::default(), n, 5000, ctx.seed ^ seed);
        let fit = Matrix::from_fn(n - 5000, 64, |r, c| g.keys[(r, c)]);
        let holdout = Matrix::from_fn(5000, 64, |r, c| g.keys[(n - 5000 + r, c)]);
        let rep3b = measure_ood(&fit, &holdout, &g.queries);
        rows.push(vec![
            gname.to_string(),
            format!("{:.2}", rep3b.q_to_k),
            format!("{:.2}", rep3b.k_to_k),
            format!("{:.1}x", rep3b.gap()),
        ]);
    }
    rep.table(&["Geometry", "Q→K distance", "K→K distance", "Gap"], &rows);
    rep.para(
        "Paper shape (Fig 3b): queries are >10× farther from the key \
         distribution than keys themselves. The synthetic geometry's gap \
         is smaller in absolute terms but reproduces the separation that \
         breaks key-key indexes.",
    );
    rep.write(ctx)
}

/// Fig 6: recall vs scanned keys for all four indexes × three geometries.
pub fn fig6(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "fig6",
        "Recall vs scanned keys: Flat/IVF/HNSW/RoarGraph (paper Fig 6)",
        ctx,
    );
    let n = if ctx.full { 131_072 } else { 16_384 };
    let nq = 64;
    let train_q = 2048;
    rep.para(&format!(
        "{n} keys; RoarGraph trained on {train_q} held-out prefill queries \
         (§3.2). Recall@100, Q→K and K→K."
    ));

    let mut rows = Vec::new();
    let mut summary_ra: Vec<f64> = Vec::new();
    for (gname, seed) in
        [("llama3-geom", 21u64), ("yi6-geom", 22u64), ("yi9-geom", 23u64)]
    {
        let g = geometry::generate(
            &GeometryParams::default(),
            n + nq,
            train_q + nq,
            ctx.seed ^ seed,
        );
        let keys = Arc::new(Matrix::from_fn(n, 64, |r, c| g.keys[(r, c)]));
        let kq = Matrix::from_fn(nq, 64, |r, c| g.keys[(n + r, c)]);
        let qq = Matrix::from_fn(nq, 64, |r, c| g.queries[(r, c)]);
        let train = Matrix::from_fn(train_q, 64, |r, c| g.queries[(nq + r, c)]);

        let flat = FlatIndex::new(keys.clone());
        let ivf = IvfIndex::build(keys.clone(), None, ctx.seed);
        let hnsw = HnswIndex::build(keys.clone(), HnswParams::default());
        let roar = RoarGraph::build(keys.clone(), &train, RoarParams::default());

        let nlist = ivf.nlist();
        let graph_sweep: Vec<SearchParams> =
            [100usize, 200, 400, 800].iter().map(|&e| SearchParams { ef: e, nprobe: 0 }).collect();
        let ivf_sweep: Vec<SearchParams> = [1usize, 8, 64, 256, nlist]
            .iter()
            .map(|&p| SearchParams { ef: 0, nprobe: p.min(nlist) })
            .collect();
        let flat_sweep = vec![SearchParams::default()];

        for (dir, queries) in [("Q->K", &qq), ("K->K", &kq)] {
            let truths = truths_for(&keys, queries, 100);
            let curves: Vec<(&str, Vec<(f64, f64)>)> = vec![
                ("Flat", sweep(&flat, queries, &truths, &flat_sweep)),
                ("IVF", sweep(&ivf, queries, &truths, &ivf_sweep)),
                ("HNSW", sweep(&hnsw, queries, &truths, &graph_sweep)),
                ("RetrievalAttention", sweep(&roar, queries, &truths, &graph_sweep)),
            ];
            for (idx_name, curve) in curves {
                for (frac, recall) in curve {
                    if idx_name == "RetrievalAttention" && dir == "Q->K" && recall >= 0.95 {
                        summary_ra.push(frac);
                    }
                    rows.push(vec![
                        gname.to_string(),
                        idx_name.to_string(),
                        dir.to_string(),
                        format!("{:.4}", frac),
                        format!("{:.3}", recall),
                    ]);
                }
            }
        }
    }
    rep.table(&["Geometry", "Index", "Direction", "Scan fraction", "Recall@100"], &rows);
    if let Some(best) = summary_ra.iter().copied().reduce(f64::min) {
        rep.para(&format!(
            "**RetrievalAttention reaches recall ≥0.95 on Q→K scanning \
             {:.1}% of keys** (paper: 1–3% at 128K; the fraction shrinks \
             with corpus size).",
            best * 100.0
        ));
    }
    rep.write(ctx)
}
