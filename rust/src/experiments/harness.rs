//! Shared experiment machinery: report writing, accuracy and latency
//! runners.

use super::ExpCtx;
use crate::config::{Method, ServeConfig};
use crate::model::{Engine, Session};
use crate::util::json::Value;
use crate::workload::Sample;
use anyhow::Result;
use std::fmt::Write as _;

/// A markdown + CSV report accumulator.
pub struct Report {
    id: String,
    md: String,
    csv: String,
}

impl Report {
    pub fn new(id: &str, title: &str, ctx: &ExpCtx) -> Report {
        let mut md = String::new();
        let _ = writeln!(md, "# {id} — {title}\n");
        let _ = writeln!(
            md,
            "profile: {} | seed: {:#x} | host: {} threads\n",
            if ctx.full { "full" } else { "quick (scaled)" },
            ctx.seed,
            crate::util::parallel::num_threads()
        );
        Report { id: id.to_string(), md, csv: String::new() }
    }

    pub fn para(&mut self, text: &str) {
        let _ = writeln!(self.md, "{text}\n");
    }

    /// Emit a markdown table; also mirrors rows into the CSV buffer.
    pub fn table(&mut self, header: &[&str], rows: &[Vec<String>]) {
        let _ = writeln!(self.md, "| {} |", header.join(" | "));
        let seps = header.iter().map(|_| "---").collect::<Vec<_>>().join("|");
        let _ = writeln!(self.md, "|{}|", seps);
        for row in rows {
            let _ = writeln!(self.md, "| {} |", row.join(" | "));
        }
        let _ = writeln!(self.md);
        let _ = writeln!(self.csv, "{}", header.join(","));
        for row in rows {
            let _ = writeln!(self.csv, "{}", row.join(","));
        }
    }

    pub fn code_block(&mut self, text: &str) {
        let _ = writeln!(self.md, "```\n{text}\n```\n");
    }

    pub fn write(&self, ctx: &ExpCtx) -> Result<()> {
        std::fs::create_dir_all(&ctx.out_dir)?;
        std::fs::write(ctx.out_dir.join(format!("{}.md", self.id)), &self.md)?;
        if !self.csv.is_empty() {
            std::fs::write(ctx.out_dir.join(format!("{}.csv", self.id)), &self.csv)?;
        }
        println!("{}", self.md);
        Ok(())
    }

    /// Also drop a machine-readable summary (used by fig1's composite).
    pub fn write_json(&self, ctx: &ExpCtx, v: &Value) -> Result<()> {
        std::fs::write(ctx.out_dir.join(format!("{}.json", self.id)), v.to_string_pretty())?;
        Ok(())
    }
}

/// Engine config for the accuracy experiments (induction model; the
/// static pattern is scaled with the context so host retrieval matters).
pub fn accuracy_config(ctx: &ExpCtx, method: Method) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = method;
    cfg.artifacts_dir = ctx.artifacts_dir.clone();
    cfg.pattern = crate::kvcache::StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    cfg.seed = ctx.seed;
    cfg
}

/// The method rows of Table 2 (paper order).
pub const TABLE2_METHODS: &[Method] = &[
    Method::Full,
    Method::StreamingLlm,
    Method::SnapKv,
    Method::InfLlm,
    Method::InfiniGen,
    Method::Quest,
    Method::Flat,
    Method::Ivf,
    Method::RetrievalAttention,
];

/// Evaluate one method on a set of prefilled bases: returns mean score
/// (0–100, strict exact-match like the paper's Retr.* metrics) and mean
/// scanned fraction.
pub fn eval_method(
    engine: &Engine,
    bases: &[(Session, Sample)],
    method: Method,
) -> Result<(f32, f64)> {
    let mut score = 0.0f32;
    let mut scanned_frac = 0.0f64;
    for (base, sample) in bases {
        let mut sess = engine.session_for_method(base, method)?;
        let (tokens, _) = engine.generate(&mut sess, sample.expect.len())?;
        score += if sample.passed(&tokens) { 1.0 } else { 0.0 };
        let n = sess.caches[0][0].len().max(1);
        scanned_frac += sess.mean_scanned() / n as f64;
    }
    let n = bases.len().max(1) as f32;
    Ok((100.0 * score / n, scanned_frac / bases.len().max(1) as f64))
}

/// Prefill a batch of samples once (method-independent).
pub fn prefill_bases(engine: &Engine, samples: Vec<Sample>) -> Result<Vec<(Session, Sample)>> {
    samples
        .into_iter()
        .map(|s| {
            let sess = engine.prefill(&s.prompt)?;
            Ok((sess, s))
        })
        .collect()
}

/// Format seconds with 3 significant decimals (paper style).
pub fn fmt_s(s: f64) -> String {
    format!("{s:.3}")
}

pub fn fmt_pct(x: f32) -> String {
    format!("{x:.1}")
}
