//! Experiment drivers: one per paper table/figure (DESIGN.md §6).
//!
//! `retrieval-attention experiment <id> [--full] [--out results/]`
//! regenerates the artifact; `experiment all` runs the suite. Every driver
//! writes `results/<id>.md` (the paper-shaped table) and `results/<id>.csv`
//! (raw rows), and EXPERIMENTS.md records paper-vs-measured per id.
//!
//! `--full` selects the paper-scale parameters; the default "quick"
//! profile shrinks context lengths / sample counts so the whole suite runs
//! in minutes on CI — the *shape* conclusions are identical (the scale
//! factor is printed into each report header).

pub mod accuracy;
pub mod fig1;
pub mod harness;
pub mod index_exp;
pub mod latency;
pub mod sparsity;

use anyhow::Result;
use std::path::PathBuf;

/// Shared experiment context.
pub struct ExpCtx {
    pub out_dir: PathBuf,
    /// Paper-scale parameters when true; scaled-down otherwise.
    pub full: bool,
    pub seed: u64,
    pub artifacts_dir: String,
}

impl ExpCtx {
    pub fn new(out_dir: impl Into<PathBuf>, full: bool) -> Self {
        ExpCtx {
            out_dir: out_dir.into(),
            full,
            seed: 0xE1A0,
            artifacts_dir: "artifacts".into(),
        }
    }
}

type ExpFn = fn(&ExpCtx) -> Result<()>;

/// The experiment registry: paper artifact id → driver.
pub const REGISTRY: &[(&str, ExpFn, &str)] = &[
    ("table1", latency::table1, "Full-attention decode latency & KV bytes vs context (Tab 1)"),
    ("fig2", sparsity::fig2, "Dynamic sparsity: recovery ratio, dynamic vs static (Fig 2)"),
    ("fig3a", index_exp::fig3a, "Recall vs scan%: Q->K vs K->K for IVF/HNSW (Fig 3a)"),
    ("fig3b", index_exp::fig3b, "Mahalanobis OOD distances (Fig 3b)"),
    ("table2", accuracy::table2, "Infinity-Bench-style accuracy, all methods (Tab 2)"),
    ("table3", accuracy::table3, "RULER-style accuracy vs context length (Tab 3)"),
    ("fig5", accuracy::fig5, "Needle-in-a-haystack grid (Fig 5/7)"),
    ("table4", latency::table4, "Per-token decode latency vs context length (Tab 4)"),
    ("table5", latency::table5, "Decode latency breakdown: search/attention/other (Tab 5)"),
    ("fig6", index_exp::fig6, "Recall vs scanned keys, 4 indexes x 3 geometries (Fig 6)"),
    ("table7", latency::table7, "128K decode latency on the A100 profile (Tab 7)"),
    ("table8", latency::table8, "Decode latency 100K-1M (Tab 8)"),
    ("fig8", accuracy::fig8, "Needle pass at 250K-1M, index level (Fig 8)"),
    ("table9", accuracy::table9, "RULER-128K per-task: InfiniGen/Quest/ours (Tab 9)"),
    ("table10", accuracy::table10, "PyramidKV-style budget allocation (Tab 10)"),
    ("table11", accuracy::table11, "Deep-model proxy: KV-retrieval accuracy + latency (Tab 11)"),
    ("fig1", fig1::fig1, "Accuracy-vs-latency scatter (Fig 1, composite)"),
];

/// Run one experiment by id, or `all`.
pub fn run(id: &str, ctx: &ExpCtx) -> Result<()> {
    std::fs::create_dir_all(&ctx.out_dir)?;
    if id == "all" {
        for (name, f, desc) in REGISTRY {
            eprintln!("=== experiment {name}: {desc}");
            let t = std::time::Instant::now();
            f(ctx)?;
            eprintln!("=== {name} done in {:.1}s", t.elapsed().as_secs_f64());
        }
        return Ok(());
    }
    let (_, f, _) = REGISTRY
        .iter()
        .find(|(name, _, _)| *name == id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment `{id}`; see `experiment list`"))?;
    f(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique() {
        let mut ids: Vec<&str> = REGISTRY.iter().map(|(n, _, _)| *n).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn registry_covers_every_paper_artifact() {
        // The paper's evaluation artifacts (DESIGN.md §6).
        for required in [
            "table1", "table2", "table3", "table4", "table5", "table7", "table8",
            "table9", "table10", "table11", "fig1", "fig2", "fig3a", "fig3b",
            "fig5", "fig6", "fig8",
        ] {
            assert!(
                REGISTRY.iter().any(|(n, _, _)| *n == required),
                "missing experiment {required}"
            );
        }
    }

    #[test]
    fn unknown_id_is_error() {
        let ctx = ExpCtx::new(std::env::temp_dir().join("ra-exp-test"), false);
        assert!(run("nope", &ctx).is_err());
    }
}
