//! Latency experiments: Tables 1/4/5/7/8.
//!
//! Measured numbers are real wall-clock on this host (PJRT CPU "device" +
//! native host path); modeled numbers use the hw profiles (Table 1's GPU
//! memory arithmetic, full-attention scaling, vLLM OOM boundaries) and are
//! labeled as such. The paper-shape claims are about *ratios and slopes*,
//! which carry over (DESIGN.md §2).

use super::harness::*;
use super::ExpCtx;
use crate::baselines::{build_retriever, RetrieverInputs};
use crate::config::{Method, ServeConfig};
use crate::hw::{HwProfile, ModelGeometry, A100, RTX4090};
use crate::model::Engine;
use crate::workload::geometry::{self, GeometryParams};
use anyhow::Result;
use std::time::Instant;

/// Table 1: decode latency & KV cache of full attention vs context.
pub fn table1(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "table1",
        "Full-attention cost vs context length (paper Table 1)",
        ctx,
    );
    let geom = ModelGeometry::LLAMA3_8B;
    let lengths = [128 * 1024usize, 256 * 1024, 512 * 1024, 1_000_000];
    let mut rows = Vec::new();
    for &n in &lengths {
        let kv_gb = geom.kv_bytes(n) as f64 / (1u64 << 30) as f64;
        // Without KV cache, decoding one token re-runs the whole prefix:
        //   projections:  n tokens x 2*params flops
        //   attention:    2 * n^2 * d_model * layers-equivalent flops
        // on the RTX4090 compute profile. The model reproduces the
        // superlinear, attention-dominated growth of the paper's column
        // (their absolute numbers include long-sequence inefficiencies
        // our peak-flops model ignores).
        let params = 8.0e9;
        let d_model = 4096.0;
        let proj = n as f64 * 2.0 * params;
        let attn = 2.0 * (n as f64) * (n as f64) * d_model;
        let total_s = (proj + attn) / RTX4090.device_flops;
        rows.push(vec![
            format!("{}K", n / 1024),
            format!("{:.1}", total_s),
            format!("{kv_gb:.1}"),
        ]);
    }
    rep.table(&["Context", "Modeled decode latency (s, no KV cache)", "KV cache (GB)"], &rows);
    rep.para(
        "Paper Table 1 reports 32.8s/111s/465s/1765s and 15.6/31.2/62.5/125 GB. \
         The KV-bytes column is exact arithmetic (same formula); the latency \
         column is a bandwidth model with the quadratic recompute factor — \
         shape: superlinear growth, attention-dominated.",
    );

    // Measured sanity: host full attention per token is linear in n.
    let mut meas = Vec::new();
    for &n in &[4096usize, 8192, 16384] {
        let g = geometry::generate(&GeometryParams::default(), n, 4, ctx.seed);
        let q = g.queries.row(0).to_vec();
        let t = Instant::now();
        let iters = 5;
        for _ in 0..iters {
            let ids: Vec<u32> = (0..n as u32).collect();
            crate::util::bench::black_box(crate::attention::attend_subset(
                &q, &g.keys, &g.values, &ids, 0.125,
            ));
        }
        meas.push(vec![
            format!("{n}"),
            format!("{:.3}ms", t.elapsed().as_secs_f64() * 1000.0 / iters as f64),
        ]);
    }
    rep.para("Measured (this host): single-head full-attention time per token —");
    rep.table(&["Keys", "Host attention / token / head"], &meas);
    rep.write(ctx)
}

/// Measure mean per-token decode latency for `methods` on one preset at
/// context `n` (synthetic geometry sessions; real engine decode steps).
pub fn method_latencies(
    ctx: &ExpCtx,
    preset: &str,
    n: usize,
    methods: &[Method],
) -> Result<Vec<f64>> {
    let mut cfg = ServeConfig::default();
    cfg.model = preset.into();
    cfg.artifacts_dir = ctx.artifacts_dir.clone();
    cfg.seed = ctx.seed;
    cfg.retrieval.top_k = 100;
    cfg.retrieval.ef = 128;
    let engine = Engine::from_config(cfg)?;
    let spec = engine.spec().clone();

    // One geometry per (layer, kv head).
    let heads: Vec<Vec<geometry::HeadGeometry>> = (0..spec.layers)
        .map(|l| {
            (0..spec.kv_heads)
                .map(|k| {
                    geometry::generate(
                        &GeometryParams { head_dim: spec.head_dim, ..Default::default() },
                        n,
                        512,
                        ctx.seed ^ ((l * 7 + k) as u64),
                    )
                })
                .collect()
        })
        .collect();

    let steps = if ctx.full { 20 } else { 8 };
    let mut out = Vec::with_capacity(methods.len());
    for &m in methods {
        if matches!(m, Method::Full | Method::VllmLike) && n > 16384 && !ctx.full {
            // Exact host attention over everything at large n is the slow
            // baseline the paper also caps; measure at the cap and scale
            // linearly (it IS linear — verified in table1's measured block).
            let capped = self::measure_decode(&engine, &heads, m, steps, 16384)?;
            out.push(capped * n as f64 / 16384.0);
            continue;
        }
        out.push(self::measure_decode(&engine, &heads, m, steps, n)?);
    }
    Ok(out)
}

fn measure_decode(
    engine: &Engine,
    heads: &[Vec<geometry::HeadGeometry>],
    method: Method,
    steps: usize,
    cap: usize,
) -> Result<f64> {
    // Truncate geometry to `cap` keys if needed.
    let truncated: Vec<Vec<geometry::HeadGeometry>> = heads
        .iter()
        .map(|layer| {
            layer
                .iter()
                .map(|g| {
                    if g.keys.rows() <= cap {
                        geometry::HeadGeometry {
                            keys: g.keys.clone(),
                            values: g.values.clone(),
                            queries: g.queries.clone(),
                        }
                    } else {
                        let d = g.keys.cols();
                        let take = |m: &crate::tensor::Matrix| {
                            crate::tensor::Matrix::from_fn(cap, d, |r, c| m[(r, c)])
                        };
                        geometry::HeadGeometry {
                            keys: take(&g.keys),
                            values: take(&g.values),
                            queries: g.queries.clone(),
                        }
                    }
                })
                .collect()
        })
        .collect();
    let mut sess = engine.synthetic_session(truncated, method)?;
    // Warm up one step (first PJRT executions page everything in).
    engine.decode_step(&mut sess, 1)?;
    let t = Instant::now();
    for i in 0..steps {
        engine.decode_step(&mut sess, (i % 100) as u32)?;
    }
    Ok(t.elapsed().as_secs_f64() / steps as f64)
}

/// Table 4: per-token decode latency vs context length, all methods.
pub fn table4(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "table4",
        "Per-token decode latency vs context (paper Table 4, RTX4090)",
        ctx,
    );
    let lengths: Vec<usize> = if ctx.full {
        vec![4096, 8192, 16384, 32768, 65536, 131072]
    } else {
        vec![2048, 4096, 8192, 16384]
    };
    let methods = [
        Method::Full,
        Method::StreamingLlm,
        Method::SnapKv,
        Method::InfLlm,
        Method::Quest,
        Method::InfiniGen,
        Method::Flat,
        Method::Ivf,
        Method::RetrievalAttention,
    ];
    rep.para(&format!(
        "Measured wall-clock on this host (llama3-mini preset, synthetic \
         geometry sessions, {} decode steps/point). `Full` is exact host \
         attention over every token (the no-dropping upper baseline); \
         vLLM's paper row is OOM at every length on 24GB — reproduced by \
         the admission check (see kvcache::paged tests).",
        if ctx.full { 20 } else { 8 }
    ));

    let mut cols: Vec<Vec<f64>> = Vec::new();
    for &n in &lengths {
        cols.push(method_latencies(ctx, "llama3-mini", n, &methods)?);
    }
    let mut rows = Vec::new();
    for (mi, &m) in methods.iter().enumerate() {
        let mut row = vec![m.label().to_string()];
        for col in &cols {
            row.push(fmt_s(col[mi]));
        }
        rows.push(row);
    }
    // vLLM row: OOM per the RTX4090 budget (weights + KV arithmetic).
    let mut vllm_row = vec!["vLLM (24GB model)".to_string()];
    for &n in &lengths {
        let need = ModelGeometry::LLAMA3_8B.kv_bytes(n * 16); // paper-scale tokens
        let free = RTX4090.device_mem_bytes.saturating_sub(16 * (1 << 30));
        vllm_row.push(if need > free { "OOM".into() } else { "ok".into() });
    }
    rows.insert(1, vllm_row);

    let mut header: Vec<String> = vec!["Method".into()];
    header.extend(lengths.iter().map(|l| format!("{}", l)));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    rep.table(&header_refs, &rows);
    rep.para(
        "Paper-shape checks: Full grows ~linearly; StreamingLLM/SnapKV \
         flat; Flat grows with n; IVF grows slower; RetrievalAttention \
         nearly flat and beats Flat by a growing factor (paper: 4.9x at \
         128K) and IVF (paper: 1.98x).",
    );
    // Machine-readable summary for fig1.
    let mut summary = crate::util::json::Value::obj();
    for (mi, &m) in methods.iter().enumerate() {
        summary.set(m.label(), cols.last().unwrap()[mi]);
    }
    rep.write_json(ctx, &summary)?;
    rep.write(ctx)
}

/// Table 5: decode latency breakdown at the largest context.
pub fn table5(ctx: &ExpCtx) -> Result<()> {
    let mut rep =
        Report::new("table5", "Decode latency breakdown (paper Table 5, 128K)", ctx);
    let n = if ctx.full { 65536 } else { 16384 };
    let methods = [Method::Flat, Method::Ivf, Method::RetrievalAttention];
    let mut cfg = ServeConfig::default();
    cfg.model = "llama3-mini".into();
    cfg.artifacts_dir = ctx.artifacts_dir.clone();
    cfg.retrieval.top_k = 100;
    let engine = Engine::from_config(cfg)?;
    let spec = engine.spec().clone();
    let heads: Vec<Vec<geometry::HeadGeometry>> = (0..spec.layers)
        .map(|l| {
            (0..spec.kv_heads)
                .map(|k| {
                    geometry::generate(
                        &GeometryParams { head_dim: spec.head_dim, ..Default::default() },
                        n,
                        512,
                        ctx.seed ^ ((l * 3 + k) as u64),
                    )
                })
                .collect()
        })
        .collect();

    let mut rows = Vec::new();
    for &m in &methods {
        let mut sess = engine.synthetic_session(heads.clone(), m)?;
        engine.decode_step(&mut sess, 1)?;
        let steps = if ctx.full { 16 } else { 6 };
        let mut bd = crate::metrics::PhaseBreakdown::default();
        for i in 0..steps {
            let out = engine.decode_step(&mut sess, i as u32)?;
            bd.add(&out.breakdown);
        }
        let bd = bd.scale(1.0 / steps as f64);
        rows.push(vec![
            m.label().to_string(),
            fmt_s(bd.search),
            fmt_s(bd.attention),
            fmt_s(bd.other),
            fmt_s(bd.total()),
            format!("{:.1}%", bd.search_share() * 100.0),
        ]);
    }
    rep.table(
        &[
            "Method",
            "Vector search (s)",
            "Attention (s)",
            "Others (s)",
            "Total (s)",
            "Search share",
        ],
        &rows,
    );
    rep.para(
        "Paper shape (Table 5): Flat spends 86.6% of the step in search, \
         IVF 67.0%, RetrievalAttention 34.0%.",
    );
    rep.write(ctx)
}

/// Table 7: per-preset decode latency (A100-profile context in the paper).
pub fn table7(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "table7",
        "Per-preset decode latency (paper Table 7, A100/128K)",
        ctx,
    );
    let n = if ctx.full { 32768 } else { 8192 };
    let methods = [
        Method::StreamingLlm,
        Method::SnapKv,
        Method::InfLlm,
        Method::Flat,
        Method::Ivf,
        Method::RetrievalAttention,
    ];
    let mut rows: Vec<Vec<String>> = methods.iter().map(|m| vec![m.label().to_string()]).collect();
    for preset in ["yi6-mini", "yi9-mini", "llama3-mini"] {
        let lat = method_latencies(ctx, preset, n, &methods)?;
        for (mi, l) in lat.iter().enumerate() {
            rows[mi].push(fmt_s(*l));
        }
    }
    rep.para(&format!(
        "Measured at {n} tokens per preset on this host. Paper shape \
         (Table 7): deeper Yi-9B is slowest per method; ours beats IVF \
         ~2x and Flat ~3.6x on every model; static methods are flat-cheap \
         but accuracy-broken (Table 2)."
    ));
    rep.table(&["Method", "yi6-mini", "yi9-mini", "llama3-mini"], &rows);
    rep.write(ctx)
}

/// Table 8: 100K–1M scaling, single-head measured.
pub fn table8(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new("table8", "Decode latency 100K-1M (paper Table 8)", ctx);
    let lengths: Vec<usize> = if ctx.full {
        vec![100_000, 200_000, 500_000, 1_000_000]
    } else {
        vec![50_000, 100_000, 200_000]
    };
    rep.para(
        "Per-(query-head) host cost measured directly at full paper scale \
         (index search + sparse attention per decode query); engine-level \
         overheads are context-independent and excluded. vLLM boundary \
         from the A100-80GB arithmetic.",
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut flat_row = vec!["Flat".to_string()];
    let mut ivf_row = vec!["IVF".to_string()];
    let mut ra_row = vec!["RetrievalAttention".to_string()];
    let mut vllm_row = vec!["vLLM (80GB)".to_string()];
    let mut stream_row = vec!["StreamingLLM".to_string()];
    for &n in &lengths {
        let g = geometry::generate(&GeometryParams::default(), n, 1024, ctx.seed ^ n as u64);
        let keys = std::sync::Arc::new(g.keys);
        let values = g.values;
        let ids: std::sync::Arc<Vec<u32>> =
            std::sync::Arc::new((0..n as u32).collect());
        let cfg = crate::config::RetrievalConfig { top_k: 100, ..Default::default() };
        let queries_for_search =
            crate::tensor::Matrix::from_fn(64, keys.cols(), |r, c| g.queries[(r, c)]);

        for (method, row) in [
            (Method::Flat, &mut flat_row),
            (Method::Ivf, &mut ivf_row),
            (Method::RetrievalAttention, &mut ra_row),
        ] {
            let train = crate::tensor::Matrix::from_fn(
                g.queries.rows() - 64,
                keys.cols(),
                |r, c| g.queries[(64 + r, c)],
            );
            let inp = RetrieverInputs::from_parts(
                keys.clone().into(),
                (*ids).clone(),
                &train,
                0.125,
                &cfg,
                ctx.seed,
            );
            let retr = build_retriever(method, inp);
            let t = Instant::now();
            let reps = 16;
            for i in 0..reps {
                let q = queries_for_search.row(i % 64);
                let r = retr.retrieve(q, 100);
                crate::util::bench::black_box(crate::attention::attend_subset(
                    q, &keys, &values, &r.ids, 0.125,
                ));
            }
            row.push(format!("{:.5}", t.elapsed().as_secs_f64() / reps as f64));
        }
        // StreamingLLM: constant, no host work.
        stream_row.push("0.00000".into());
        // vLLM: paper-scale arithmetic on the A100 80GB.
        let need = ModelGeometry::LLAMA3_8B.kv_bytes(n);
        let free = A100.device_mem_bytes.saturating_sub(16 * (1 << 30));
        vllm_row.push(if need > free { "OOM".into() } else { "ok".into() });
    }
    rows.push(vllm_row);
    rows.push(stream_row);
    rows.push(flat_row);
    rows.push(ivf_row);
    rows.push(ra_row);
    let mut header: Vec<String> = vec!["Method".into()];
    header.extend(lengths.iter().map(|l| format!("{}K", l / 1000)));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    rep.table(&header_refs, &rows);
    rep.para(
        "Paper shape (Table 8): Flat grows ~10x from 100K→1M, IVF ~6x, \
         RetrievalAttention ~flat (paper: +8%); vLLM OOM past 200K.",
    );
    rep.write(ctx)
}

/// Expose profile names for the CLI.
pub fn profiles() -> Vec<&'static HwProfile> {
    vec![&RTX4090, &A100, &crate::hw::LOCALHOST]
}
