//! Fig 1: the accuracy-vs-latency headline scatter — a composite of the
//! table2 (accuracy) and table4 (latency) outputs.

use super::harness::Report;
use super::ExpCtx;
use crate::util::json;
use anyhow::{Context, Result};

pub fn fig1(ctx: &ExpCtx) -> Result<()> {
    let mut rep = Report::new(
        "fig1",
        "Accuracy vs decode latency (paper Fig 1, composite)",
        ctx,
    );
    let acc_path = ctx.out_dir.join("table2.json");
    let lat_path = ctx.out_dir.join("table4.json");
    if !acc_path.exists() || !lat_path.exists() {
        rep.para(
            "table2/table4 summaries not found — run `experiment table2` \
             and `experiment table4` first (or `experiment all`, which \
             orders them before fig1).",
        );
        // Run them now rather than failing: fig1 is a composite.
        super::accuracy::table2(ctx)?;
        super::latency::table4(ctx)?;
    }
    let acc = json::parse(&std::fs::read_to_string(&acc_path).context("table2.json")?)?;
    let lat = json::parse(&std::fs::read_to_string(&lat_path).context("table4.json")?)?;

    let mut rows = Vec::new();
    if let (json::Value::Obj(am), json::Value::Obj(_)) = (&acc, &lat) {
        for (method, a) in am {
            let l = lat.get(method).and_then(json::Value::as_f64);
            rows.push(vec![
                method.clone(),
                format!("{:.1}", a.as_f64().unwrap_or(0.0)),
                l.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    rep.table(&["Method", "Avg accuracy (table2)", "Decode latency s (table4, longest)"], &rows);
    rep.para(
        "Paper shape (Fig 1): RetrievalAttention sits in the top-left \
         corner — full-attention accuracy at near-static latency; Flat is \
         accurate but slow; StreamingLLM fast but inaccurate.",
    );
    rep.write(ctx)
}
