//! The ANNS substrate.
//!
//! RetrievalAttention's core claim (§2.4/§3.2 of the paper) is about *which*
//! vector index you put under the attention mechanism:
//!
//! * [`flat`] — exact KNN by linear scan; the accuracy ceiling and the
//!   latency floor of Table 4's `Flat` row.
//! * [`ivf`] — k-means clustering + inverted lists; the conventional
//!   comparator that needs to scan 30–50% of keys under Q→K OOD.
//! * [`hnsw`] — proximity graph built from key/key closeness; falls into
//!   local optima under OOD (Fig 3a).
//! * [`roargraph`] — the paper's attention-aware index: exact KNN links
//!   from *prefill query vectors* to keys, projected onto key–key edges
//!   (RoarGraph-style), so decode-time queries traverse edges that reflect
//!   the query distribution. Reaches recall ≥0.95 scanning 1–3% of keys.
//!
//! All indexes use **inner product** as the similarity (larger = more
//! similar), exactly matching the attention logit `q·k`.

pub mod flat;
pub mod hnsw;
pub mod ivf;
pub mod kmeans;
pub mod roargraph;

use crate::tensor::Matrix;
use std::ops::Range;

/// A search result: ids and scores sorted by score descending, plus the
/// number of key vectors whose distance was actually computed ("scanned" in
/// the paper's Fig 3a/Fig 6 x-axis).
#[derive(Clone, Debug, Default)]
pub struct SearchResult {
    pub ids: Vec<u32>,
    pub scores: Vec<f32>,
    /// Number of key vectors scored during this search.
    pub scanned: usize,
}

impl SearchResult {
    /// Recall@k against an exact ground-truth id set.
    pub fn recall_against(&self, truth: &[u32]) -> f32 {
        if truth.is_empty() {
            return 1.0;
        }
        let hit = self.ids.iter().filter(|id| truth.contains(id)).count();
        hit as f32 / truth.len() as f32
    }
}

/// Per-query search knobs. Each index interprets the fields it understands;
/// sweeping these produces the recall-vs-scanned curves of Fig 3a / Fig 6.
#[derive(Clone, Copy, Debug)]
pub struct SearchParams {
    /// Beam width for graph indexes (HNSW / RoarGraph).
    pub ef: usize,
    /// Number of inverted lists probed by IVF.
    pub nprobe: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams { ef: 128, nprobe: 8 }
    }
}

/// Context handed to online inserts.
///
/// The attention-aware index (RoarGraph) wires new keys using *queries*,
/// not key/key closeness — for decoded tokens the natural training side is
/// the recent decode queries, which come from exactly the distribution
/// future decode queries will come from (the same argument §3.2 makes for
/// prefill queries).
#[derive(Clone, Copy, Default)]
pub struct InsertContext<'a> {
    /// Recent decode query vectors (one per row, oldest first). `None` or
    /// empty ⇒ indexes fall back to key-space wiring.
    pub recent_queries: Option<&'a Matrix>,
}

impl<'a> InsertContext<'a> {
    pub fn none() -> InsertContext<'static> {
        InsertContext { recent_queries: None }
    }

    fn queries(&self) -> Option<&'a Matrix> {
        self.recent_queries.filter(|m| m.rows() > 0)
    }
}

/// A reclamation epoch's dense-id remap, shared by every index family of
/// one GQA group. Tombstoned dense slots are physically dropped: surviving
/// rows are renumbered contiguously (order-preserving), the key store is
/// replaced by its compacted form, and the whole thing is published under
/// a bumped **store generation** — dense ids are only meaningful within a
/// generation, so readers must pair an index front with the id map of the
/// same generation (see `baselines::GroupShared`).
pub struct RemapPlan {
    /// Compacted key store: exactly the surviving rows, in the old order.
    pub store: KeyStore,
    /// Old dense id → new dense id; [`RemapPlan::DROPPED`] marks slots
    /// being reclaimed. Length == the pre-remap dense slot count.
    pub old_to_new: Vec<u32>,
    /// Dense slots in the compacted space (== `store.rows()`).
    pub new_len: usize,
    /// The store generation after this remap (stamped on index fronts).
    pub store_gen: u64,
}

impl RemapPlan {
    /// Sentinel in `old_to_new` for reclaimed slots.
    pub const DROPPED: u32 = u32::MAX;

    /// Build the plan that drops `dead` (ascending dense ids) from
    /// `store`: survivors renumber contiguously in the old order. This is
    /// THE planner — `Job::Compact` and every remap test go through it.
    /// Returns the plan plus the surviving old ids (`keep`, which the
    /// caller maps to surviving absolute ids), or `None` when there is
    /// nothing to drop or nothing would survive (the graph families need
    /// at least one node).
    pub fn from_dead(dead: &[u32], store: &KeyStore, gen: u64) -> Option<(RemapPlan, Vec<u32>)> {
        debug_assert!(dead.windows(2).all(|w| w[0] < w[1]), "dead ids must be ascending");
        let old_len = store.rows();
        if dead.is_empty() {
            return None;
        }
        let mut old_to_new = vec![RemapPlan::DROPPED; old_len];
        let mut keep: Vec<u32> = Vec::with_capacity(old_len.saturating_sub(dead.len()));
        let mut di = 0usize;
        for old in 0..old_len as u32 {
            if di < dead.len() && dead[di] == old {
                di += 1;
                continue;
            }
            old_to_new[old as usize] = keep.len() as u32;
            keep.push(old);
        }
        if keep.is_empty() {
            return None;
        }
        let plan = RemapPlan {
            store: store.compact_select(&keep),
            old_to_new,
            new_len: keep.len(),
            store_gen: gen,
        };
        Some((plan, keep))
    }

    /// New dense id of `old`, or `None` when the slot is reclaimed.
    #[inline]
    pub fn map(&self, old: u32) -> Option<u32> {
        match self.old_to_new.get(old as usize) {
            Some(&n) if n != RemapPlan::DROPPED => Some(n),
            _ => None,
        }
    }
}

/// Shared by the families' `remap_dense` impls: renumber a tombstone
/// bitset into the compacted space. Heads of one GQA group receive the
/// identical remove stream, so the planner (built from head 0's dead set)
/// normally drops every tombstone — but a diverged head's extra tombstone
/// survives the remap as a tombstone instead of being resurrected.
pub(crate) fn remap_dead(dead: &[bool], plan: &RemapPlan) -> (Vec<bool>, usize) {
    let mut out = vec![false; plan.new_len];
    let mut count = 0usize;
    for (old, &was_dead) in dead.iter().enumerate() {
        if !was_dead {
            continue;
        }
        if let Some(new) = plan.map(old as u32) {
            out[new as usize] = true;
            count += 1;
        }
    }
    (out, count)
}

/// Shared by the families' `dead_ids` impls: ascending tombstoned slots.
pub(crate) fn collect_dead(dead: &[bool]) -> Vec<u32> {
    dead.iter()
        .enumerate()
        .filter_map(|(i, &d)| if d { Some(i as u32) } else { None })
        .collect()
}

/// Common interface over all four index families.
///
/// Indexes are **online**: construction happens once over the prefill keys,
/// and decoded keys the sliding window has passed over are folded in through
/// [`VectorIndex::insert_batch`] (RetroInfer-style "the KV cache is a live
/// vector store"), keeping per-token decode cost bounded for arbitrarily
/// long generations. Deletion runs through [`VectorIndex::remove_batch`]:
/// ids are tombstoned (dense ids stay stable — the shared id map is never
/// rewritten), search never returns a tombstoned id, and each family
/// reclaims structure its own way (flat/IVF compact their scan lists past
/// a tombstone-ratio threshold; the graphs re-link around the hole with
/// the degree-bounded repair machinery). Implementations are
/// `Send + Sync` so per-head searches can be fanned out across threads
/// (Appendix C, "Multi-head Parallelism").
pub trait VectorIndex: Send + Sync {
    /// Number of dense id slots (including tombstoned ones).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tombstoned-but-unreclaimed slots.
    fn tombstones(&self) -> usize {
        0
    }

    /// Vectors currently searchable.
    fn live_len(&self) -> usize {
        self.len() - self.tombstones()
    }

    /// Top-`k` maximum-inner-product search.
    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> SearchResult;

    /// Short name used in experiment tables ("Flat", "IVF", ...).
    fn name(&self) -> &'static str;

    /// Approximate heap bytes held by the index structure (excluding the
    /// shared key storage), for the memory accounting of Table 1.
    fn memory_bytes(&self) -> usize;

    /// Whether this index family implements online inserts. Callers use
    /// this to decide if an overflow buffer can be drained into the index.
    fn supports_insert(&self) -> bool {
        false
    }

    /// Fold freshly appended key vectors into the searchable set.
    ///
    /// `keys` **replaces** the shared key store: rows `[0, new.start)` must
    /// be byte-identical to the previous store (dense ids are stable), rows
    /// `new` are the appended vectors, and `new.end == keys.rows()`. All
    /// indexes of one GQA group receive the same `Arc`, preserving the
    /// single-key-copy-per-group memory layout (Appendix C).
    ///
    /// Returns `false` when the index family does not support online
    /// maintenance (the default); callers then keep scanning the overflow
    /// buffer linearly, i.e. the pre-insert behaviour.
    fn insert_batch(&mut self, keys: KeyStore, new: Range<usize>, ctx: &InsertContext<'_>) -> bool {
        let _ = (keys, new, ctx);
        false
    }

    /// Single-vector convenience wrapper over [`VectorIndex::insert_batch`].
    fn insert(&mut self, keys: KeyStore, id: usize, ctx: &InsertContext<'_>) -> bool {
        self.insert_batch(keys, id..id + 1, ctx)
    }

    /// Whether this family implements the deletion path.
    fn supports_remove(&self) -> bool {
        false
    }

    /// Tombstone the given dense ids: they must never be returned by a
    /// subsequent search, and `tombstones()` must account for them until
    /// the family compacts. Unknown/already-dead ids are ignored. Returns
    /// `false` when the family does not implement removal (the default).
    fn remove_batch(&mut self, ids: &[u32]) -> bool {
        let _ = ids;
        false
    }

    /// Whether this family implements the reclamation remap
    /// ([`VectorIndex::remap_dense`]).
    fn supports_remap(&self) -> bool {
        false
    }

    /// Dense ids currently tombstoned, ascending. Families that support
    /// removal must report them — the reclamation planner builds the
    /// old→new renumbering from the first head's set.
    fn dead_ids(&self) -> Vec<u32> {
        Vec::new()
    }

    /// Apply a reclamation epoch's dense-id remap: adopt `plan.store` as
    /// the key store and renumber every internal dense reference through
    /// `plan.old_to_new`, dropping reclaimed slots. After a successful
    /// remap `len() == plan.new_len` and (absent head divergence)
    /// `tombstones() == 0`; searches over surviving rows must return the
    /// renumbered ids of (approximately, for the graphs) the same rows as
    /// before. Returns `false` when unsupported or when the plan does not
    /// match this index's dense space (the default).
    fn remap_dense(&mut self, plan: &RemapPlan) -> bool {
        let _ = plan;
        false
    }

    /// Whether searches rank candidates against a quantized scan tier
    /// (see [`crate::kernel::QuantMode`]): candidate *ordering* is then
    /// approximate, and an exact re-rank of the top pool is worthwhile
    /// ([`search_rerank`]).
    fn scan_quantized(&self) -> bool {
        false
    }

    /// Whether this family backs [`VectorIndex::score_exact`] with a real
    /// f32 row read (all four in-crate families do). This is the
    /// capability [`search_rerank`] gates on: a family that reports
    /// `scan_quantized()` without this degrades to a plain (approximate-
    /// order) search instead of re-ranking against the sentinel scores —
    /// a quality fallback, never a worker panic (the no-panic policy
    /// `cargo xtask lint` enforces on the serving path).
    fn supports_exact_rerank(&self) -> bool {
        false
    }

    /// Exact f32 inner product of `query` with dense row `id`, read from
    /// the index's **own** key store — the same generation as the dense
    /// ids its searches return, so this is always safe to call on a
    /// search result even mid-reclamation. Backs the
    /// `retrieval.quant.rerank` exact re-scoring pass.
    ///
    /// The default returns `f32::NEG_INFINITY` (ranks the row last and
    /// can never be mistaken for a plausible score). Callers must gate on
    /// [`VectorIndex::supports_exact_rerank`] — [`search_rerank`] does —
    /// so the sentinel is unreachable on the serving path.
    fn score_exact(&self, query: &[f32], id: u32) -> f32 {
        let _ = (query, id);
        f32::NEG_INFINITY
    }

    /// Batched [`VectorIndex::score_exact`] over a candidate pool,
    /// appended to `out`. Families backed by the segmented store override
    /// this with the run-batched exact gather so the rerank pool pays one
    /// chunk lookup per run, not per id.
    fn score_exact_batch(&self, query: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        out.reserve(ids.len());
        for &id in ids {
            out.push(self.score_exact(query, id));
        }
    }

    /// Whether this family can serialize itself into a session snapshot
    /// ([`crate::store`]). All four in-crate families can; the default is
    /// conservative for future families.
    fn supports_save(&self) -> bool {
        false
    }

    /// Stable one-byte family tag used by the snapshot format to dispatch
    /// [`load_index`]. Tags are part of the on-disk format: never reuse or
    /// renumber them (see the version policy in `store`).
    fn family_tag(&self) -> u8 {
        u8::MAX
    }

    /// Serialize the family's structure — everything EXCEPT the shared key
    /// store, which the snapshot writes once per GQA group — so that
    /// [`load_index`] over the same store rebuilds an index whose searches
    /// are bit-identical to this one's. Default: unsupported.
    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        let _ = w;
        anyhow::bail!("{}: snapshot persistence unsupported", self.name())
    }

    /// Deep copy, used by the double-buffered maintenance swap: the worker
    /// mutates a private back buffer and publishes it atomically while
    /// decode keeps searching the front.
    fn clone_index(&self) -> Box<dyn VectorIndex>;
}

/// Snapshot family tags (on-disk format constants — append-only).
pub const FAMILY_FLAT: u8 = 0;
pub const FAMILY_IVF: u8 = 1;
pub const FAMILY_HNSW: u8 = 2;
pub const FAMILY_ROAR: u8 = 3;

/// Restore an index family from a snapshot stream: the inverse of
/// [`VectorIndex::save_state`], dispatched on the family tag. `keys` is
/// the group's restored key store (written once per GQA group, shared by
/// every head's index via its `Arc`'d chunks).
pub fn load_index(
    tag: u8,
    keys: KeyStore,
    r: &mut crate::store::codec::SnapReader<'_>,
) -> anyhow::Result<Box<dyn VectorIndex>> {
    Ok(match tag {
        FAMILY_FLAT => Box::new(flat::FlatIndex::load_state(keys, r)?),
        FAMILY_IVF => Box::new(ivf::IvfIndex::load_state(keys, r)?),
        FAMILY_HNSW => Box::new(hnsw::HnswIndex::load_state(keys, r)?),
        FAMILY_ROAR => Box::new(roargraph::RoarGraph::load_state(keys, r)?),
        other => anyhow::bail!("unknown index family tag {other} in snapshot"),
    })
}

/// Shared by the families' save/load impls: tombstone bitset packed 8
/// flags per byte (a 128K-row head's set is 16 KB per head per snapshot,
/// not 128 KB of bool padding).
pub(crate) fn dead_to_bytes(dead: &[bool]) -> Vec<u8> {
    let mut out = vec![0u8; dead.len().div_ceil(8)];
    for (i, &d) in dead.iter().enumerate() {
        if d {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

/// Inverse of [`dead_to_bytes`]: unpack exactly `n` flags; returns the
/// bitset plus its popcount, or `None` when the byte length does not
/// match `n` (a corrupted snapshot).
pub(crate) fn dead_from_bytes(bytes: &[u8], n: usize) -> Option<(Vec<bool>, usize)> {
    if bytes.len() != n.div_ceil(8) {
        return None;
    }
    let mut dead = Vec::with_capacity(n);
    let mut count = 0usize;
    for i in 0..n {
        let d = bytes[i / 8] & (1 << (i % 8)) != 0;
        count += d as usize;
        dead.push(d);
    }
    Some((dead, count))
}

/// Search with an exact re-rank pass over a widened candidate pool: when
/// the index ranks against a quantized scan tier, fetch `rerank × k`
/// candidates, re-score them against the f32 keys, and keep the exact
/// top-k. Quantization error is thereby confined to the ordering *beyond*
/// the pool boundary — exactly where ANN search already tolerates
/// approximation. `rerank <= 1`, `k == 0`, or an unquantized index
/// degrades to a plain search.
pub fn search_rerank(
    index: &dyn VectorIndex,
    query: &[f32],
    k: usize,
    rerank: usize,
    params: &SearchParams,
) -> SearchResult {
    if rerank <= 1 || k == 0 || !index.scan_quantized() || !index.supports_exact_rerank() {
        return index.search(query, k, params);
    }
    let pool = k.saturating_mul(rerank);
    let mut r = index.search(query, pool, params);
    let mut exact: Vec<f32> = Vec::with_capacity(r.ids.len());
    index.score_exact_batch(query, &r.ids, &mut exact);
    let mut rescored: Vec<(f32, u32)> =
        exact.into_iter().zip(r.ids.iter().copied()).collect();
    // The exact re-scores touch the f32 rows: count them as scanned.
    r.scanned += rescored.len();
    rescored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    rescored.truncate(k);
    r.ids = rescored.iter().map(|&(_, id)| id).collect();
    r.scores = rescored.into_iter().map(|(s, _)| s).collect();
    r
}

/// Shared key storage: the per-GQA-group dense key copy (Appendix C,
/// "Minimize the CPU Memory Usage") as a **segmented store** — `Arc`'d
/// chunks shared structurally across drains, so online growth appends an
/// O(batch) chunk instead of recopying the O(context) prefix (see
/// [`crate::kvcache::SegmentedStore`]). Rows `[0, old.rows())` of a grown
/// store are bit-identical to the old one, keeping dense ids stable.
pub type KeyStore = crate::kvcache::SegmentedStore;

/// Helper: exact top-k by brute force over a dense matrix — the ground
/// truth used by experiments and tests. Always f32, one batched kernel
/// call for the whole scan.
pub fn exact_topk(keys: &Matrix, query: &[f32], k: usize) -> Vec<u32> {
    let mut scores: Vec<f32> = Vec::with_capacity(keys.rows());
    crate::kernel::dot_rows(query, keys.as_slice(), keys.cols(), &mut scores);
    crate::tensor::argtopk(&scores, k).into_iter().map(|i| i as u32).collect()
}

/// Exact top-k over a segmented key store (RoarGraph's bipartite phase
/// scans segment-contiguous f32 rows — one batched kernel call per chunk,
/// never the quantized mirror: this is ground truth).
pub fn exact_topk_store(keys: &KeyStore, query: &[f32], k: usize) -> Vec<u32> {
    let mut scores: Vec<f32> = Vec::with_capacity(keys.rows());
    for seg in keys.segments() {
        crate::kernel::dot_rows(query, seg.as_slice(), seg.cols(), &mut scores);
    }
    crate::tensor::argtopk(&scores, k).into_iter().map(|i| i as u32).collect()
}

/// Epoch-stamped visited set: O(1) clear between searches without
/// reallocating, shared by the graph indexes.
pub(crate) struct VisitedSet {
    stamp: Vec<u32>,
    epoch: u32,
}

impl VisitedSet {
    pub fn new(n: usize) -> Self {
        VisitedSet { stamp: vec![0; n], epoch: 0 }
    }

    /// Start a fresh traversal.
    pub fn clear(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Wrapped: reset stamps so stale marks cannot collide.
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
    }

    /// Mark `i` visited; returns true if it was not visited before.
    #[inline]
    pub fn insert(&mut self, i: usize) -> bool {
        if self.stamp[i] == self.epoch {
            false
        } else {
            self.stamp[i] = self.epoch;
            true
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visited_set_epochs() {
        let mut v = VisitedSet::new(4);
        v.clear();
        assert!(v.insert(2));
        assert!(!v.insert(2));
        v.clear();
        assert!(v.insert(2));
    }

    #[test]
    fn recall_computation() {
        let r = SearchResult { ids: vec![1, 2, 3], scores: vec![], scanned: 0 };
        assert_eq!(r.recall_against(&[1, 2, 9, 10]), 0.5);
        assert_eq!(r.recall_against(&[]), 1.0);
    }

    #[test]
    fn exact_topk_orders_by_ip() {
        let keys = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let ids = exact_topk(&keys, &[2.0, 1.0], 3);
        assert_eq!(ids, vec![2, 0, 1]);
    }
}
