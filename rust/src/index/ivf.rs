//! IVF (inverted file) index: k-means coarse quantiser + inverted lists.
//!
//! The conventional cluster-based comparator of the paper (Faiss IVF). Under
//! the Q→K distribution gap the query lands "between" key clusters, so many
//! lists must be probed for high recall — the 30–50% scan fraction of
//! Fig 3a and the 0.373 s/token row of Table 4.

use super::{InsertContext, KeyStore, SearchParams, SearchResult, VectorIndex};
use crate::tensor::{argtopk, dot, l2_sq};
use std::ops::Range;

/// Inverted-file index over a shared key store.
pub struct IvfIndex {
    keys: KeyStore,
    /// `nlist x d` centroids.
    centroids: crate::tensor::Matrix,
    /// Inverted lists: ids per centroid.
    lists: Vec<Vec<u32>>,
}

impl IvfIndex {
    /// Build with `nlist` clusters (defaults to `4*sqrt(n)` when `None`,
    /// the common Faiss heuristic).
    pub fn build(keys: KeyStore, nlist: Option<usize>, seed: u64) -> Self {
        let n = keys.rows();
        let nlist = nlist.unwrap_or_else(|| (4.0 * (n as f64).sqrt()) as usize).clamp(1, n.max(1));
        let km = super::kmeans::kmeans(&keys, nlist, 10, seed);
        let mut lists = vec![Vec::new(); km.centroids.rows()];
        for (i, &c) in km.assignment.iter().enumerate() {
            lists[c as usize].push(i as u32);
        }
        IvfIndex { keys, centroids: km.centroids, lists }
    }

    pub fn nlist(&self) -> usize {
        self.lists.len()
    }
}

impl VectorIndex for IvfIndex {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> SearchResult {
        let nprobe = params.nprobe.clamp(1, self.lists.len());
        // Rank lists by centroid distance to the query (L2, as for build).
        let cdist: Vec<f32> = (0..self.centroids.rows())
            .map(|c| -l2_sq(query, self.centroids.row(c)))
            .collect();
        let probe = argtopk(&cdist, nprobe);

        let mut ids: Vec<u32> = Vec::new();
        let mut scores: Vec<f32> = Vec::new();
        let mut scanned = self.centroids.rows(); // centroid comparisons count as scans
        for c in probe {
            for &id in &self.lists[c] {
                scores.push(dot(query, self.keys.row(id as usize)));
                ids.push(id);
            }
            scanned += self.lists[c].len();
        }
        let top = argtopk(&scores, k);
        SearchResult {
            ids: top.iter().map(|&i| ids[i]).collect(),
            scores: top.iter().map(|&i| scores[i]).collect(),
            scanned,
        }
    }

    fn name(&self) -> &'static str {
        "IVF"
    }

    fn memory_bytes(&self) -> usize {
        self.centroids.as_slice().len() * 4
            + self.lists.iter().map(|l| l.len() * 4).sum::<usize>()
            + std::mem::size_of::<Self>()
    }

    fn supports_insert(&self) -> bool {
        true
    }

    /// Assign each new vector to its nearest coarse centroid (the same L2
    /// rule `kmeans` used for the base assignment) — exactly how Faiss'
    /// `IndexIVFFlat::add` grows an inverted file without retraining the
    /// quantiser.
    fn insert_batch(&mut self, keys: KeyStore, new: Range<usize>, _ctx: &InsertContext<'_>) -> bool {
        debug_assert_eq!(new.end, keys.rows());
        debug_assert_eq!(new.start, self.keys.rows());
        for i in new {
            let row = keys.row(i);
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..self.centroids.rows() {
                let d2 = l2_sq(row, self.centroids.row(c));
                if d2 < best_d {
                    best_d = d2;
                    best = c;
                }
            }
            self.lists[best].push(i as u32);
        }
        self.keys = keys;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::exact_topk;
    use crate::tensor::Matrix;
    
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn random_keys(n: usize, d: usize, seed: u64) -> KeyStore {
        let mut rng = Rng::seed_from(seed);
        Arc::new(Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5))
    }

    #[test]
    fn full_probe_equals_exact() {
        let keys = random_keys(256, 8, 3);
        let idx = IvfIndex::build(keys.clone(), Some(16), 3);
        let q: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe: 16 });
        let truth = exact_topk(&keys, &q, 10);
        assert_eq!(r.ids, truth);
    }

    #[test]
    fn more_probes_never_fewer_hits() {
        let keys = random_keys(512, 8, 5);
        let idx = IvfIndex::build(keys.clone(), Some(32), 5);
        let q: Vec<f32> = (0..8).map(|i| (8 - i) as f32 * 0.05).collect();
        let truth = exact_topk(&keys, &q, 10);
        let mut last = 0.0;
        for nprobe in [1, 4, 16, 32] {
            let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe });
            let rec = r.recall_against(&truth);
            assert!(rec >= last - 1e-6, "recall should be monotone in nprobe");
            last = rec;
        }
        assert!((last - 1.0).abs() < 1e-6);
    }

    #[test]
    fn insert_then_full_probe_is_exact() {
        let keys = random_keys(256, 8, 9);
        let mut idx = IvfIndex::build(keys.clone(), Some(16), 9);
        let mut grown = (*keys).clone();
        let mut rng = Rng::seed_from(99);
        for _ in 0..64 {
            let row: Vec<f32> = (0..8).map(|_| rng.f32() - 0.5).collect();
            grown.push_row(&row);
        }
        let grown = Arc::new(grown);
        assert!(idx.insert_batch(grown.clone(), 256..320, &crate::index::InsertContext::none()));
        assert_eq!(idx.len(), 320);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 - 3.0) * 0.2).collect();
        let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe: 16 });
        let truth = exact_topk(&grown, &q, 10);
        assert_eq!(r.ids, truth, "full probe after insert must stay exact");
    }

    #[test]
    fn scanned_grows_with_nprobe() {
        let keys = random_keys(512, 8, 7);
        let idx = IvfIndex::build(keys, Some(32), 7);
        let q = vec![0.1f32; 8];
        let s1 = idx.search(&q, 5, &SearchParams { ef: 0, nprobe: 1 }).scanned;
        let s8 = idx.search(&q, 5, &SearchParams { ef: 0, nprobe: 8 }).scanned;
        assert!(s8 > s1);
    }
}
