//! IVF (inverted file) index: k-means coarse quantiser + inverted lists.
//!
//! The conventional cluster-based comparator of the paper (Faiss IVF). Under
//! the Q→K distribution gap the query lands "between" key clusters, so many
//! lists must be probed for high recall — the 30–50% scan fraction of
//! Fig 3a and the 0.373 s/token row of Table 4.
//!
//! Removal tombstones entries; past a 25% tombstone ratio the inverted
//! lists are compacted (dead ids dropped), exactly how Faiss reclaims a
//! `remove_ids`-heavy IVF without retraining the quantiser.

use super::{InsertContext, KeyStore, RemapPlan, SearchParams, SearchResult, VectorIndex};
use crate::kernel;
use crate::tensor::argtopk;
use std::ops::Range;

/// Inverted-file index over a shared key store.
#[derive(Clone)]
pub struct IvfIndex {
    keys: KeyStore,
    /// `nlist x d` centroids.
    centroids: crate::tensor::Matrix,
    /// Inverted lists: ids per centroid.
    lists: Vec<Vec<u32>>,
    /// Tombstones, one per dense slot.
    dead: Vec<bool>,
    dead_count: usize,
    /// `dead_count` at the last list compaction: dense ids are permanent,
    /// so the compaction ratio is measured against tombstones accumulated
    /// since then (an all-time ratio would re-sweep every list on every
    /// later removal once crossed).
    dead_at_compact: usize,
}

impl IvfIndex {
    /// Build with `nlist` clusters (defaults to `4*sqrt(n)` when `None`,
    /// the common Faiss heuristic).
    pub fn build(keys: impl Into<KeyStore>, nlist: Option<usize>, seed: u64) -> Self {
        let keys = keys.into();
        let n = keys.rows();
        let nlist = nlist.unwrap_or_else(|| (4.0 * (n as f64).sqrt()) as usize).clamp(1, n.max(1));
        // The quantiser trains on a dense view (one-time build cost).
        let km = super::kmeans::kmeans(&keys.to_matrix(), nlist, 10, seed);
        let mut lists = vec![Vec::new(); km.centroids.rows()];
        for (i, &c) in km.assignment.iter().enumerate() {
            lists[c as usize].push(i as u32);
        }
        IvfIndex {
            keys,
            centroids: km.centroids,
            lists,
            dead: vec![false; n],
            dead_count: 0,
            dead_at_compact: 0,
        }
    }

    pub fn nlist(&self) -> usize {
        self.lists.len()
    }

    /// Restore from a snapshot stream over the group's restored key store
    /// (the inverse of [`VectorIndex::save_state`]): the trained coarse
    /// quantiser and inverted lists come back verbatim, so searches are
    /// bit-identical — no k-means retraining on restore.
    pub(crate) fn load_state(
        keys: KeyStore,
        r: &mut crate::store::codec::SnapReader<'_>,
    ) -> anyhow::Result<IvfIndex> {
        let centroids = r.matrix()?;
        let nlist = r.usize()?;
        let mut lists = Vec::with_capacity(nlist);
        for _ in 0..nlist {
            lists.push(r.u32s()?);
        }
        let dead_bytes = r.bytes()?;
        let (dead, dead_count) = super::dead_from_bytes(&dead_bytes, keys.rows())
            .ok_or_else(|| anyhow::anyhow!("ivf snapshot: tombstone set != store rows"))?;
        let dead_at_compact = r.usize()?;
        anyhow::ensure!(
            centroids.cols() == keys.cols(),
            "ivf snapshot: centroid width ({}) != key width ({})",
            centroids.cols(),
            keys.cols()
        );
        anyhow::ensure!(
            lists.iter().flatten().all(|&i| (i as usize) < keys.rows()),
            "ivf snapshot: posting-list id out of bounds"
        );
        Ok(IvfIndex { keys, centroids, lists, dead, dead_count, dead_at_compact })
    }
}

impl VectorIndex for IvfIndex {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn tombstones(&self) -> usize {
        self.dead_count
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> SearchResult {
        let nprobe = params.nprobe.clamp(1, self.lists.len());
        // Rank lists by centroid distance to the query (L2, as for build):
        // one batched kernel call over the contiguous centroid matrix.
        let mut cdist: Vec<f32> = Vec::with_capacity(self.centroids.rows());
        kernel::l2_rows(query, self.centroids.as_slice(), self.centroids.cols(), &mut cdist);
        for v in cdist.iter_mut() {
            *v = -*v;
        }
        let probe = argtopk(&cdist, nprobe);

        // Gather each probed posting list's live ids, then batch-score
        // them against the store's scan tier (quantized mirror when
        // built) — one kernel dispatch per list instead of one per id.
        let mut ids: Vec<u32> = Vec::new();
        let mut scores: Vec<f32> = Vec::new();
        let mut scanned = self.centroids.rows(); // centroid comparisons count as scans
        for c in probe {
            let before = ids.len();
            ids.extend(self.lists[c].iter().copied().filter(|&id| !self.dead[id as usize]));
            self.keys.score_ids(query, &ids[before..], &mut scores);
            scanned += self.lists[c].len();
        }
        let top = argtopk(&scores, k);
        SearchResult {
            ids: top.iter().map(|&i| ids[i]).collect(),
            scores: top.iter().map(|&i| scores[i]).collect(),
            scanned,
        }
    }

    fn name(&self) -> &'static str {
        "IVF"
    }

    fn memory_bytes(&self) -> usize {
        // Key store bytes are charged once per GQA group by the owner.
        self.centroids.as_slice().len() * 4
            + self.lists.iter().map(|l| l.len() * 4).sum::<usize>()
            + self.dead.len()
            + std::mem::size_of::<Self>()
    }

    fn supports_insert(&self) -> bool {
        true
    }

    /// Assign each new vector to its nearest coarse centroid (the same L2
    /// rule `kmeans` used for the base assignment) — exactly how Faiss'
    /// `IndexIVFFlat::add` grows an inverted file without retraining the
    /// quantiser.
    fn insert_batch(
        &mut self,
        keys: KeyStore,
        new: Range<usize>,
        _ctx: &InsertContext<'_>,
    ) -> bool {
        debug_assert_eq!(new.end, keys.rows());
        debug_assert_eq!(new.start, self.keys.rows());
        let mut cbuf: Vec<f32> = Vec::with_capacity(self.centroids.rows());
        for i in new {
            let row = keys.row(i);
            // Batched centroid assignment (same L2 rule as the kmeans
            // build), exact f32 as always for structure decisions.
            cbuf.clear();
            kernel::l2_rows(row, self.centroids.as_slice(), self.centroids.cols(), &mut cbuf);
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for (c, &d2) in cbuf.iter().enumerate() {
                if d2 < best_d {
                    best_d = d2;
                    best = c;
                }
            }
            self.lists[best].push(i as u32);
        }
        self.keys = keys;
        self.dead.resize(self.keys.rows(), false);
        true
    }

    fn supports_remove(&self) -> bool {
        true
    }

    fn remove_batch(&mut self, ids: &[u32]) -> bool {
        for &id in ids {
            let i = id as usize;
            if i < self.dead.len() && !self.dead[i] {
                self.dead[i] = true;
                self.dead_count += 1;
            }
        }
        // Compaction threshold: drop dead entries from the inverted lists
        // once the tombstones accumulated since the last compaction exceed
        // a quarter of the LIVE corpus, so probes stop paying for them —
        // a total-slots denominator would fire ever more rarely as dead
        // rows accumulate over a streaming session. The tombstone bitset
        // stays (dense ids are permanent between reclamation epochs).
        if (self.dead_count - self.dead_at_compact) * 4 > self.keys.rows() - self.dead_count {
            let dead = &self.dead;
            for l in &mut self.lists {
                l.retain(|&id| !dead[id as usize]);
            }
            self.dead_at_compact = self.dead_count;
        }
        true
    }

    fn supports_remap(&self) -> bool {
        true
    }

    fn scan_quantized(&self) -> bool {
        self.keys.is_quantized()
    }

    fn supports_exact_rerank(&self) -> bool {
        true
    }

    fn score_exact(&self, query: &[f32], id: u32) -> f32 {
        self.keys.score_exact(query, id as usize)
    }

    fn score_exact_batch(&self, query: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        self.keys.score_ids_exact(query, ids, out);
    }

    fn dead_ids(&self) -> Vec<u32> {
        super::collect_dead(&self.dead)
    }

    /// Rewrite every inverted list through the renumbering (dropping
    /// reclaimed entries) and adopt the compacted store; the coarse
    /// quantiser is untouched — exactly how Faiss survives `remove_ids`
    /// without retraining.
    fn remap_dense(&mut self, plan: &RemapPlan) -> bool {
        if plan.old_to_new.len() != self.keys.rows() || plan.store.rows() != plan.new_len {
            return false;
        }
        let (dead, dead_count) = super::remap_dead(&self.dead, plan);
        for l in &mut self.lists {
            let mut out = Vec::with_capacity(l.len());
            for &id in l.iter() {
                if let Some(new) = plan.map(id) {
                    out.push(new);
                }
            }
            *l = out;
        }
        self.keys = plan.store.clone();
        self.dead = dead;
        self.dead_count = dead_count;
        self.dead_at_compact = dead_count;
        true
    }

    fn supports_save(&self) -> bool {
        true
    }

    fn family_tag(&self) -> u8 {
        super::FAMILY_IVF
    }

    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        w.matrix(&self.centroids)?;
        w.usize(self.lists.len())?;
        for l in &self.lists {
            w.u32s(l)?;
        }
        w.bytes(&super::dead_to_bytes(&self.dead))?;
        w.usize(self.dead_at_compact)?;
        Ok(())
    }

    fn clone_index(&self) -> Box<dyn VectorIndex> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{exact_topk, exact_topk_store};
    use crate::tensor::Matrix;

    use crate::util::rng::Rng;

    fn random_keys(n: usize, d: usize, seed: u64) -> KeyStore {
        let mut rng = Rng::seed_from(seed);
        KeyStore::from_matrix(Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5))
    }

    #[test]
    fn full_probe_equals_exact() {
        let keys = random_keys(256, 8, 3);
        let idx = IvfIndex::build(keys.clone(), Some(16), 3);
        let q: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe: 16 });
        let truth = exact_topk_store(&keys, &q, 10);
        assert_eq!(r.ids, truth);
    }

    #[test]
    fn more_probes_never_fewer_hits() {
        let keys = random_keys(512, 8, 5);
        let idx = IvfIndex::build(keys.clone(), Some(32), 5);
        let q: Vec<f32> = (0..8).map(|i| (8 - i) as f32 * 0.05).collect();
        let truth = exact_topk_store(&keys, &q, 10);
        let mut last = 0.0;
        for nprobe in [1, 4, 16, 32] {
            let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe });
            let rec = r.recall_against(&truth);
            assert!(rec >= last - 1e-6, "recall should be monotone in nprobe");
            last = rec;
        }
        assert!((last - 1.0).abs() < 1e-6);
    }

    #[test]
    fn insert_then_full_probe_is_exact() {
        let keys = random_keys(256, 8, 9);
        let mut idx = IvfIndex::build(keys.clone(), Some(16), 9);
        let mut rng = Rng::seed_from(99);
        let batch = Matrix::from_fn(64, 8, |_, _| rng.f32() - 0.5);
        let grown = keys.append_rows(batch);
        assert!(idx.insert_batch(grown.clone(), 256..320, &crate::index::InsertContext::none()));
        assert_eq!(idx.len(), 320);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 - 3.0) * 0.2).collect();
        let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe: 16 });
        let truth = exact_topk(&grown.to_matrix(), &q, 10);
        assert_eq!(r.ids, truth, "full probe after insert must stay exact");
    }

    #[test]
    fn scanned_grows_with_nprobe() {
        let keys = random_keys(512, 8, 7);
        let idx = IvfIndex::build(keys, Some(32), 7);
        let q = vec![0.1f32; 8];
        let s1 = idx.search(&q, 5, &SearchParams { ef: 0, nprobe: 1 }).scanned;
        let s8 = idx.search(&q, 5, &SearchParams { ef: 0, nprobe: 8 }).scanned;
        assert!(s8 > s1);
    }

    #[test]
    fn remap_then_full_probe_matches_exact_over_survivors() {
        let keys = random_keys(256, 8, 17);
        let mut idx = IvfIndex::build(keys.clone(), Some(16), 17);
        let removed: Vec<u32> = (0..256).step_by(4).map(|i| i as u32).collect();
        assert!(idx.remove_batch(&removed));
        assert_eq!(idx.dead_ids(), removed);
        let (plan, keep) = RemapPlan::from_dead(&removed, &keys, 1).expect("plan must build");
        assert_eq!(keep, (0..256u32).filter(|i| i % 4 != 0).collect::<Vec<u32>>());
        assert!(idx.supports_remap());
        assert!(idx.remap_dense(&plan));
        assert_eq!(idx.len(), keep.len());
        assert_eq!(idx.tombstones(), 0);
        let listed: usize = idx.lists.iter().map(|l| l.len()).sum();
        assert_eq!(listed, keep.len(), "lists must hold exactly the survivors");
        // Full probe over the compacted space equals exact KNN over it.
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.4).cos()).collect();
        let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe: 16 });
        let truth = exact_topk_store(&plan.store, &q, 10);
        assert_eq!(r.ids, truth, "remapped full probe must stay exact");
    }

    #[test]
    fn remove_then_full_probe_matches_exact_over_live() {
        let keys = random_keys(300, 8, 13);
        let mut idx = IvfIndex::build(keys.clone(), Some(16), 13);
        let removed: Vec<u32> = (0..300).step_by(3).map(|i| i as u32).collect();
        assert!(idx.remove_batch(&removed));
        assert_eq!(idx.tombstones(), removed.len());
        // 100/300 dead crosses the compaction threshold: lists shrink.
        let listed: usize = idx.lists.iter().map(|l| l.len()).sum();
        assert_eq!(listed, 200, "compaction must drop dead entries");
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).sin()).collect();
        let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe: 16 });
        for id in &r.ids {
            assert!(id % 3 != 0, "tombstoned id {id} returned");
        }
        // Exact over the live subset.
        let mut scores: Vec<(f32, u32)> = (0..300u32)
            .filter(|i| i % 3 != 0)
            .map(|i| (crate::tensor::dot(&q, keys.row(i as usize)), i))
            .collect();
        scores.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let truth: Vec<u32> = scores.into_iter().take(10).map(|(_, i)| i).collect();
        assert_eq!(r.ids, truth, "full probe over live set must stay exact");
    }
}
