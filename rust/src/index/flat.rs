//! Exact KNN by linear scan ("Flat" in the paper's tables).
//!
//! Scans 100% of the live key vectors; rayon-parallel over row blocks.
//! This is both the accuracy ceiling (recall = 1.0 by construction) and
//! the latency comparator that RetrievalAttention beats by 4.9× at 128K
//! (Table 4). The scan walks the segmented store chunk by chunk (no
//! per-row chunk lookup). Removal tombstones rows; past a 25% tombstone
//! ratio the index compacts to an explicit live-id list so dead rows stop
//! costing scan time.

use super::{InsertContext, KeyStore, RemapPlan, SearchParams, SearchResult, VectorIndex};
use crate::tensor::argtopk;
use crate::util::parallel;
use std::ops::Range;

/// Tombstone fraction (dead * COMPACT_DEN > rows * COMPACT_NUM triggers
/// the live-list compaction).
const COMPACT_NUM: usize = 1;
const COMPACT_DEN: usize = 4;

/// Brute-force maximum-inner-product index.
#[derive(Clone)]
pub struct FlatIndex {
    keys: KeyStore,
    /// Tombstones, one per dense slot.
    dead: Vec<bool>,
    dead_count: usize,
    /// Live dense ids, (re)materialised whenever the tombstones
    /// accumulated since the last compaction cross the threshold. Between
    /// compactions the list may contain a bounded number of stale dead
    /// ids — the scan filters them (they are touched, not scored).
    live: Option<Vec<u32>>,
    /// `dead_count` at the last compaction (the threshold is measured
    /// against the delta: dense ids are permanent, so an all-time ratio
    /// would re-sweep the live list on every later removal).
    dead_at_compact: usize,
    /// Rows per parallel task; tuned in the perf pass (large enough to
    /// amortise task overhead, small enough to balance).
    block: usize,
}

impl FlatIndex {
    pub fn new(keys: impl Into<KeyStore>) -> Self {
        let keys = keys.into();
        let n = keys.rows();
        FlatIndex {
            keys,
            dead: vec![false; n],
            dead_count: 0,
            live: None,
            dead_at_compact: 0,
            block: 4096,
        }
    }

    /// Restore from a snapshot stream over the group's restored key store
    /// (the inverse of [`VectorIndex::save_state`]).
    pub(crate) fn load_state(
        keys: KeyStore,
        r: &mut crate::store::codec::SnapReader<'_>,
    ) -> anyhow::Result<FlatIndex> {
        let block = r.usize()?;
        let dead_bytes = r.bytes()?;
        let (dead, dead_count) = super::dead_from_bytes(&dead_bytes, keys.rows())
            .ok_or_else(|| anyhow::anyhow!("flat snapshot: tombstone set != store rows"))?;
        let dead_at_compact = r.usize()?;
        let live = if r.bool()? { Some(r.u32s()?) } else { None };
        if let Some(live) = &live {
            anyhow::ensure!(
                live.iter().all(|&i| (i as usize) < keys.rows()),
                "flat snapshot: live id out of bounds"
            );
        }
        Ok(FlatIndex { keys, dead, dead_count, live, dead_at_compact, block: block.max(1) })
    }

    fn maybe_compact(&mut self) {
        // Ratio against the LIVE row count, not total dense slots: dense
        // ids are permanent between reclamation epochs, so a total-rows
        // denominator would make compaction fire ever more rarely as dead
        // rows pile up over a long streaming session.
        let since = self.dead_count - self.dead_at_compact;
        let live = self.keys.rows() - self.dead_count;
        if since * COMPACT_DEN > live * COMPACT_NUM {
            self.live = Some(
                (0..self.keys.rows() as u32).filter(|&i| !self.dead[i as usize]).collect(),
            );
            self.dead_at_compact = self.dead_count;
        }
    }
}

impl VectorIndex for FlatIndex {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn tombstones(&self) -> usize {
        self.dead_count
    }

    fn search(&self, query: &[f32], k: usize, _params: &SearchParams) -> SearchResult {
        if let Some(live) = &self.live {
            // Compacted path: batch-score the live list through the store's
            // scan tier (quantized mirror when built), then overwrite any
            // post-compaction tombstone with -inf (filtered here, swept
            // out at the next compaction).
            let n = live.len();
            let score_block = |lo: usize, hi: usize| -> Vec<f32> {
                let mut v = Vec::with_capacity(hi - lo);
                self.keys.score_ids(query, &live[lo..hi], &mut v);
                for (j, &id) in live[lo..hi].iter().enumerate() {
                    if self.dead[id as usize] {
                        v[j] = f32::NEG_INFINITY;
                    }
                }
                v
            };
            let scores: Vec<f32> = if n >= 2 * self.block {
                let nblocks = n.div_ceil(self.block);
                let per_block: Vec<Vec<f32>> = parallel::par_map_range(nblocks, |b| {
                    let lo = b * self.block;
                    score_block(lo, (lo + self.block).min(n))
                });
                per_block.into_iter().flatten().collect()
            } else {
                score_block(0, n)
            };
            let mut top = argtopk(&scores, k);
            top.retain(|&i| !self.dead[live[i] as usize]);
            let stale = self.dead_count - self.dead_at_compact;
            return SearchResult {
                scores: top.iter().map(|&i| scores[i]).collect(),
                ids: top.into_iter().map(|i| live[i]).collect(),
                scanned: n - stale.min(n),
            };
        }
        let n = self.keys.rows();
        // Segment-local batched scan through the store's scan tier
        // (quantized mirror when built); dead rows are overwritten with
        // -inf and filtered below. Tasks are fixed `block`-row ranges
        // *within* segments (one giant prefill chunk must still fan out
        // across cores), addressed segment-locally so the hot loop never
        // pays a chunk lookup.
        // (segment, local start, local end, global index of local start).
        let score_range = |s: usize, lo: usize, hi: usize, gbase: usize| -> Vec<f32> {
            let mut v = Vec::with_capacity(hi - lo);
            self.keys.score_segment_range(query, s, lo, hi, &mut v);
            for (j, x) in v.iter_mut().enumerate() {
                if self.dead[gbase + j] {
                    *x = f32::NEG_INFINITY;
                }
            }
            v
        };
        let segments = self.keys.segments();
        let mut tasks: Vec<(usize, usize, usize, usize)> = Vec::new();
        let mut base = 0;
        for (s, seg) in segments.iter().enumerate() {
            let rows = seg.rows();
            let mut lo = 0;
            while lo < rows {
                let hi = (lo + self.block).min(rows);
                tasks.push((s, lo, hi, base + lo));
                lo = hi;
            }
            base += rows;
        }
        let scores: Vec<f32> = if n >= 2 * self.block {
            let per_task: Vec<Vec<f32>> =
                parallel::par_map(&tasks, |&(s, lo, hi, gbase)| score_range(s, lo, hi, gbase));
            per_task.into_iter().flatten().collect()
        } else {
            let mut v = Vec::with_capacity(n);
            for &(s, lo, hi, gbase) in &tasks {
                v.extend(score_range(s, lo, hi, gbase));
            }
            v
        };
        let mut ids = argtopk(&scores, k);
        ids.retain(|&i| !self.dead[i]);
        SearchResult {
            scores: ids.iter().map(|&i| scores[i]).collect(),
            ids: ids.into_iter().map(|i| i as u32).collect(),
            scanned: n - self.dead_count,
        }
    }

    fn name(&self) -> &'static str {
        "Flat"
    }

    fn memory_bytes(&self) -> usize {
        // The key store (payload AND chunk table) is charged once per GQA
        // group by the owner, not per head.
        self.dead.len()
            + self.live.as_ref().map(|l| l.len() * 4).unwrap_or(0)
            + std::mem::size_of::<Self>()
    }

    fn supports_insert(&self) -> bool {
        true
    }

    /// Exact scan has no structure to maintain: adopt the grown store.
    fn insert_batch(
        &mut self,
        keys: KeyStore,
        new: Range<usize>,
        _ctx: &InsertContext<'_>,
    ) -> bool {
        debug_assert_eq!(new.end, keys.rows());
        debug_assert_eq!(new.start, self.keys.rows());
        self.keys = keys;
        self.dead.resize(self.keys.rows(), false);
        if let Some(live) = &mut self.live {
            live.extend(new.map(|i| i as u32));
        }
        true
    }

    fn supports_remove(&self) -> bool {
        true
    }

    fn remove_batch(&mut self, ids: &[u32]) -> bool {
        for &id in ids {
            let i = id as usize;
            if i < self.dead.len() && !self.dead[i] {
                self.dead[i] = true;
                self.dead_count += 1;
            }
        }
        self.maybe_compact();
        true
    }

    fn supports_remap(&self) -> bool {
        true
    }

    fn scan_quantized(&self) -> bool {
        self.keys.is_quantized()
    }

    fn supports_exact_rerank(&self) -> bool {
        true
    }

    fn score_exact(&self, query: &[f32], id: u32) -> f32 {
        self.keys.score_exact(query, id as usize)
    }

    fn score_exact_batch(&self, query: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        self.keys.score_ids_exact(query, ids, out);
    }

    fn dead_ids(&self) -> Vec<u32> {
        super::collect_dead(&self.dead)
    }

    /// Exact scan has no structure beyond the store: adopt the compacted
    /// store and renumber the tombstone bitset.
    fn remap_dense(&mut self, plan: &RemapPlan) -> bool {
        if plan.old_to_new.len() != self.keys.rows() || plan.store.rows() != plan.new_len {
            return false;
        }
        let (dead, dead_count) = super::remap_dead(&self.dead, plan);
        self.keys = plan.store.clone();
        self.dead = dead;
        self.dead_count = dead_count;
        self.dead_at_compact = dead_count;
        self.live = None;
        true
    }

    fn supports_save(&self) -> bool {
        true
    }

    fn family_tag(&self) -> u8 {
        super::FAMILY_FLAT
    }

    /// Everything except the shared key store: the tombstone bitset, the
    /// compaction watermark, and the (optional) compacted live list.
    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        w.usize(self.block)?;
        w.bytes(&super::dead_to_bytes(&self.dead))?;
        w.usize(self.dead_at_compact)?;
        w.bool(self.live.is_some())?;
        if let Some(live) = &self.live {
            w.u32s(live)?;
        }
        Ok(())
    }

    fn clone_index(&self) -> Box<dyn VectorIndex> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    fn keys() -> KeyStore {
        // 8 unit-ish vectors in 4d.
        KeyStore::from_matrix(Matrix::from_fn(8, 4, |r, c| {
            if r % 4 == c {
                1.0 + r as f32 * 0.1
            } else {
                0.0
            }
        }))
    }

    #[test]
    fn finds_exact_top1() {
        let idx = FlatIndex::new(keys());
        let q = [0.0, 0.0, 1.0, 0.0];
        let r = idx.search(&q, 1, &SearchParams::default());
        // rows 2 and 6 point along dim 2; row 6 has larger magnitude (1.6).
        assert_eq!(r.ids, vec![6]);
        assert_eq!(r.scanned, 8);
    }

    #[test]
    fn scores_sorted_desc() {
        let idx = FlatIndex::new(keys());
        let q = [1.0, 0.5, 0.25, 0.125];
        let r = idx.search(&q, 8, &SearchParams::default());
        for w in r.scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert_eq!(r.ids.len(), 8);
    }

    #[test]
    fn k_zero_is_empty() {
        let idx = FlatIndex::new(keys());
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0], 0, &SearchParams::default());
        assert!(r.ids.is_empty());
    }

    #[test]
    fn insert_extends_exact_scan() {
        let base = keys();
        let mut idx = FlatIndex::new(base.clone());
        // Append a dominant vector along dim 2.
        let grown = base.append_rows(Matrix::from_vec(1, 4, vec![0.0, 0.0, 9.0, 0.0]));
        let n = grown.rows();
        assert!(idx.insert_batch(grown, 8..n, &crate::index::InsertContext::none()));
        assert_eq!(idx.len(), 9);
        let r = idx.search(&[0.0, 0.0, 1.0, 0.0], 1, &SearchParams::default());
        assert_eq!(r.ids, vec![8], "inserted vector must be searchable");
    }

    #[test]
    fn removed_ids_never_returned() {
        let mut idx = FlatIndex::new(keys());
        assert!(idx.remove_batch(&[6]));
        assert_eq!(idx.tombstones(), 1);
        assert_eq!(idx.live_len(), 7);
        let r = idx.search(&[0.0, 0.0, 1.0, 0.0], 8, &SearchParams::default());
        assert!(!r.ids.contains(&6), "tombstoned id returned: {:?}", r.ids);
        // Runner-up along dim 2 (row 2) now wins.
        assert_eq!(r.ids[0], 2);
        assert_eq!(r.scanned, 7);
        // Removing again is a no-op.
        assert!(idx.remove_batch(&[6]));
        assert_eq!(idx.tombstones(), 1);
    }

    #[test]
    fn remap_drops_dead_and_renumbers() {
        let base = keys();
        let mut idx = FlatIndex::new(base.clone());
        assert!(idx.remove_batch(&[0, 3, 5]));
        assert_eq!(idx.dead_ids(), vec![0, 3, 5]);
        let (plan, keep) =
            RemapPlan::from_dead(&idx.dead_ids(), &base, 1).expect("plan must build");
        assert_eq!(keep, vec![1, 2, 4, 6, 7]);
        assert!(idx.supports_remap());
        assert!(idx.remap_dense(&plan));
        assert_eq!(idx.len(), 5);
        assert_eq!(idx.tombstones(), 0);
        assert!(idx.dead_ids().is_empty());
        // Old id 6 (the dominant dim-2 vector) is now dense id 3.
        let r = idx.search(&[0.0, 0.0, 1.0, 0.0], 1, &SearchParams::default());
        assert_eq!(r.ids, vec![3]);
        assert_eq!(r.scanned, 5);
        // Inserts keep working against the compacted store.
        let grown = plan.store.append_rows(Matrix::from_vec(1, 4, vec![9.0, 0.0, 0.0, 0.0]));
        let n = grown.rows();
        assert!(idx.insert_batch(grown, 5..n, &crate::index::InsertContext::none()));
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0], 1, &SearchParams::default());
        assert_eq!(r.ids, vec![5]);
        // A mismatched plan is refused, not applied.
        let bogus = RemapPlan {
            store: KeyStore::new(4),
            old_to_new: vec![0, 1],
            new_len: 0,
            store_gen: 2,
        };
        assert!(!idx.remap_dense(&bogus));
        assert_eq!(idx.len(), 6);
    }

    #[test]
    fn compaction_then_insert_stays_exact() {
        let mut idx = FlatIndex::new(keys());
        // 3/8 dead crosses the 25% compaction threshold.
        assert!(idx.remove_batch(&[0, 1, 2]));
        assert_eq!(idx.tombstones(), 3);
        let r = idx.search(&[1.0, 1.0, 1.0, 1.0], 8, &SearchParams::default());
        assert_eq!(r.ids.len(), 5);
        assert_eq!(r.scanned, 5, "compacted scan must skip dead rows");
        for id in &r.ids {
            assert!(*id >= 3);
        }
        // Inserts after compaction land in the live list.
        let grown = idx.keys.append_rows(Matrix::from_vec(1, 4, vec![9.0, 0.0, 0.0, 0.0]));
        let n = grown.rows();
        assert!(idx.insert_batch(grown, 8..n, &crate::index::InsertContext::none()));
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0], 1, &SearchParams::default());
        assert_eq!(r.ids, vec![8]);
    }
}
