//! Exact KNN by linear scan ("Flat" in the paper's tables).
//!
//! Scans 100% of the key vectors; rayon-parallel over row blocks. This is
//! both the accuracy ceiling (recall = 1.0 by construction) and the latency
//! comparator that RetrievalAttention beats by 4.9× at 128K (Table 4).

use super::{InsertContext, KeyStore, SearchParams, SearchResult, VectorIndex};
use crate::tensor::{argtopk, dot};
use crate::util::parallel;
use std::ops::Range;

/// Brute-force maximum-inner-product index.
pub struct FlatIndex {
    keys: KeyStore,
    /// Rows per rayon task; tuned in the perf pass (large enough to amortise
    /// task overhead, small enough to balance).
    block: usize,
}

impl FlatIndex {
    pub fn new(keys: KeyStore) -> Self {
        FlatIndex { keys, block: 4096 }
    }
}

impl VectorIndex for FlatIndex {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn search(&self, query: &[f32], k: usize, _params: &SearchParams) -> SearchResult {
        let n = self.keys.rows();
        let scores: Vec<f32> = if n >= 2 * self.block {
            // Parallel scoring for long contexts: one task per row block.
            let nblocks = n.div_ceil(self.block);
            let per_block: Vec<Vec<f32>> = parallel::par_map_range(nblocks, |b| {
                let lo = b * self.block;
                let hi = (lo + self.block).min(n);
                (lo..hi).map(|i| dot(query, self.keys.row(i))).collect()
            });
            per_block.into_iter().flatten().collect()
        } else {
            (0..n).map(|i| dot(query, self.keys.row(i))).collect()
        };
        let ids = argtopk(&scores, k);
        SearchResult {
            scores: ids.iter().map(|&i| scores[i]).collect(),
            ids: ids.into_iter().map(|i| i as u32).collect(),
            scanned: n,
        }
    }

    fn name(&self) -> &'static str {
        "Flat"
    }

    fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
    }

    fn supports_insert(&self) -> bool {
        true
    }

    /// Exact scan has no structure to maintain: adopt the grown store.
    fn insert_batch(&mut self, keys: KeyStore, new: Range<usize>, _ctx: &InsertContext<'_>) -> bool {
        debug_assert_eq!(keys.cols(), self.keys.cols());
        debug_assert_eq!(new.end, keys.rows());
        debug_assert_eq!(new.start, self.keys.rows());
        self.keys = keys;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use std::sync::Arc;

    fn keys() -> KeyStore {
        // 8 unit-ish vectors in 4d.
        Arc::new(Matrix::from_fn(8, 4, |r, c| if r % 4 == c { 1.0 + r as f32 * 0.1 } else { 0.0 }))
    }

    #[test]
    fn finds_exact_top1() {
        let idx = FlatIndex::new(keys());
        let q = [0.0, 0.0, 1.0, 0.0];
        let r = idx.search(&q, 1, &SearchParams::default());
        // rows 2 and 6 point along dim 2; row 6 has larger magnitude (1.6).
        assert_eq!(r.ids, vec![6]);
        assert_eq!(r.scanned, 8);
    }

    #[test]
    fn scores_sorted_desc() {
        let idx = FlatIndex::new(keys());
        let q = [1.0, 0.5, 0.25, 0.125];
        let r = idx.search(&q, 8, &SearchParams::default());
        for w in r.scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert_eq!(r.ids.len(), 8);
    }

    #[test]
    fn k_zero_is_empty() {
        let idx = FlatIndex::new(keys());
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0], 0, &SearchParams::default());
        assert!(r.ids.is_empty());
    }

    #[test]
    fn insert_extends_exact_scan() {
        let base = keys();
        let mut idx = FlatIndex::new(base.clone());
        // Append a dominant vector along dim 2.
        let mut grown = (*base).clone();
        grown.push_row(&[0.0, 0.0, 9.0, 0.0]);
        let n = grown.rows();
        assert!(idx.insert_batch(Arc::new(grown), 8..n, &crate::index::InsertContext::none()));
        assert_eq!(idx.len(), 9);
        let r = idx.search(&[0.0, 0.0, 1.0, 0.0], 1, &SearchParams::default());
        assert_eq!(r.ids, vec![8], "inserted vector must be searchable");
    }
}
