//! Lloyd's k-means with k-means++ seeding, used by the IVF index and by
//! InfLLM-style block representatives.
//!
//! Clustering uses Euclidean distance (the conventional choice for IVF
//! coarse quantisers); *search* over the resulting lists still ranks by
//! inner product. This mirrors Faiss' `IndexIVFFlat` with `METRIC_INNER_PRODUCT`.

use crate::tensor::{l2_sq, Matrix};
use crate::util::parallel;
use crate::util::rng::Rng;

/// Result of a k-means run.
pub struct KMeans {
    /// `k x d` centroid matrix.
    pub centroids: Matrix,
    /// Cluster assignment per input row.
    pub assignment: Vec<u32>,
}

/// Run k-means++ then at most `iters` Lloyd iterations.
///
/// Deterministic for a fixed `seed`. Empty clusters are re-seeded from the
/// point farthest from its centroid.
pub fn kmeans(data: &Matrix, k: usize, iters: usize, seed: u64) -> KMeans {
    let n = data.rows();
    let d = data.cols();
    assert!(k >= 1 && n >= 1, "kmeans needs k>=1, n>=1");
    let k = k.min(n);
    let mut rng = Rng::seed_from(seed);

    // --- k-means++ seeding ---
    let mut centroids = Matrix::zeros(0, d);
    let first = rng.below(n);
    centroids.push_row(data.row(first));
    let mut dist2: Vec<f32> = (0..n).map(|i| l2_sq(data.row(i), data.row(first))).collect();
    while centroids.rows() < k {
        let total: f64 = dist2.iter().map(|&v| v as f64).sum();
        let next = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &v) in dist2.iter().enumerate() {
                target -= v as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        centroids.push_row(data.row(next));
        let c = centroids.rows() - 1;
        for i in 0..n {
            let d2 = l2_sq(data.row(i), centroids.row(c));
            if d2 < dist2[i] {
                dist2[i] = d2;
            }
        }
    }

    // --- Lloyd iterations ---
    let mut assignment = vec![0u32; n];
    for _ in 0..iters {
        // Assign (parallel over point blocks).
        let block = 2048;
        let nblocks = n.div_ceil(block);
        let assigned: Vec<Vec<u32>> = parallel::par_map_range(nblocks, |b| {
            let lo = b * block;
            let hi = (lo + block).min(n);
            // One batched kernel call per point over the contiguous
            // centroid matrix (the IVF-build hot loop).
            let mut cbuf: Vec<f32> = Vec::with_capacity(centroids.rows());
            (lo..hi)
                .map(|i| {
                    cbuf.clear();
                    crate::kernel::l2_rows(
                        data.row(i),
                        centroids.as_slice(),
                        centroids.cols(),
                        &mut cbuf,
                    );
                    let mut best = 0u32;
                    let mut best_d = f32::INFINITY;
                    for (c, &d2) in cbuf.iter().enumerate() {
                        if d2 < best_d {
                            best_d = d2;
                            best = c as u32;
                        }
                    }
                    best
                })
                .collect()
        });
        let new_assign: Vec<u32> = assigned.into_iter().flatten().collect();
        let changed = new_assign != assignment;
        assignment = new_assign;

        // Update.
        let mut sums = Matrix::zeros(centroids.rows(), d);
        let mut counts = vec![0u32; centroids.rows()];
        for i in 0..n {
            let c = assignment[i] as usize;
            crate::tensor::axpy(1.0, data.row(i), sums.row_mut(c));
            counts[c] += 1;
        }
        for c in 0..centroids.rows() {
            if counts[c] == 0 {
                // Re-seed empty cluster from a random point.
                let j = rng.below(n);
                centroids.row_mut(c).copy_from_slice(data.row(j));
            } else {
                let inv = 1.0 / counts[c] as f32;
                let (cent, sum) = (centroids.row_mut(c), sums.row(c));
                for (o, &s) in cent.iter_mut().zip(sum.iter()) {
                    *o = s * inv;
                }
            }
        }
        if !changed {
            break;
        }
    }

    KMeans { centroids, assignment }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two well-separated blobs must be split into two clusters.
    #[test]
    fn separates_two_blobs() {
        let mut data = Matrix::zeros(0, 2);
        for i in 0..20 {
            data.push_row(&[10.0 + (i % 5) as f32 * 0.01, 10.0]);
            data.push_row(&[-10.0 - (i % 5) as f32 * 0.01, -10.0]);
        }
        let km = kmeans(&data, 2, 20, 42);
        // All even rows share a cluster, all odd rows share the other.
        let c0 = km.assignment[0];
        let c1 = km.assignment[1];
        assert_ne!(c0, c1);
        for i in 0..40 {
            assert_eq!(km.assignment[i], if i % 2 == 0 { c0 } else { c1 });
        }
    }

    #[test]
    fn k_clamped_to_n() {
        let data = Matrix::from_vec(2, 2, vec![0.0, 0.0, 1.0, 1.0]);
        let km = kmeans(&data, 10, 5, 1);
        assert_eq!(km.centroids.rows(), 2);
    }

    #[test]
    fn deterministic_for_seed() {
        let data = Matrix::from_fn(50, 3, |r, c| ((r * 7 + c * 13) % 17) as f32);
        let a = kmeans(&data, 4, 10, 7);
        let b = kmeans(&data, 4, 10, 7);
        assert_eq!(a.assignment, b.assignment);
    }
}
