//! HNSW proximity graph (Malkov & Yashunin) under inner-product similarity.
//!
//! Built purely from key/key closeness — exactly the construction the paper
//! shows breaking down on Q→K searches (Fig 3a: "graph-based HNSW falls
//! into a local optimum"), because edges reflect the key distribution while
//! decode queries come from the OOD query distribution.

use super::{KeyStore, SearchParams, SearchResult, VectorIndex, VisitedSet};
use crate::tensor::dot;

use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Candidate ordered by similarity (max-heap => best first).
#[derive(Copy, Clone)]
struct Cand {
    sim: f32,
    id: u32,
}
impl PartialEq for Cand {
    fn eq(&self, o: &Self) -> bool {
        self.sim == o.sim && self.id == o.id
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Cand {
    fn cmp(&self, o: &Self) -> Ordering {
        self.sim.total_cmp(&o.sim).then(self.id.cmp(&o.id))
    }
}

/// Reversed ordering (min-heap on similarity) for result frontiers.
#[derive(Copy, Clone)]
struct RevCand(Cand);
impl PartialEq for RevCand {
    fn eq(&self, o: &Self) -> bool {
        self.0 == o.0
    }
}
impl Eq for RevCand {}
impl PartialOrd for RevCand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for RevCand {
    fn cmp(&self, o: &Self) -> Ordering {
        o.0.cmp(&self.0)
    }
}

/// Build-time parameters.
#[derive(Clone, Copy, Debug)]
pub struct HnswParams {
    /// Max out-degree on layers > 0 (layer 0 uses 2M).
    pub m: usize,
    /// Construction beam width.
    pub ef_construction: usize,
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 16, ef_construction: 100, seed: 0 }
    }
}

struct Layer {
    /// Adjacency: `neighbors[id]` is the out-edge list of `id`.
    neighbors: Vec<Vec<u32>>,
}

/// Hierarchical navigable small-world graph.
pub struct HnswIndex {
    keys: KeyStore,
    layers: Vec<Layer>,
    /// Top-layer entry point.
    entry: u32,
    /// Node's maximum layer.
    node_level: Vec<u8>,
    m: usize,
}

impl HnswIndex {
    pub fn build(keys: KeyStore, params: HnswParams) -> Self {
        let n = keys.rows();
        assert!(n > 0, "HNSW needs at least one key");
        let mut rng = Rng::seed_from(params.seed);
        let level_mult = 1.0 / (params.m as f64).ln();

        let node_level: Vec<u8> = (0..n)
            .map(|_| {
                let u: f64 = rng.f64().max(1e-12);
                ((-u.ln() * level_mult) as usize).min(15) as u8
            })
            .collect();
        let max_level = *node_level.iter().max().unwrap() as usize;
        let mut layers: Vec<Layer> =
            (0..=max_level).map(|_| Layer { neighbors: vec![Vec::new(); n] }).collect();
        let entry = node_level.iter().enumerate().max_by_key(|(_, &l)| l).unwrap().0 as u32;

        let mut idx = HnswIndex { keys, layers: Vec::new(), entry, node_level, m: params.m };
        // Incremental insertion. We temporarily move `layers` into the struct
        // via an option dance to satisfy the borrow checker simply: operate on
        // local `layers` and a helper search that borrows keys only.
        let mut visited = VisitedSet::new(n);
        let mut order: Vec<usize> = (0..n).collect();
        // Insert the entry point first so every later node can reach it.
        order.swap(0, entry as usize);
        let mut inserted: Vec<u32> = Vec::with_capacity(n);

        for &i in &order {
            let q = idx.keys.row(i).to_vec();
            let node_lvl = idx.node_level[i] as usize;
            if inserted.is_empty() {
                inserted.push(i as u32);
                continue;
            }
            // Greedy descent from the global entry to node_lvl+1.
            let mut ep = idx.entry;
            for l in (node_lvl + 1..=max_level).rev() {
                ep = greedy_closest(&idx.keys, &layers[l], &q, ep);
            }
            // Beam search + connect on layers node_lvl..=0.
            for l in (0..=node_lvl.min(max_level)).rev() {
                let ef = params.ef_construction;
                let w = beam_search(&idx.keys, &layers[l], &q, &[ep], ef, &mut visited).0;
                let m_l = if l == 0 { params.m * 2 } else { params.m };
                let selected = select_neighbors(&idx.keys, &w, m_l);
                for &nb in &selected {
                    layers[l].neighbors[i].push(nb);
                    layers[l].neighbors[nb as usize].push(i as u32);
                    // Prune over-full neighbor lists.
                    if layers[l].neighbors[nb as usize].len() > m_l {
                        let cands: Vec<Cand> = layers[l].neighbors[nb as usize]
                            .iter()
                            .map(|&x| Cand {
                                sim: dot(idx.keys.row(nb as usize), idx.keys.row(x as usize)),
                                id: x,
                            })
                            .collect();
                        layers[l].neighbors[nb as usize] =
                            select_neighbors(&idx.keys, &cands, m_l);
                    }
                }
                if let Some(best) = selected.first() {
                    ep = *best;
                }
            }
            inserted.push(i as u32);
        }
        idx.layers = layers;
        idx
    }

    /// Beam search on the bottom layer with explicit ef; returns candidates
    /// best-first plus the scan count.
    fn search_layer0(&self, query: &[f32], ef: usize) -> (Vec<Cand>, usize) {
        let mut visited = VisitedSet::new(self.keys.rows());
        let mut scanned = 0usize;
        // Descend upper layers greedily.
        let mut ep = self.entry;
        for l in (1..self.layers.len()).rev() {
            ep = greedy_closest_counted(&self.keys, &self.layers[l], query, ep, &mut scanned);
        }
        let (mut w, s) = beam_search(&self.keys, &self.layers[0], query, &[ep], ef, &mut visited);
        scanned += s;
        w.sort_by(|a, b| b.cmp(a));
        (w, scanned)
    }
}

/// Greedy hill-climb to the most similar node on a layer.
fn greedy_closest(keys: &crate::tensor::Matrix, layer: &Layer, q: &[f32], start: u32) -> u32 {
    let mut scanned = 0;
    greedy_closest_counted(keys, layer, q, start, &mut scanned)
}

fn greedy_closest_counted(
    keys: &crate::tensor::Matrix,
    layer: &Layer,
    q: &[f32],
    start: u32,
    scanned: &mut usize,
) -> u32 {
    let mut cur = start;
    let mut cur_sim = dot(q, keys.row(cur as usize));
    *scanned += 1;
    loop {
        let mut improved = false;
        for &nb in &layer.neighbors[cur as usize] {
            let s = dot(q, keys.row(nb as usize));
            *scanned += 1;
            if s > cur_sim {
                cur_sim = s;
                cur = nb;
                improved = true;
            }
        }
        if !improved {
            return cur;
        }
    }
}

/// Standard HNSW beam search over one layer; returns up to `ef` candidates
/// (unsorted) and the number of similarity computations.
fn beam_search(
    keys: &crate::tensor::Matrix,
    layer: &Layer,
    q: &[f32],
    entries: &[u32],
    ef: usize,
    visited: &mut VisitedSet,
) -> (Vec<Cand>, usize) {
    visited.clear();
    let mut scanned = 0usize;
    let mut frontier: BinaryHeap<Cand> = BinaryHeap::new(); // best-first
    let mut results: BinaryHeap<RevCand> = BinaryHeap::new(); // worst-first

    for &e in entries {
        if visited.insert(e as usize) {
            let sim = dot(q, keys.row(e as usize));
            scanned += 1;
            frontier.push(Cand { sim, id: e });
            results.push(RevCand(Cand { sim, id: e }));
        }
    }
    while let Some(c) = frontier.pop() {
        let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
        if c.sim < worst && results.len() >= ef {
            break;
        }
        for &nb in &layer.neighbors[c.id as usize] {
            if visited.insert(nb as usize) {
                let sim = dot(q, keys.row(nb as usize));
                scanned += 1;
                let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
                if results.len() < ef || sim > worst {
                    frontier.push(Cand { sim, id: nb });
                    results.push(RevCand(Cand { sim, id: nb }));
                    if results.len() > ef {
                        results.pop();
                    }
                }
            }
        }
    }
    (results.into_iter().map(|r| r.0).collect(), scanned)
}

/// Simple neighbor selection: keep the `m` most similar candidates. (The
/// full RNG-style diversity heuristic lives in `roargraph::prune`, where it
/// matters most; plain top-m matches hnswlib's default for IP.)
fn select_neighbors(_keys: &crate::tensor::Matrix, cands: &[Cand], m: usize) -> Vec<u32> {
    let mut sorted: Vec<Cand> = cands.to_vec();
    sorted.sort_by(|a, b| b.cmp(a));
    sorted.dedup_by_key(|c| c.id);
    sorted.into_iter().take(m).map(|c| c.id).collect()
}

impl VectorIndex for HnswIndex {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> SearchResult {
        let ef = params.ef.max(k);
        let (cands, scanned) = self.search_layer0(query, ef);
        SearchResult {
            ids: cands.iter().take(k).map(|c| c.id).collect(),
            scores: cands.iter().take(k).map(|c| c.sim).collect(),
            scanned,
        }
    }

    fn name(&self) -> &'static str {
        "HNSW"
    }

    fn memory_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.neighbors.iter().map(|n| n.len() * 4 + 24).sum::<usize>())
            .sum::<usize>()
            + self.node_level.len()
            + std::mem::size_of::<Self>()
    }
}

impl HnswIndex {
    /// Max out-degree parameter (diagnostics).
    pub fn m(&self) -> usize {
        self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::exact_topk;
    use crate::tensor::Matrix;
    
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn random_keys(n: usize, d: usize, seed: u64) -> KeyStore {
        let mut rng = Rng::seed_from(seed);
        Arc::new(Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5))
    }

    #[test]
    fn in_distribution_recall_high() {
        let keys = random_keys(2000, 16, 11);
        let idx = HnswIndex::build(keys.clone(), HnswParams::default());
        // K->K queries (in-distribution): recall@10 should be high.
        let mut total = 0.0;
        let nq = 20;
        for qi in 0..nq {
            let q = keys.row(qi * 17).to_vec();
            let truth = exact_topk(&keys, &q, 10);
            let r = idx.search(&q, 10, &SearchParams { ef: 128, nprobe: 0 });
            total += r.recall_against(&truth);
        }
        let recall = total / nq as f32;
        assert!(recall > 0.85, "K->K recall too low: {recall}");
    }

    #[test]
    fn scanned_less_than_n_for_small_ef() {
        let keys = random_keys(4000, 16, 13);
        let idx = HnswIndex::build(keys, HnswParams::default());
        let q = vec![0.3f32; 16];
        let r = idx.search(&q, 10, &SearchParams { ef: 32, nprobe: 0 });
        assert!(r.scanned < 4000, "HNSW should scan a fraction: {}", r.scanned);
        assert_eq!(r.ids.len(), 10);
    }

    #[test]
    fn ef_monotone_recall() {
        let keys = random_keys(1500, 8, 17);
        let idx = HnswIndex::build(keys.clone(), HnswParams::default());
        let q = keys.row(3).to_vec();
        let truth = exact_topk(&keys, &q, 10);
        let lo = idx.search(&q, 10, &SearchParams { ef: 10, nprobe: 0 }).recall_against(&truth);
        let hi = idx.search(&q, 10, &SearchParams { ef: 400, nprobe: 0 }).recall_against(&truth);
        assert!(hi >= lo);
        assert!(hi > 0.85, "high-ef recall too low: {hi}");
    }

    #[test]
    fn single_node_graph() {
        let keys = Arc::new(Matrix::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]));
        let idx = HnswIndex::build(keys, HnswParams::default());
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0], 5, &SearchParams::default());
        assert_eq!(r.ids, vec![0]);
    }
}
