//! HNSW proximity graph (Malkov & Yashunin) under inner-product similarity.
//!
//! Built purely from key/key closeness — exactly the construction the paper
//! shows breaking down on Q→K searches (Fig 3a: "graph-based HNSW falls
//! into a local optimum"), because edges reflect the key distribution while
//! decode queries come from the OOD query distribution.

use super::{
    InsertContext, KeyStore, RemapPlan, SearchParams, SearchResult, VectorIndex, VisitedSet,
};
use crate::tensor::dot;

use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::ops::Range;

/// Candidate ordered by similarity (max-heap => best first).
#[derive(Copy, Clone)]
struct Cand {
    sim: f32,
    id: u32,
}
impl PartialEq for Cand {
    fn eq(&self, o: &Self) -> bool {
        self.sim == o.sim && self.id == o.id
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Cand {
    fn cmp(&self, o: &Self) -> Ordering {
        self.sim.total_cmp(&o.sim).then(self.id.cmp(&o.id))
    }
}

/// Reversed ordering (min-heap on similarity) for result frontiers.
#[derive(Copy, Clone)]
struct RevCand(Cand);
impl PartialEq for RevCand {
    fn eq(&self, o: &Self) -> bool {
        self.0 == o.0
    }
}
impl Eq for RevCand {}
impl PartialOrd for RevCand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for RevCand {
    fn cmp(&self, o: &Self) -> Ordering {
        o.0.cmp(&self.0)
    }
}

/// Build-time parameters.
#[derive(Clone, Copy, Debug)]
pub struct HnswParams {
    /// Max out-degree on layers > 0 (layer 0 uses 2M).
    pub m: usize,
    /// Construction beam width.
    pub ef_construction: usize,
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 16, ef_construction: 100, seed: 0 }
    }
}

#[derive(Clone)]
struct Layer {
    /// Adjacency: `neighbors[id]` is the out-edge list of `id`.
    neighbors: Vec<Vec<u32>>,
}

/// Hierarchical navigable small-world graph. Construction is genuinely
/// incremental (one [`HnswIndex::insert_node`] per key), which is also the
/// online-maintenance path: decoded keys folded in through
/// [`VectorIndex::insert_batch`] go through the exact same wiring as
/// build-time keys, so insert-then-search matches a from-scratch rebuild up
/// to the level draws. Removal tombstones the node and re-links its
/// neighborhood: every live node that lost an edge inherits the dead
/// node's live out-edges as candidates, re-selected under the same degree
/// bound as construction — the hole is bridged instead of fragmenting the
/// graph.
#[derive(Clone)]
pub struct HnswIndex {
    keys: KeyStore,
    layers: Vec<Layer>,
    /// Top-layer entry point.
    entry: u32,
    /// Node's maximum layer.
    node_level: Vec<u8>,
    /// Tombstones, one per dense slot.
    dead: Vec<bool>,
    dead_count: usize,
    m: usize,
    ef_construction: usize,
    /// Level-draw stream; persisted so online inserts stay deterministic.
    rng: Rng,
    level_mult: f64,
}

impl HnswIndex {
    pub fn build(keys: impl Into<KeyStore>, params: HnswParams) -> Self {
        let keys = keys.into();
        let n = keys.rows();
        assert!(n > 0, "HNSW needs at least one key");
        let mut idx = HnswIndex {
            keys,
            layers: vec![Layer { neighbors: Vec::new() }],
            entry: 0,
            node_level: Vec::with_capacity(n),
            dead: vec![false; n],
            dead_count: 0,
            m: params.m,
            ef_construction: params.ef_construction,
            rng: Rng::seed_from(params.seed),
            level_mult: 1.0 / (params.m as f64).ln(),
        };
        let mut visited = VisitedSet::new(n);
        for i in 0..n {
            idx.insert_node(i, &mut visited);
        }
        idx
    }

    /// Restore from a snapshot stream over the group's restored key store
    /// (the inverse of [`VectorIndex::save_state`]): the layered adjacency,
    /// node levels, entry point, tombstones and the level-draw RNG stream
    /// come back verbatim, so searches are bit-identical and post-restore
    /// inserts draw the same levels the source session would have.
    pub(crate) fn load_state(
        keys: KeyStore,
        r: &mut crate::store::codec::SnapReader<'_>,
    ) -> anyhow::Result<HnswIndex> {
        let m = r.usize()?;
        let ef_construction = r.usize()?;
        let rng_state = r.u64()?;
        let entry = r.u32()?;
        let node_level = r.bytes()?;
        let dead_bytes = r.bytes()?;
        let (dead, dead_count) = super::dead_from_bytes(&dead_bytes, keys.rows())
            .ok_or_else(|| anyhow::anyhow!("hnsw snapshot: tombstone set != store rows"))?;
        let n_layers = r.usize()?;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let n_nodes = r.usize()?;
            let mut neighbors = Vec::with_capacity(n_nodes);
            for _ in 0..n_nodes {
                neighbors.push(r.u32s()?);
            }
            layers.push(Layer { neighbors });
        }
        anyhow::ensure!(!layers.is_empty(), "hnsw snapshot: no layers");
        anyhow::ensure!(m >= 2, "hnsw snapshot: degenerate degree bound {m}");
        // Bounds validation (the codec's per-field sanity contract): a
        // corrupted snapshot must fail the restore, not panic the replica
        // worker on its first post-resume search.
        let n = keys.rows();
        anyhow::ensure!(n > 0, "hnsw snapshot: empty store");
        anyhow::ensure!(
            node_level.len() == n,
            "hnsw snapshot: node levels ({}) != store rows ({n})",
            node_level.len()
        );
        anyhow::ensure!((entry as usize) < n, "hnsw snapshot: entry {entry} out of bounds");
        anyhow::ensure!(
            node_level.iter().all(|&l| (l as usize) < layers.len()),
            "hnsw snapshot: node level exceeds layer count"
        );
        for layer in &layers {
            // Every layer spans the full node range (inserts resize all
            // layers in lockstep); a narrower layer would panic the
            // greedy descent on its first search.
            anyhow::ensure!(
                layer.neighbors.len() == n,
                "hnsw snapshot: layer width ({}) != store rows ({n})",
                layer.neighbors.len()
            );
            anyhow::ensure!(
                layer.neighbors.iter().flatten().all(|&v| (v as usize) < n),
                "hnsw snapshot: neighbor id out of bounds"
            );
        }
        Ok(HnswIndex {
            keys,
            layers,
            entry,
            node_level,
            dead,
            dead_count,
            m,
            ef_construction,
            rng: Rng::from_state(rng_state),
            level_mult: 1.0 / (m as f64).ln(),
        })
    }

    /// Geometric level draw (standard HNSW).
    fn draw_level(&mut self) -> usize {
        let u: f64 = self.rng.f64().max(1e-12);
        ((-u.ln() * self.level_mult) as usize).min(15)
    }

    /// Wire node `i` (whose key row must already be in `self.keys`) into
    /// the graph: greedy descent through the upper layers, then beam search
    /// + degree-bounded symmetric connect on layers `lvl..=0`.
    fn insert_node(&mut self, i: usize, visited: &mut VisitedSet) {
        debug_assert_eq!(self.node_level.len(), i, "nodes must be inserted in id order");
        let lvl = self.draw_level();
        self.node_level.push(lvl as u8);
        for layer in &mut self.layers {
            if layer.neighbors.len() <= i {
                layer.neighbors.resize(i + 1, Vec::new());
            }
        }
        while self.layers.len() <= lvl {
            self.layers.push(Layer { neighbors: vec![Vec::new(); i + 1] });
        }
        if i == 0 {
            self.entry = 0;
            return;
        }
        let q = self.keys.row(i).to_vec();
        let entry_lvl = self.node_level[self.entry as usize] as usize;

        // Greedy descent from the global entry down to lvl+1.
        let mut ep = self.entry;
        for l in (lvl + 1..=entry_lvl).rev() {
            ep = greedy_closest(&self.keys, &self.layers[l], &q, ep);
        }
        // Beam search + connect on layers lvl..=0.
        for l in (0..=lvl.min(entry_lvl)).rev() {
            let w = beam_search(
                &self.keys,
                &self.layers[l],
                &q,
                &[ep],
                self.ef_construction,
                visited,
            )
            .0;
            let m_l = if l == 0 { self.m * 2 } else { self.m };
            let selected = select_neighbors(&w, m_l);
            for &nb in &selected {
                self.layers[l].neighbors[i].push(nb);
                self.layers[l].neighbors[nb as usize].push(i as u32);
                // Prune over-full neighbor lists.
                if self.layers[l].neighbors[nb as usize].len() > m_l {
                    let cands: Vec<Cand> = self.layers[l].neighbors[nb as usize]
                        .iter()
                        .map(|&x| Cand {
                            sim: dot(self.keys.row(nb as usize), self.keys.row(x as usize)),
                            id: x,
                        })
                        .collect();
                    self.layers[l].neighbors[nb as usize] = select_neighbors(&cands, m_l);
                }
            }
            if let Some(best) = selected.first() {
                ep = *best;
            }
        }
        // A node above the current top becomes the new entry point.
        if lvl > entry_lvl {
            self.entry = i as u32;
        }
    }

    /// Beam search on the bottom layer with explicit ef; returns candidates
    /// best-first plus the scan count. Dead nodes are traversed (their
    /// edges were re-linked away, but a stale path may still touch them)
    /// yet filtered out by the caller.
    fn search_layer0(&self, query: &[f32], ef: usize) -> (Vec<Cand>, usize) {
        let mut visited = VisitedSet::new(self.keys.rows());
        let mut scanned = 0usize;
        // Descend upper layers greedily.
        let mut ep = self.entry;
        for l in (1..self.layers.len()).rev() {
            ep = greedy_closest_counted(&self.keys, &self.layers[l], query, ep, &mut scanned);
        }
        let (mut w, s) = beam_search(&self.keys, &self.layers[0], query, &[ep], ef, &mut visited);
        scanned += s;
        w.sort_by(|a, b| b.cmp(a));
        (w, scanned)
    }

    /// Re-link the graph around freshly tombstoned nodes: on every layer,
    /// each live node that lost a neighbor merges that neighbor's live
    /// out-edges into its candidate set and re-selects under the layer's
    /// degree bound; the dead nodes' own adjacency is then cleared.
    ///
    /// Only nodes *adjacent to the fresh batch* are re-selected — edges
    /// are wired symmetrically at insert time, so a dead node's own list
    /// names (almost) every node pointing at it; the rare asymmetric
    /// stale edge left by pruning merely makes a search score one cleared
    /// dead node (a filtered dead end), it cannot corrupt results. This
    /// keeps a small eviction batch O(batch × degree²), not O(n).
    fn relink_around_dead(&mut self, fresh: &[u32]) {
        for l in 0..self.layers.len() {
            let m_l = if l == 0 { self.m * 2 } else { self.m };
            let layer_len = self.layers[l].neighbors.len();
            // Live nodes that appear in a freshly-dead node's adjacency.
            let mut affected: Vec<u32> = Vec::new();
            for &r in fresh {
                if (r as usize) < layer_len {
                    for &u in &self.layers[l].neighbors[r as usize] {
                        if !self.dead[u as usize] {
                            affected.push(u);
                        }
                    }
                }
            }
            affected.sort_unstable();
            affected.dedup();
            let mut updates: Vec<(usize, Vec<u32>)> = Vec::new();
            for &au in &affected {
                let u = au as usize;
                let adj = &self.layers[l].neighbors[u];
                if !adj.iter().any(|&v| self.dead[v as usize]) {
                    continue;
                }
                // Candidates: surviving neighbors + the lost neighbors'
                // live out-edges (bridging the hole).
                let mut cands: Vec<Cand> = Vec::new();
                for &v in adj {
                    if self.dead[v as usize] {
                        for &w in &self.layers[l].neighbors[v as usize] {
                            if !self.dead[w as usize] && w as usize != u {
                                cands.push(Cand {
                                    sim: dot(self.keys.row(u), self.keys.row(w as usize)),
                                    id: w,
                                });
                            }
                        }
                    } else {
                        cands.push(Cand {
                            sim: dot(self.keys.row(u), self.keys.row(v as usize)),
                            id: v,
                        });
                    }
                }
                updates.push((u, select_neighbors(&cands, m_l)));
            }
            for (u, list) in updates {
                self.layers[l].neighbors[u] = list;
            }
            for &r in fresh {
                if (r as usize) < layer_len {
                    self.layers[l].neighbors[r as usize].clear();
                }
            }
        }
        // Entry repair: the beam must start from a live node.
        if self.dead.get(self.entry as usize).copied().unwrap_or(false) {
            let mut best: Option<usize> = None;
            for i in 0..self.node_level.len() {
                if self.dead[i] {
                    continue;
                }
                if best.map(|b| self.node_level[i] > self.node_level[b]).unwrap_or(true) {
                    best = Some(i);
                }
            }
            if let Some(b) = best {
                self.entry = b as u32;
            }
        }
    }
}

/// Greedy hill-climb to the most similar node on a layer.
fn greedy_closest(keys: &KeyStore, layer: &Layer, q: &[f32], start: u32) -> u32 {
    let mut scanned = 0;
    greedy_closest_counted(keys, layer, q, start, &mut scanned)
}

fn greedy_closest_counted(
    keys: &KeyStore,
    layer: &Layer,
    q: &[f32],
    start: u32,
    scanned: &mut usize,
) -> u32 {
    let mut cur = start;
    let mut cur_sim = keys.score(q, cur as usize);
    *scanned += 1;
    let mut sims: Vec<f32> = Vec::new();
    loop {
        // Batch-score the whole neighbor list: one kernel dispatch per
        // hop instead of one per edge.
        let nbs = &layer.neighbors[cur as usize];
        sims.clear();
        keys.score_ids(q, nbs, &mut sims);
        *scanned += nbs.len();
        let mut improved = false;
        for (&nb, &s) in nbs.iter().zip(sims.iter()) {
            if s > cur_sim {
                cur_sim = s;
                cur = nb;
                improved = true;
            }
        }
        if !improved {
            return cur;
        }
    }
}

/// Standard HNSW beam search over one layer; returns up to `ef` candidates
/// (unsorted) and the number of similarity computations. Neighbor lists
/// are scored as a batch against the store's scan tier (quantized mirror
/// when built): every unvisited neighbor was scored one-at-a-time before
/// too, so batching changes latency, never results.
fn beam_search(
    keys: &KeyStore,
    layer: &Layer,
    q: &[f32],
    entries: &[u32],
    ef: usize,
    visited: &mut VisitedSet,
) -> (Vec<Cand>, usize) {
    visited.clear();
    let mut scanned = 0usize;
    let mut frontier: BinaryHeap<Cand> = BinaryHeap::new(); // best-first
    let mut results: BinaryHeap<RevCand> = BinaryHeap::new(); // worst-first
    let mut batch: Vec<u32> = Vec::new();
    let mut sims: Vec<f32> = Vec::new();

    for &e in entries {
        if visited.insert(e as usize) {
            let sim = keys.score(q, e as usize);
            scanned += 1;
            frontier.push(Cand { sim, id: e });
            results.push(RevCand(Cand { sim, id: e }));
        }
    }
    while let Some(c) = frontier.pop() {
        let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
        if c.sim < worst && results.len() >= ef {
            break;
        }
        batch.clear();
        for &nb in &layer.neighbors[c.id as usize] {
            if visited.insert(nb as usize) {
                batch.push(nb);
            }
        }
        sims.clear();
        keys.score_ids(q, &batch, &mut sims);
        scanned += batch.len();
        for (&nb, &sim) in batch.iter().zip(sims.iter()) {
            let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
            if results.len() < ef || sim > worst {
                frontier.push(Cand { sim, id: nb });
                results.push(RevCand(Cand { sim, id: nb }));
                if results.len() > ef {
                    results.pop();
                }
            }
        }
    }
    (results.into_iter().map(|r| r.0).collect(), scanned)
}

/// Simple neighbor selection: keep the `m` most similar candidates. (The
/// full RNG-style diversity heuristic lives in `roargraph::prune`, where it
/// matters most; plain top-m matches hnswlib's default for IP.)
fn select_neighbors(cands: &[Cand], m: usize) -> Vec<u32> {
    let mut sorted: Vec<Cand> = cands.to_vec();
    sorted.sort_by(|a, b| b.cmp(a));
    sorted.dedup_by_key(|c| c.id);
    sorted.into_iter().take(m).map(|c| c.id).collect()
}

impl VectorIndex for HnswIndex {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn tombstones(&self) -> usize {
        self.dead_count
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> SearchResult {
        if self.dead_count >= self.keys.rows() {
            return SearchResult::default();
        }
        let ef = params.ef.max(k);
        let (cands, scanned) = self.search_layer0(query, ef);
        let live: Vec<&Cand> = cands.iter().filter(|c| !self.dead[c.id as usize]).collect();
        SearchResult {
            ids: live.iter().take(k).map(|c| c.id).collect(),
            scores: live.iter().take(k).map(|c| c.sim).collect(),
            scanned,
        }
    }

    fn name(&self) -> &'static str {
        "HNSW"
    }

    fn memory_bytes(&self) -> usize {
        // Key store bytes are charged once per GQA group by the owner.
        self.layers
            .iter()
            .map(|l| l.neighbors.iter().map(|n| n.len() * 4 + 24).sum::<usize>())
            .sum::<usize>()
            + self.node_level.len()
            + self.dead.len()
            + std::mem::size_of::<Self>()
    }

    fn supports_insert(&self) -> bool {
        true
    }

    /// Online insert = the build-time wiring, one node at a time, over the
    /// grown key store.
    fn insert_batch(
        &mut self,
        keys: KeyStore,
        new: Range<usize>,
        _ctx: &InsertContext<'_>,
    ) -> bool {
        debug_assert_eq!(new.end, keys.rows());
        debug_assert_eq!(new.start, self.keys.rows());
        self.keys = keys;
        self.dead.resize(self.keys.rows(), false);
        let mut visited = VisitedSet::new(self.keys.rows());
        for i in new {
            self.insert_node(i, &mut visited);
        }
        true
    }

    fn supports_remove(&self) -> bool {
        true
    }

    fn remove_batch(&mut self, ids: &[u32]) -> bool {
        let mut fresh: Vec<u32> = Vec::new();
        for &id in ids {
            let i = id as usize;
            if i < self.dead.len() && !self.dead[i] {
                self.dead[i] = true;
                self.dead_count += 1;
                fresh.push(id);
            }
        }
        if !fresh.is_empty() {
            self.relink_around_dead(&fresh);
        }
        true
    }

    fn supports_remap(&self) -> bool {
        true
    }

    fn scan_quantized(&self) -> bool {
        self.keys.is_quantized()
    }

    fn supports_exact_rerank(&self) -> bool {
        true
    }

    fn score_exact(&self, query: &[f32], id: u32) -> f32 {
        self.keys.score_exact(query, id as usize)
    }

    fn score_exact_batch(&self, query: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        self.keys.score_ids_exact(query, ids, out);
    }

    fn dead_ids(&self) -> Vec<u32> {
        super::collect_dead(&self.dead)
    }

    /// Relabel the graph in place: every adjacency list, the node levels,
    /// and the entry point are renumbered through the plan; edges into
    /// reclaimed nodes vanish (removal already re-linked each dead node's
    /// neighborhood, so only rare pruning-stale edges are lost). The
    /// surviving graph structure is bit-identical modulo the renumbering,
    /// so search results over live rows are preserved exactly.
    fn remap_dense(&mut self, plan: &RemapPlan) -> bool {
        if plan.old_to_new.len() != self.keys.rows()
            || plan.store.rows() != plan.new_len
            || plan.new_len == 0
        {
            return false;
        }
        let (dead, dead_count) = super::remap_dead(&self.dead, plan);
        for layer in &mut self.layers {
            let mut neighbors: Vec<Vec<u32>> = vec![Vec::new(); plan.new_len];
            for (old, adj) in layer.neighbors.iter().enumerate() {
                let Some(new) = plan.map(old as u32) else { continue };
                let list = &mut neighbors[new as usize];
                list.reserve(adj.len());
                for &nb in adj {
                    if let Some(nn) = plan.map(nb) {
                        list.push(nn);
                    }
                }
            }
            layer.neighbors = neighbors;
        }
        let mut node_level = vec![0u8; plan.new_len];
        for (old, &lvl) in self.node_level.iter().enumerate() {
            if let Some(new) = plan.map(old as u32) {
                node_level[new as usize] = lvl;
            }
        }
        // Entry repair mirrors `relink_around_dead`: the entry is live
        // after removal, so it normally just renumbers; if the planner
        // dropped it anyway, fall back to the highest live survivor.
        let entry = plan.map(self.entry).unwrap_or_else(|| {
            let mut best = 0usize;
            for i in 0..plan.new_len {
                if !dead[i] && (dead[best] || node_level[i] > node_level[best]) {
                    best = i;
                }
            }
            best as u32
        });
        self.keys = plan.store.clone();
        self.node_level = node_level;
        self.entry = entry;
        self.dead = dead;
        self.dead_count = dead_count;
        true
    }

    fn supports_save(&self) -> bool {
        true
    }

    fn family_tag(&self) -> u8 {
        super::FAMILY_HNSW
    }

    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        w.usize(self.m)?;
        w.usize(self.ef_construction)?;
        w.u64(self.rng.state())?;
        w.u32(self.entry)?;
        w.bytes(&self.node_level)?;
        w.bytes(&super::dead_to_bytes(&self.dead))?;
        w.usize(self.layers.len())?;
        for layer in &self.layers {
            w.usize(layer.neighbors.len())?;
            for adj in &layer.neighbors {
                w.u32s(adj)?;
            }
        }
        Ok(())
    }

    fn clone_index(&self) -> Box<dyn VectorIndex> {
        Box::new(self.clone())
    }
}

impl HnswIndex {
    /// Max out-degree parameter (diagnostics).
    pub fn m(&self) -> usize {
        self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{exact_topk_store, InsertContext};
    use crate::tensor::Matrix;

    use crate::util::rng::Rng;

    fn random_keys(n: usize, d: usize, seed: u64) -> KeyStore {
        let mut rng = Rng::seed_from(seed);
        KeyStore::from_matrix(Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5))
    }

    #[test]
    fn in_distribution_recall_high() {
        let keys = random_keys(2000, 16, 11);
        let idx = HnswIndex::build(keys.clone(), HnswParams::default());
        // K->K queries (in-distribution): recall@10 should be high.
        let mut total = 0.0;
        let nq = 20;
        for qi in 0..nq {
            let q = keys.row(qi * 17).to_vec();
            let truth = exact_topk_store(&keys, &q, 10);
            let r = idx.search(&q, 10, &SearchParams { ef: 128, nprobe: 0 });
            total += r.recall_against(&truth);
        }
        let recall = total / nq as f32;
        assert!(recall > 0.85, "K->K recall too low: {recall}");
    }

    #[test]
    fn scanned_less_than_n_for_small_ef() {
        let keys = random_keys(4000, 16, 13);
        let idx = HnswIndex::build(keys, HnswParams::default());
        let q = vec![0.3f32; 16];
        let r = idx.search(&q, 10, &SearchParams { ef: 32, nprobe: 0 });
        assert!(r.scanned < 4000, "HNSW should scan a fraction: {}", r.scanned);
        assert_eq!(r.ids.len(), 10);
    }

    #[test]
    fn ef_monotone_recall() {
        let keys = random_keys(1500, 8, 17);
        let idx = HnswIndex::build(keys.clone(), HnswParams::default());
        let q = keys.row(3).to_vec();
        let truth = exact_topk_store(&keys, &q, 10);
        let lo = idx.search(&q, 10, &SearchParams { ef: 10, nprobe: 0 }).recall_against(&truth);
        let hi = idx.search(&q, 10, &SearchParams { ef: 400, nprobe: 0 }).recall_against(&truth);
        assert!(hi >= lo);
        assert!(hi > 0.85, "high-ef recall too low: {hi}");
    }

    #[test]
    fn single_node_graph() {
        let keys = KeyStore::from_matrix(Matrix::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]));
        let idx = HnswIndex::build(keys, HnswParams::default());
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0], 5, &SearchParams::default());
        assert_eq!(r.ids, vec![0]);
    }

    #[test]
    fn insert_grows_from_single_node() {
        let keys = KeyStore::from_matrix(Matrix::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]));
        let mut idx = HnswIndex::build(keys.clone(), HnswParams::default());
        let mut batch = Matrix::zeros(0, 4);
        batch.push_row(&[0.0, 1.0, 0.0, 0.0]);
        batch.push_row(&[0.0, 0.0, 1.0, 0.0]);
        let grown = keys.append_rows(batch);
        assert!(idx.insert_batch(grown, 1..3, &InsertContext::none()));
        let r = idx.search(&[0.0, 0.0, 1.0, 0.0], 1, &SearchParams::default());
        assert_eq!(r.ids, vec![2]);
        let all = idx.search(&[0.5, 0.5, 0.5, 0.0], 3, &SearchParams { ef: 16, nprobe: 0 });
        assert_eq!(all.ids.len(), 3, "all nodes reachable after insert");
    }

    #[test]
    fn inserted_half_matches_rebuilt_recall() {
        // Build on the first half, insert the second half, and require
        // recall@10 close to a from-scratch build over everything.
        let all = random_keys(2000, 16, 29);
        let half = KeyStore::from_matrix(Matrix::from_fn(1000, 16, |r, c| all.row(r)[c]));
        let mut idx = HnswIndex::build(half, HnswParams::default());
        assert!(idx.insert_batch(all.clone(), 1000..2000, &InsertContext::none()));
        let rebuilt = HnswIndex::build(all.clone(), HnswParams::default());
        let params = SearchParams { ef: 128, nprobe: 0 };
        let (mut rec_ins, mut rec_reb) = (0.0f32, 0.0f32);
        let nq = 20;
        for qi in 0..nq {
            let q = all.row(qi * 83 + 7).to_vec();
            let truth = exact_topk_store(&all, &q, 10);
            rec_ins += idx.search(&q, 10, &params).recall_against(&truth);
            rec_reb += rebuilt.search(&q, 10, &params).recall_against(&truth);
        }
        rec_ins /= nq as f32;
        rec_reb /= nq as f32;
        assert!(
            rec_ins >= rec_reb - 0.05,
            "insert path lost recall: insert {rec_ins} vs rebuild {rec_reb}"
        );
    }

    #[test]
    fn removed_nodes_unreachable_and_relink_preserves_coverage() {
        let keys = random_keys(1200, 16, 31);
        let mut idx = HnswIndex::build(keys.clone(), HnswParams::default());
        let removed: Vec<u32> = (0..1200).step_by(5).map(|i| i as u32).collect();
        assert!(idx.remove_batch(&removed));
        assert_eq!(idx.tombstones(), removed.len());
        assert_eq!(idx.live_len(), 1200 - removed.len());
        // No tombstoned id is ever returned, even under an exhaustive beam.
        let r = idx.search(&vec![0.1f32; 16], 1200, &SearchParams { ef: 1200, nprobe: 0 });
        for id in &r.ids {
            assert!(id % 5 != 0, "tombstoned id {id} returned");
        }
        // Re-link must keep (nearly) every live node reachable.
        assert!(
            r.ids.len() >= (idx.live_len() * 99) / 100,
            "re-link lost reachability: {} of {}",
            r.ids.len(),
            idx.live_len()
        );
    }

    #[test]
    fn remap_relabels_graph_and_preserves_results() {
        let keys = random_keys(800, 16, 43);
        let mut idx = HnswIndex::build(keys.clone(), HnswParams::default());
        let removed: Vec<u32> = (0..800).step_by(4).map(|i| i as u32).collect();
        assert!(idx.remove_batch(&removed));
        // Pre-remap results in old dense ids, for a panel of queries.
        let params = SearchParams { ef: 128, nprobe: 0 };
        let panel: Vec<Vec<f32>> = (0..10).map(|qi| keys.row(qi * 67 + 1).to_vec()).collect();
        let pre: Vec<Vec<u32>> = panel.iter().map(|q| idx.search(q, 10, &params).ids).collect();
        let (plan, keep) = RemapPlan::from_dead(&removed, &keys, 1).expect("plan must build");
        assert_eq!(keep.len(), 600);
        assert!(idx.supports_remap());
        assert!(idx.remap_dense(&plan));
        assert_eq!(idx.len(), keep.len());
        assert_eq!(idx.tombstones(), 0);
        // Pure relabeling: the surviving graph is identical modulo rare
        // pruning-stale edges into dead transit nodes (which occupied
        // beam slots pre-remap and vanish post-remap), so searches must
        // return (near-)exactly the renumbered pre-remap results.
        for (q, old_ids) in panel.iter().zip(pre.iter()) {
            let post = idx.search(q, 10, &params).ids;
            let expect: Vec<u32> = old_ids.iter().map(|&o| plan.map(o).unwrap()).collect();
            for &id in &post {
                assert!((id as usize) < keep.len(), "post-remap id {id} out of range");
            }
            let hits = post.iter().filter(|id| expect.contains(id)).count();
            assert!(
                hits * 10 >= expect.len() * 9,
                "remap changed search results: {hits}/{} overlap",
                expect.len()
            );
        }
        // Inserts keep working in the compacted space.
        let grown = plan.store.append_rows(Matrix::from_fn(4, 16, |r, c| (r + c) as f32 * 0.1));
        let total = grown.rows();
        assert!(idx.insert_batch(grown, keep.len()..total, &InsertContext::none()));
        assert_eq!(idx.len(), total);
    }

    #[test]
    fn remove_entry_point_still_searches() {
        let keys = random_keys(300, 8, 37);
        let mut idx = HnswIndex::build(keys.clone(), HnswParams::default());
        let entry = idx.entry;
        assert!(idx.remove_batch(&[entry]));
        assert!(!idx.dead[idx.entry as usize], "entry must be repaired to a live node");
        let r = idx.search(&vec![0.2f32; 8], 10, &SearchParams { ef: 64, nprobe: 0 });
        assert_eq!(r.ids.len(), 10);
        assert!(!r.ids.contains(&entry));
    }

    #[test]
    fn remove_everything_returns_empty() {
        let keys = random_keys(50, 8, 41);
        let mut idx = HnswIndex::build(keys, HnswParams::default());
        let all: Vec<u32> = (0..50).collect();
        assert!(idx.remove_batch(&all));
        let r = idx.search(&[0.0; 8], 10, &SearchParams::default());
        assert!(r.ids.is_empty());
        assert_eq!(idx.live_len(), 0);
    }
}
