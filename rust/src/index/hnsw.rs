//! HNSW proximity graph (Malkov & Yashunin) under inner-product similarity.
//!
//! Built purely from key/key closeness — exactly the construction the paper
//! shows breaking down on Q→K searches (Fig 3a: "graph-based HNSW falls
//! into a local optimum"), because edges reflect the key distribution while
//! decode queries come from the OOD query distribution.

use super::{InsertContext, KeyStore, SearchParams, SearchResult, VectorIndex, VisitedSet};
use crate::tensor::dot;

use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::ops::Range;

/// Candidate ordered by similarity (max-heap => best first).
#[derive(Copy, Clone)]
struct Cand {
    sim: f32,
    id: u32,
}
impl PartialEq for Cand {
    fn eq(&self, o: &Self) -> bool {
        self.sim == o.sim && self.id == o.id
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Cand {
    fn cmp(&self, o: &Self) -> Ordering {
        self.sim.total_cmp(&o.sim).then(self.id.cmp(&o.id))
    }
}

/// Reversed ordering (min-heap on similarity) for result frontiers.
#[derive(Copy, Clone)]
struct RevCand(Cand);
impl PartialEq for RevCand {
    fn eq(&self, o: &Self) -> bool {
        self.0 == o.0
    }
}
impl Eq for RevCand {}
impl PartialOrd for RevCand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for RevCand {
    fn cmp(&self, o: &Self) -> Ordering {
        o.0.cmp(&self.0)
    }
}

/// Build-time parameters.
#[derive(Clone, Copy, Debug)]
pub struct HnswParams {
    /// Max out-degree on layers > 0 (layer 0 uses 2M).
    pub m: usize,
    /// Construction beam width.
    pub ef_construction: usize,
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 16, ef_construction: 100, seed: 0 }
    }
}

struct Layer {
    /// Adjacency: `neighbors[id]` is the out-edge list of `id`.
    neighbors: Vec<Vec<u32>>,
}

/// Hierarchical navigable small-world graph. Construction is genuinely
/// incremental (one [`HnswIndex::insert_node`] per key), which is also the
/// online-maintenance path: decoded keys folded in through
/// [`VectorIndex::insert_batch`] go through the exact same wiring as
/// build-time keys, so insert-then-search matches a from-scratch rebuild up
/// to the level draws.
pub struct HnswIndex {
    keys: KeyStore,
    layers: Vec<Layer>,
    /// Top-layer entry point.
    entry: u32,
    /// Node's maximum layer.
    node_level: Vec<u8>,
    m: usize,
    ef_construction: usize,
    /// Level-draw stream; persisted so online inserts stay deterministic.
    rng: Rng,
    level_mult: f64,
}

impl HnswIndex {
    pub fn build(keys: KeyStore, params: HnswParams) -> Self {
        let n = keys.rows();
        assert!(n > 0, "HNSW needs at least one key");
        let mut idx = HnswIndex {
            keys,
            layers: vec![Layer { neighbors: Vec::new() }],
            entry: 0,
            node_level: Vec::with_capacity(n),
            m: params.m,
            ef_construction: params.ef_construction,
            rng: Rng::seed_from(params.seed),
            level_mult: 1.0 / (params.m as f64).ln(),
        };
        let mut visited = VisitedSet::new(n);
        for i in 0..n {
            idx.insert_node(i, &mut visited);
        }
        idx
    }

    /// Geometric level draw (standard HNSW).
    fn draw_level(&mut self) -> usize {
        let u: f64 = self.rng.f64().max(1e-12);
        ((-u.ln() * self.level_mult) as usize).min(15)
    }

    /// Wire node `i` (whose key row must already be in `self.keys`) into
    /// the graph: greedy descent through the upper layers, then beam search
    /// + degree-bounded symmetric connect on layers `lvl..=0`.
    fn insert_node(&mut self, i: usize, visited: &mut VisitedSet) {
        debug_assert_eq!(self.node_level.len(), i, "nodes must be inserted in id order");
        let lvl = self.draw_level();
        self.node_level.push(lvl as u8);
        for layer in &mut self.layers {
            if layer.neighbors.len() <= i {
                layer.neighbors.resize(i + 1, Vec::new());
            }
        }
        while self.layers.len() <= lvl {
            self.layers.push(Layer { neighbors: vec![Vec::new(); i + 1] });
        }
        if i == 0 {
            self.entry = 0;
            return;
        }
        let q = self.keys.row(i).to_vec();
        let entry_lvl = self.node_level[self.entry as usize] as usize;

        // Greedy descent from the global entry down to lvl+1.
        let mut ep = self.entry;
        for l in (lvl + 1..=entry_lvl).rev() {
            ep = greedy_closest(&self.keys, &self.layers[l], &q, ep);
        }
        // Beam search + connect on layers lvl..=0.
        for l in (0..=lvl.min(entry_lvl)).rev() {
            let w = beam_search(&self.keys, &self.layers[l], &q, &[ep], self.ef_construction, visited).0;
            let m_l = if l == 0 { self.m * 2 } else { self.m };
            let selected = select_neighbors(&self.keys, &w, m_l);
            for &nb in &selected {
                self.layers[l].neighbors[i].push(nb);
                self.layers[l].neighbors[nb as usize].push(i as u32);
                // Prune over-full neighbor lists.
                if self.layers[l].neighbors[nb as usize].len() > m_l {
                    let cands: Vec<Cand> = self.layers[l].neighbors[nb as usize]
                        .iter()
                        .map(|&x| Cand {
                            sim: dot(self.keys.row(nb as usize), self.keys.row(x as usize)),
                            id: x,
                        })
                        .collect();
                    self.layers[l].neighbors[nb as usize] =
                        select_neighbors(&self.keys, &cands, m_l);
                }
            }
            if let Some(best) = selected.first() {
                ep = *best;
            }
        }
        // A node above the current top becomes the new entry point.
        if lvl > entry_lvl {
            self.entry = i as u32;
        }
    }

    /// Beam search on the bottom layer with explicit ef; returns candidates
    /// best-first plus the scan count.
    fn search_layer0(&self, query: &[f32], ef: usize) -> (Vec<Cand>, usize) {
        let mut visited = VisitedSet::new(self.keys.rows());
        let mut scanned = 0usize;
        // Descend upper layers greedily.
        let mut ep = self.entry;
        for l in (1..self.layers.len()).rev() {
            ep = greedy_closest_counted(&self.keys, &self.layers[l], query, ep, &mut scanned);
        }
        let (mut w, s) = beam_search(&self.keys, &self.layers[0], query, &[ep], ef, &mut visited);
        scanned += s;
        w.sort_by(|a, b| b.cmp(a));
        (w, scanned)
    }
}

/// Greedy hill-climb to the most similar node on a layer.
fn greedy_closest(keys: &crate::tensor::Matrix, layer: &Layer, q: &[f32], start: u32) -> u32 {
    let mut scanned = 0;
    greedy_closest_counted(keys, layer, q, start, &mut scanned)
}

fn greedy_closest_counted(
    keys: &crate::tensor::Matrix,
    layer: &Layer,
    q: &[f32],
    start: u32,
    scanned: &mut usize,
) -> u32 {
    let mut cur = start;
    let mut cur_sim = dot(q, keys.row(cur as usize));
    *scanned += 1;
    loop {
        let mut improved = false;
        for &nb in &layer.neighbors[cur as usize] {
            let s = dot(q, keys.row(nb as usize));
            *scanned += 1;
            if s > cur_sim {
                cur_sim = s;
                cur = nb;
                improved = true;
            }
        }
        if !improved {
            return cur;
        }
    }
}

/// Standard HNSW beam search over one layer; returns up to `ef` candidates
/// (unsorted) and the number of similarity computations.
fn beam_search(
    keys: &crate::tensor::Matrix,
    layer: &Layer,
    q: &[f32],
    entries: &[u32],
    ef: usize,
    visited: &mut VisitedSet,
) -> (Vec<Cand>, usize) {
    visited.clear();
    let mut scanned = 0usize;
    let mut frontier: BinaryHeap<Cand> = BinaryHeap::new(); // best-first
    let mut results: BinaryHeap<RevCand> = BinaryHeap::new(); // worst-first

    for &e in entries {
        if visited.insert(e as usize) {
            let sim = dot(q, keys.row(e as usize));
            scanned += 1;
            frontier.push(Cand { sim, id: e });
            results.push(RevCand(Cand { sim, id: e }));
        }
    }
    while let Some(c) = frontier.pop() {
        let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
        if c.sim < worst && results.len() >= ef {
            break;
        }
        for &nb in &layer.neighbors[c.id as usize] {
            if visited.insert(nb as usize) {
                let sim = dot(q, keys.row(nb as usize));
                scanned += 1;
                let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
                if results.len() < ef || sim > worst {
                    frontier.push(Cand { sim, id: nb });
                    results.push(RevCand(Cand { sim, id: nb }));
                    if results.len() > ef {
                        results.pop();
                    }
                }
            }
        }
    }
    (results.into_iter().map(|r| r.0).collect(), scanned)
}

/// Simple neighbor selection: keep the `m` most similar candidates. (The
/// full RNG-style diversity heuristic lives in `roargraph::prune`, where it
/// matters most; plain top-m matches hnswlib's default for IP.)
fn select_neighbors(_keys: &crate::tensor::Matrix, cands: &[Cand], m: usize) -> Vec<u32> {
    let mut sorted: Vec<Cand> = cands.to_vec();
    sorted.sort_by(|a, b| b.cmp(a));
    sorted.dedup_by_key(|c| c.id);
    sorted.into_iter().take(m).map(|c| c.id).collect()
}

impl VectorIndex for HnswIndex {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> SearchResult {
        let ef = params.ef.max(k);
        let (cands, scanned) = self.search_layer0(query, ef);
        SearchResult {
            ids: cands.iter().take(k).map(|c| c.id).collect(),
            scores: cands.iter().take(k).map(|c| c.sim).collect(),
            scanned,
        }
    }

    fn name(&self) -> &'static str {
        "HNSW"
    }

    fn memory_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.neighbors.iter().map(|n| n.len() * 4 + 24).sum::<usize>())
            .sum::<usize>()
            + self.node_level.len()
            + std::mem::size_of::<Self>()
    }

    fn supports_insert(&self) -> bool {
        true
    }

    /// Online insert = the build-time wiring, one node at a time, over the
    /// grown key store.
    fn insert_batch(&mut self, keys: KeyStore, new: Range<usize>, _ctx: &InsertContext<'_>) -> bool {
        debug_assert_eq!(keys.cols(), self.keys.cols());
        debug_assert_eq!(new.end, keys.rows());
        debug_assert_eq!(new.start, self.keys.rows());
        self.keys = keys;
        let mut visited = VisitedSet::new(self.keys.rows());
        for i in new {
            self.insert_node(i, &mut visited);
        }
        true
    }
}

impl HnswIndex {
    /// Max out-degree parameter (diagnostics).
    pub fn m(&self) -> usize {
        self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::exact_topk;
    use crate::tensor::Matrix;
    
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn random_keys(n: usize, d: usize, seed: u64) -> KeyStore {
        let mut rng = Rng::seed_from(seed);
        Arc::new(Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5))
    }

    #[test]
    fn in_distribution_recall_high() {
        let keys = random_keys(2000, 16, 11);
        let idx = HnswIndex::build(keys.clone(), HnswParams::default());
        // K->K queries (in-distribution): recall@10 should be high.
        let mut total = 0.0;
        let nq = 20;
        for qi in 0..nq {
            let q = keys.row(qi * 17).to_vec();
            let truth = exact_topk(&keys, &q, 10);
            let r = idx.search(&q, 10, &SearchParams { ef: 128, nprobe: 0 });
            total += r.recall_against(&truth);
        }
        let recall = total / nq as f32;
        assert!(recall > 0.85, "K->K recall too low: {recall}");
    }

    #[test]
    fn scanned_less_than_n_for_small_ef() {
        let keys = random_keys(4000, 16, 13);
        let idx = HnswIndex::build(keys, HnswParams::default());
        let q = vec![0.3f32; 16];
        let r = idx.search(&q, 10, &SearchParams { ef: 32, nprobe: 0 });
        assert!(r.scanned < 4000, "HNSW should scan a fraction: {}", r.scanned);
        assert_eq!(r.ids.len(), 10);
    }

    #[test]
    fn ef_monotone_recall() {
        let keys = random_keys(1500, 8, 17);
        let idx = HnswIndex::build(keys.clone(), HnswParams::default());
        let q = keys.row(3).to_vec();
        let truth = exact_topk(&keys, &q, 10);
        let lo = idx.search(&q, 10, &SearchParams { ef: 10, nprobe: 0 }).recall_against(&truth);
        let hi = idx.search(&q, 10, &SearchParams { ef: 400, nprobe: 0 }).recall_against(&truth);
        assert!(hi >= lo);
        assert!(hi > 0.85, "high-ef recall too low: {hi}");
    }

    #[test]
    fn single_node_graph() {
        let keys = Arc::new(Matrix::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]));
        let idx = HnswIndex::build(keys, HnswParams::default());
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0], 5, &SearchParams::default());
        assert_eq!(r.ids, vec![0]);
    }

    #[test]
    fn insert_grows_from_single_node() {
        let keys = Arc::new(Matrix::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]));
        let mut idx = HnswIndex::build(keys.clone(), HnswParams::default());
        let mut grown = (*keys).clone();
        grown.push_row(&[0.0, 1.0, 0.0, 0.0]);
        grown.push_row(&[0.0, 0.0, 1.0, 0.0]);
        assert!(idx.insert_batch(Arc::new(grown), 1..3, &crate::index::InsertContext::none()));
        let r = idx.search(&[0.0, 0.0, 1.0, 0.0], 1, &SearchParams::default());
        assert_eq!(r.ids, vec![2]);
        let all = idx.search(&[0.5, 0.5, 0.5, 0.0], 3, &SearchParams { ef: 16, nprobe: 0 });
        assert_eq!(all.ids.len(), 3, "all nodes reachable after insert");
    }

    #[test]
    fn inserted_half_matches_rebuilt_recall() {
        // Build on the first half, insert the second half, and require
        // recall@10 close to a from-scratch build over everything.
        let all = random_keys(2000, 16, 29);
        let half = Arc::new(Matrix::from_fn(1000, 16, |r, c| all[(r, c)]));
        let mut idx = HnswIndex::build(half, HnswParams::default());
        assert!(idx.insert_batch(all.clone(), 1000..2000, &crate::index::InsertContext::none()));
        let rebuilt = HnswIndex::build(all.clone(), HnswParams::default());
        let params = SearchParams { ef: 128, nprobe: 0 };
        let (mut rec_ins, mut rec_reb) = (0.0f32, 0.0f32);
        let nq = 20;
        for qi in 0..nq {
            let q = all.row(qi * 83 + 7).to_vec();
            let truth = exact_topk(&all, &q, 10);
            rec_ins += idx.search(&q, 10, &params).recall_against(&truth);
            rec_reb += rebuilt.search(&q, 10, &params).recall_against(&truth);
        }
        rec_ins /= nq as f32;
        rec_reb /= nq as f32;
        assert!(
            rec_ins >= rec_reb - 0.05,
            "insert path lost recall: insert {rec_ins} vs rebuild {rec_reb}"
        );
    }
}
