//! The attention-aware vector index (§3.2 of the paper).
//!
//! Off-the-shelf indexes organise keys by key/key closeness, which is the
//! wrong geometry for attention: decode queries are strongly OOD relative
//! to the keys (Fig 3b). RetrievalAttention instead uses the *prefill query
//! vectors* — free training data drawn from exactly the distribution decode
//! queries will come from — to shape the graph:
//!
//! 1. **Bipartite KNN phase**: every prefill query is linked to its exact
//!    top-`kb` keys (computed on the GPU in the paper; blocked rayon
//!    brute force here).
//! 2. **Projection** (RoarGraph, Chen et al. 2024): query nodes are
//!    eliminated by connecting keys that are co-retrieved by the same
//!    query — the query's best key gets star edges to the rest of the
//!    list, plus chain edges between rank-adjacent keys. The resulting
//!    edges join keys that are close *from the query distribution's
//!    viewpoint*, not in raw key space.
//! 3. **Degree-bounded pruning**: per-node candidate lists are ranked by
//!    co-retrieval frequency then inner product and cut to `m`.
//! 4. **Connectivity repair**: BFS from the entry (key maximising inner
//!    product with the mean training query); unreachable nodes get edges
//!    from their best reachable neighbor within a sampled candidate set.
//!
//! Search is a plain best-first beam over the projected graph. Because the
//! edges already encode the query→key mapping, a decode query reaches its
//! true top-k scanning only 1–3% of keys (Fig 6).

use super::{KeyStore, SearchParams, SearchResult, VectorIndex, VisitedSet};
use crate::tensor::{argtopk, dot, Matrix};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Build-time parameters.
#[derive(Clone, Copy, Debug)]
pub struct RoarParams {
    /// Exact-KNN list length per training query (bipartite degree).
    pub kb: usize,
    /// Max out-degree after projection pruning.
    pub m: usize,
    /// Sample size for connectivity repair candidate sets.
    pub repair_sample: usize,
}

impl Default for RoarParams {
    fn default() -> Self {
        RoarParams { kb: 32, m: 32, repair_sample: 256 }
    }
}

/// Attention-aware projected bipartite graph index.
pub struct RoarGraph {
    keys: KeyStore,
    /// Flattened CSR adjacency (degree-bounded).
    offsets: Vec<u32>,
    edges: Vec<u32>,
    /// Entry points: keys closest (by IP) to the mean training query plus a
    /// few high-coverage nodes.
    entries: Vec<u32>,
}

#[derive(Copy, Clone)]
struct Cand {
    sim: f32,
    id: u32,
}
impl PartialEq for Cand {
    fn eq(&self, o: &Self) -> bool {
        self.sim == o.sim && self.id == o.id
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Cand {
    fn cmp(&self, o: &Self) -> Ordering {
        self.sim.total_cmp(&o.sim).then(self.id.cmp(&o.id))
    }
}

impl RoarGraph {
    /// Build from a key store and the prefill query matrix (`nq x d`).
    ///
    /// `queries` are *training* queries: in the serving stack these are the
    /// per-head query vectors captured during the prefill phase (§3.2).
    pub fn build(keys: KeyStore, queries: &Matrix, params: RoarParams) -> Self {
        let n = keys.rows();
        assert!(n > 0, "RoarGraph needs at least one key");
        assert!(queries.rows() > 0, "RoarGraph needs training queries (prefill Q vectors)");
        assert_eq!(queries.cols(), keys.cols(), "query/key dim mismatch");
        let kb = params.kb.min(n);

        // --- Phase 1: exact KNN from each training query to the keys. ---
        let knn: Vec<Vec<u32>> = crate::util::parallel::par_map_range(queries.rows(), |qi| {
            super::exact_topk(&keys, queries.row(qi), kb)
        });

        // --- Phase 2: project bipartite edges onto key-key edges. ---
        // Candidate lists with co-retrieval counts. For each query list
        // [k0, k1, ... ] (best first): star edges k0 <-> ki and chain edges
        // k(i) <-> k(i+1). Star edges spread reachability from the "anchor"
        // key; chain edges preserve the rank ordering the query induced.
        let mut cand: Vec<Vec<u32>> = vec![Vec::new(); n];
        for list in &knn {
            if list.len() < 2 {
                continue;
            }
            let anchor = list[0] as usize;
            for w in list.windows(2) {
                let (a, b) = (w[0] as usize, w[1] as usize);
                cand[a].push(w[1]);
                cand[b].push(w[0]);
            }
            for &other in &list[1..] {
                cand[anchor].push(other);
                cand[other as usize].push(list[0]);
            }
        }

        // --- Phase 3: rank candidates by (co-retrieval count, IP) and cut to m. ---
        let adjacency: Vec<Vec<u32>> = crate::util::parallel::par_map_range(n, |i| {
                let mut counts: std::collections::HashMap<u32, u32> = Default::default();
                for &c in &cand[i] {
                    if c as usize != i {
                        *counts.entry(c).or_insert(0) += 1;
                    }
                }
                let mut ranked: Vec<(u32, u32, f32)> = counts
                    .into_iter()
                    .map(|(id, cnt)| (id, cnt, dot(keys.row(i), keys.row(id as usize))))
                    .collect();
                ranked.sort_by(|a, b| b.1.cmp(&a.1).then(b.2.total_cmp(&a.2)));
                ranked.into_iter().take(params.m).map(|(id, _, _)| id).collect()
        });

        // --- Entry points: top keys by IP with the mean training query. ---
        let mean_q = crate::tensor::col_mean(queries);
        let entry_scores: Vec<f32> = (0..n).map(|i| dot(&mean_q, keys.row(i))).collect();
        let entries: Vec<u32> = argtopk(&entry_scores, 4.min(n)).into_iter().map(|i| i as u32).collect();

        let mut graph = RoarGraph { keys, offsets: Vec::new(), edges: Vec::new(), entries };
        let adjacency = graph.repair_connectivity(adjacency, params.repair_sample);
        graph.freeze(adjacency);
        graph
    }

    /// Make every node reachable from the entry set: BFS, then connect each
    /// unreachable node to its best (highest-IP) reachable node out of a
    /// deterministic sample, and symmetrically back.
    fn repair_connectivity(&self, mut adj: Vec<Vec<u32>>, sample: usize) -> Vec<Vec<u32>> {
        let n = adj.len();
        let mut reach = vec![false; n];
        let mut stack: Vec<u32> = self.entries.clone();
        for &e in &self.entries {
            reach[e as usize] = true;
        }
        while let Some(u) = stack.pop() {
            for &v in &adj[u as usize] {
                if !reach[v as usize] {
                    reach[v as usize] = true;
                    stack.push(v);
                }
            }
        }
        let reachable: Vec<u32> = (0..n as u32).filter(|&i| reach[i as usize]).collect();
        if reachable.is_empty() {
            return adj;
        }
        let step = (reachable.len() / sample.max(1)).max(1);
        for u in 0..n {
            if reach[u] {
                continue;
            }
            // Best reachable anchor in a strided sample.
            let mut best = reachable[0];
            let mut best_sim = f32::NEG_INFINITY;
            let mut j = 0;
            while j < reachable.len() {
                let r = reachable[j];
                let s = dot(self.keys.row(u), self.keys.row(r as usize));
                if s > best_sim {
                    best_sim = s;
                    best = r;
                }
                j += step;
            }
            adj[best as usize].push(u as u32);
            adj[u].push(best);
            // u (and anything hanging off it) is now reachable via best.
            let mut stack = vec![u as u32];
            reach[u] = true;
            while let Some(x) = stack.pop() {
                for &v in &adj[x as usize] {
                    if !reach[v as usize] {
                        reach[v as usize] = true;
                        stack.push(v);
                    }
                }
            }
        }
        adj
    }

    /// Flatten adjacency into CSR for cache-friendly traversal.
    fn freeze(&mut self, adj: Vec<Vec<u32>>) {
        let n = adj.len();
        self.offsets = Vec::with_capacity(n + 1);
        self.offsets.push(0);
        let total: usize = adj.iter().map(|a| a.len()).sum();
        self.edges = Vec::with_capacity(total);
        for a in adj {
            self.edges.extend_from_slice(&a);
            self.offsets.push(self.edges.len() as u32);
        }
    }

    #[inline]
    fn neighbors(&self, id: u32) -> &[u32] {
        &self.edges[self.offsets[id as usize] as usize..self.offsets[id as usize + 1] as usize]
    }

    /// Average out-degree (diagnostics / tests).
    pub fn avg_degree(&self) -> f32 {
        self.edges.len() as f32 / (self.offsets.len() - 1).max(1) as f32
    }
}

impl VectorIndex for RoarGraph {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> SearchResult {
        let ef = params.ef.max(k);
        let n = self.keys.rows();
        let mut visited = VisitedSet::new(n);
        visited.clear();
        let mut scanned = 0usize;
        let mut frontier: BinaryHeap<Cand> = BinaryHeap::new();
        let mut results: BinaryHeap<std::cmp::Reverse<Cand>> = BinaryHeap::new();

        for &e in &self.entries {
            if visited.insert(e as usize) {
                let sim = dot(query, self.keys.row(e as usize));
                scanned += 1;
                frontier.push(Cand { sim, id: e });
                results.push(std::cmp::Reverse(Cand { sim, id: e }));
            }
        }
        while let Some(c) = frontier.pop() {
            let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
            if results.len() >= ef && c.sim < worst {
                break;
            }
            for &nb in self.neighbors(c.id) {
                if visited.insert(nb as usize) {
                    let sim = dot(query, self.keys.row(nb as usize));
                    scanned += 1;
                    let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
                    if results.len() < ef || sim > worst {
                        frontier.push(Cand { sim, id: nb });
                        results.push(std::cmp::Reverse(Cand { sim, id: nb }));
                        if results.len() > ef {
                            results.pop();
                        }
                    }
                }
            }
        }
        let mut out: Vec<Cand> = results.into_iter().map(|r| r.0).collect();
        out.sort_by(|a, b| b.cmp(a));
        SearchResult {
            ids: out.iter().take(k).map(|c| c.id).collect(),
            scores: out.iter().take(k).map(|c| c.sim).collect(),
            scanned,
        }
    }

    fn name(&self) -> &'static str {
        "RetrievalAttention"
    }

    fn memory_bytes(&self) -> usize {
        self.offsets.len() * 4 + self.edges.len() * 4 + std::mem::size_of::<Self>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::exact_topk;
    
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// Simulated attention geometry: keys ~ N(0, I); queries live in a
    /// shifted, scaled subspace (OOD), like Q/K produced by different
    /// projection matrices.
    fn ood_setup(n: usize, nq: usize, d: usize, seed: u64) -> (KeyStore, Matrix) {
        let mut rng = Rng::seed_from(seed);
        let keys = Arc::new(Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5));
        // Queries: strong offset + anisotropic scale => OOD w.r.t. keys.
        let queries = Matrix::from_fn(nq, d, |_, c| {
            let base: f32 = rng.f32() - 0.5;
            base * if c % 2 == 0 { 3.0 } else { 0.3 } + if c < d / 4 { 2.0 } else { -1.0 }
        });
        (keys, queries)
    }

    #[test]
    fn ood_recall_beats_scan_budget() {
        let (keys, queries) = ood_setup(4000, 400, 16, 21);
        // Train on the first 300 queries, test on the remaining 100.
        let train = Matrix::from_fn(300, 16, |r, c| queries[(r, c)]);
        let idx = RoarGraph::build(keys.clone(), &train, RoarParams::default());
        let mut recall = 0.0;
        let mut scanned = 0usize;
        let ntest = 100;
        for t in 0..ntest {
            let q: Vec<f32> = (0..16).map(|c| queries[(300 + t, c)]).collect();
            let truth = exact_topk(&keys, &q, 10);
            let r = idx.search(&q, 10, &SearchParams { ef: 64, nprobe: 0 });
            recall += r.recall_against(&truth);
            scanned += r.scanned;
        }
        recall /= ntest as f32;
        let frac = scanned as f32 / (ntest * 4000) as f32;
        assert!(recall > 0.9, "OOD recall too low: {recall}");
        // The scan *fraction* shrinks with corpus size (beam work is ~ef*deg
        // regardless of n): at n=4000 a budget of ~20% is expected; the
        // paper's 1-3% figure at n=128K is asserted by the fig6 experiment
        // and the `index_search` bench.
        assert!(frac < 0.25, "scanned too much: {frac}");
    }

    #[test]
    fn all_nodes_reachable() {
        let (keys, queries) = ood_setup(500, 50, 8, 33);
        let idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        // Exhaustive beam must be able to visit everything.
        let q = vec![0.0f32; 8];
        let r = idx.search(&q, 500, &SearchParams { ef: 500, nprobe: 0 });
        assert_eq!(r.ids.len(), 500, "some nodes unreachable");
    }

    #[test]
    fn degree_bounded() {
        let (keys, queries) = ood_setup(1000, 200, 8, 5);
        let params = RoarParams { kb: 16, m: 8, repair_sample: 64 };
        let idx = RoarGraph::build(keys, &queries, params);
        // m + repair edges; allow slack of a few repair links.
        assert!(idx.avg_degree() <= 12.0, "avg degree too high: {}", idx.avg_degree());
    }

    #[test]
    fn single_key() {
        let keys = Arc::new(Matrix::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]));
        let queries = Matrix::from_vec(2, 4, vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let idx = RoarGraph::build(keys, &queries, RoarParams::default());
        let r = idx.search(&[0.5, 0.5, 0.0, 0.0], 3, &SearchParams::default());
        assert_eq!(r.ids, vec![0]);
    }
}
