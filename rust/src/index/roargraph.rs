//! The attention-aware vector index (§3.2 of the paper).
//!
//! Off-the-shelf indexes organise keys by key/key closeness, which is the
//! wrong geometry for attention: decode queries are strongly OOD relative
//! to the keys (Fig 3b). RetrievalAttention instead uses the *prefill query
//! vectors* — free training data drawn from exactly the distribution decode
//! queries will come from — to shape the graph:
//!
//! 1. **Bipartite KNN phase**: every prefill query is linked to its exact
//!    top-`kb` keys (computed on the GPU in the paper; blocked rayon
//!    brute force here).
//! 2. **Projection** (RoarGraph, Chen et al. 2024): query nodes are
//!    eliminated by connecting keys that are co-retrieved by the same
//!    query — the query's best key gets star edges to the rest of the
//!    list, plus chain edges between rank-adjacent keys. The resulting
//!    edges join keys that are close *from the query distribution's
//!    viewpoint*, not in raw key space.
//! 3. **Degree-bounded pruning**: per-node candidate lists are ranked by
//!    co-retrieval frequency then inner product and cut to `m`.
//! 4. **Connectivity repair**: BFS from the entry (key maximising inner
//!    product with the mean training query); unreachable nodes get edges
//!    from their best reachable neighbor within a sampled candidate set.
//!
//! Search is a plain best-first beam over the projected graph. Because the
//! edges already encode the query→key mapping, a decode query reaches its
//! true top-k scanning only 1–3% of keys (Fig 6).
//!
//! ## Online maintenance
//!
//! The base graph is frozen into CSR, but the index stays **online**
//! (RetroInfer-style): keys decoded after prefill are folded in through
//! [`VectorIndex::insert_batch`] with a *degree-bounded local repair*
//! instead of a rebuild. New keys are wired attention-aware — the recent
//! decode queries act as the bipartite training side (they are drawn from
//! exactly the distribution future decode queries come from): each recent
//! query's top-`kb` graph results and the batch keys it would retrieve are
//! projected star/chain style, candidates ranked by (co-retrieval count,
//! inner product) and cut to `m`. Reverse edges into frozen nodes live in a
//! patch table so the CSR never reallocates; every inserted node keeps a
//! protected edge from its primary anchor, preserving reachability under
//! pruning. After `rebuild_threshold` pending inserts the whole graph is
//! re-projected from the retained training queries, amortising the full
//! build.
//!
//! ## Deletion
//!
//! [`VectorIndex::remove_batch`] tombstones nodes FreshDiskANN-style: a
//! dead node is still *traversed* (its edges keep the graph connected so
//! the frozen CSR never needs in-edge surgery) but never *returned*, and
//! the dead node's live neighborhood is additionally bridged with
//! degree-bounded patch edges (the PR-1 [`RoarGraph::push_reverse_edge`]
//! machinery) so search quality does not decay around holes. Past a 25%
//! tombstone ratio the graph re-projects itself (the amortised rebuild),
//! keeping traversal cost proportional to the live set.

use super::{
    InsertContext, KeyStore, RemapPlan, SearchParams, SearchResult, VectorIndex, VisitedSet,
};
use crate::tensor::{argtopk, dot, Matrix};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};
use std::ops::Range;

/// Build-time parameters.
#[derive(Clone, Copy, Debug)]
pub struct RoarParams {
    /// Exact-KNN list length per training query (bipartite degree).
    pub kb: usize,
    /// Max out-degree after projection pruning.
    pub m: usize,
    /// Sample size for connectivity repair candidate sets.
    pub repair_sample: usize,
    /// Online inserts tolerated before a full re-projection; locally
    /// repaired inserts amortise against this.
    pub rebuild_threshold: usize,
}

impl Default for RoarParams {
    fn default() -> Self {
        RoarParams { kb: 32, m: 32, repair_sample: 256, rebuild_threshold: 4096 }
    }
}

/// Training queries retained for rebuilds (prefill subsample + recent
/// decode queries), capped so rebuild cost stays bounded.
const TRAIN_CAP: usize = 1024;

/// Attention-aware projected bipartite graph index.
#[derive(Clone)]
pub struct RoarGraph {
    keys: KeyStore,
    /// Flattened CSR adjacency over the frozen base nodes `[0, base_n)`.
    offsets: Vec<u32>,
    edges: Vec<u32>,
    /// Entry points: keys closest (by IP) to the mean training query plus a
    /// few high-coverage nodes.
    entries: Vec<u32>,
    params: RoarParams,
    /// Number of nodes covered by the CSR; ids ≥ `base_n` were inserted
    /// online and live in `extra`.
    base_n: usize,
    /// Extra out-edges of frozen nodes (reverse links to inserted nodes).
    patch: HashMap<u32, Vec<u32>>,
    /// Adjacency of inserted nodes, indexed by `id - base_n`.
    extra: Vec<Vec<u32>>,
    /// Per inserted node: the partner whose reverse edge is never pruned
    /// (keeps the node reachable from the base graph).
    primary_anchor: Vec<u32>,
    /// Retained training queries for amortised rebuilds.
    train: Matrix,
    /// Inserts since the last (re)build.
    pending: usize,
    /// Tombstones, one per dense slot: dead nodes are traversed (they keep
    /// the frozen CSR connected) but never returned.
    dead: Vec<bool>,
    dead_count: usize,
    /// `dead_count` at the last re-projection: dense ids are permanent, so
    /// the rebuild ratio must be measured against tombstones accumulated
    /// *since* then — otherwise one crossing of the threshold would make
    /// every later removal trigger a full rebuild forever.
    dead_at_rebuild: usize,
}

#[derive(Copy, Clone)]
struct Cand {
    sim: f32,
    id: u32,
}
impl PartialEq for Cand {
    fn eq(&self, o: &Self) -> bool {
        self.sim == o.sim && self.id == o.id
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Cand {
    fn cmp(&self, o: &Self) -> Ordering {
        self.sim.total_cmp(&o.sim).then(self.id.cmp(&o.id))
    }
}

impl RoarGraph {
    /// Build from a key store and the prefill query matrix (`nq x d`).
    ///
    /// `queries` are *training* queries: in the serving stack these are the
    /// per-head query vectors captured during the prefill phase (§3.2).
    pub fn build(keys: impl Into<KeyStore>, queries: &Matrix, params: RoarParams) -> Self {
        let keys: KeyStore = keys.into();
        let n = keys.rows();
        assert!(n > 0, "RoarGraph needs at least one key");
        assert!(queries.rows() > 0, "RoarGraph needs training queries (prefill Q vectors)");
        assert_eq!(queries.cols(), keys.cols(), "query/key dim mismatch");
        let kb = params.kb.min(n);

        // --- Phase 1: exact KNN from each training query to the keys. ---
        let knn: Vec<Vec<u32>> = crate::util::parallel::par_map_range(queries.rows(), |qi| {
            super::exact_topk_store(&keys, queries.row(qi), kb)
        });

        // --- Phase 2: project bipartite edges onto key-key edges. ---
        // Candidate lists with co-retrieval counts. For each query list
        // [k0, k1, ... ] (best first): star edges k0 <-> ki and chain edges
        // k(i) <-> k(i+1). Star edges spread reachability from the "anchor"
        // key; chain edges preserve the rank ordering the query induced.
        let mut cand: Vec<Vec<u32>> = vec![Vec::new(); n];
        for list in &knn {
            if list.len() < 2 {
                continue;
            }
            let anchor = list[0] as usize;
            for w in list.windows(2) {
                let (a, b) = (w[0] as usize, w[1] as usize);
                cand[a].push(w[1]);
                cand[b].push(w[0]);
            }
            for &other in &list[1..] {
                cand[anchor].push(other);
                cand[other as usize].push(list[0]);
            }
        }

        // --- Phase 3: rank candidates by (co-retrieval count, IP) and cut to m. ---
        let adjacency: Vec<Vec<u32>> = crate::util::parallel::par_map_range(n, |i| {
                let mut counts: std::collections::HashMap<u32, u32> = Default::default();
                for &c in &cand[i] {
                    if c as usize != i {
                        *counts.entry(c).or_insert(0) += 1;
                    }
                }
                let mut ranked: Vec<(u32, u32, f32)> = counts
                    .into_iter()
                    .map(|(id, cnt)| (id, cnt, dot(keys.row(i), keys.row(id as usize))))
                    .collect();
                ranked.sort_by(|a, b| b.1.cmp(&a.1).then(b.2.total_cmp(&a.2)));
                ranked.into_iter().take(params.m).map(|(id, _, _)| id).collect()
        });

        // --- Entry points: top keys by IP with the mean training query. ---
        let mean_q = crate::tensor::col_mean(queries);
        let entry_scores: Vec<f32> = (0..n).map(|i| dot(&mean_q, keys.row(i))).collect();
        let entries: Vec<u32> =
            argtopk(&entry_scores, 4.min(n)).into_iter().map(|i| i as u32).collect();

        // Retain a strided training subsample for amortised rebuilds.
        let train = queries.subsample_strided(TRAIN_CAP);

        let mut graph = RoarGraph {
            keys,
            offsets: Vec::new(),
            edges: Vec::new(),
            entries,
            params,
            base_n: n,
            patch: HashMap::new(),
            extra: Vec::new(),
            primary_anchor: Vec::new(),
            train,
            pending: 0,
            dead: vec![false; n],
            dead_count: 0,
            dead_at_rebuild: 0,
        };
        let adjacency = graph.repair_connectivity(adjacency, params.repair_sample);
        graph.freeze(adjacency);
        graph
    }

    /// Restore from a snapshot stream over the group's restored key store
    /// (the inverse of [`VectorIndex::save_state`]): the frozen CSR, the
    /// patch/extra overlays, the protected anchors, the retained training
    /// queries and the rebuild counters come back verbatim — no bipartite
    /// KNN phase and no re-projection on restore, and searches over the
    /// restored graph are bit-identical to the source session's.
    pub(crate) fn load_state(
        keys: KeyStore,
        r: &mut crate::store::codec::SnapReader<'_>,
    ) -> anyhow::Result<RoarGraph> {
        let params = RoarParams {
            kb: r.usize()?,
            m: r.usize()?,
            repair_sample: r.usize()?,
            rebuild_threshold: r.usize()?,
        };
        let base_n = r.usize()?;
        let offsets = r.u32s()?;
        let edges = r.u32s()?;
        let entries = r.u32s()?;
        anyhow::ensure!(
            offsets.len() == base_n + 1,
            "roargraph snapshot: CSR offsets ({}) != base nodes ({base_n}) + 1",
            offsets.len()
        );
        let n_patch = r.usize()?;
        let mut patch = HashMap::with_capacity(n_patch);
        for _ in 0..n_patch {
            let from = r.u32()?;
            patch.insert(from, r.u32s()?);
        }
        let n_extra = r.usize()?;
        let mut extra = Vec::with_capacity(n_extra);
        for _ in 0..n_extra {
            extra.push(r.u32s()?);
        }
        let primary_anchor = r.u32s()?;
        let train = r.matrix()?;
        let pending = r.usize()?;
        let dead_bytes = r.bytes()?;
        let (dead, dead_count) = super::dead_from_bytes(&dead_bytes, keys.rows())
            .ok_or_else(|| anyhow::anyhow!("roargraph snapshot: tombstone set != store rows"))?;
        let dead_at_rebuild = r.usize()?;
        anyhow::ensure!(
            base_n + extra.len() == keys.rows(),
            "roargraph snapshot: base ({base_n}) + online ({}) != store rows ({})",
            extra.len(),
            keys.rows()
        );
        // Bounds validation (the codec's per-field sanity contract): a
        // corrupted snapshot must fail the restore, not panic the replica
        // worker mid-traversal.
        let n = keys.rows();
        // A fully-tombstoned graph legally has no live entry point
        // (`fix_entries` found nothing to retain); otherwise the beam
        // must have somewhere to start.
        anyhow::ensure!(
            !entries.is_empty() || dead_count == n,
            "roargraph snapshot: no entry points"
        );
        anyhow::ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1])
                && offsets.last().map(|&e| e as usize == edges.len()).unwrap_or(false),
            "roargraph snapshot: CSR offsets are not a prefix sum of the edge list"
        );
        let in_bounds = |ids: &[u32]| ids.iter().all(|&v| (v as usize) < n);
        anyhow::ensure!(in_bounds(&edges), "roargraph snapshot: edge target out of bounds");
        anyhow::ensure!(in_bounds(&entries), "roargraph snapshot: entry out of bounds");
        anyhow::ensure!(
            patch.keys().all(|&k| (k as usize) < base_n)
                && patch.values().all(|v| in_bounds(v)),
            "roargraph snapshot: patch edge out of bounds"
        );
        anyhow::ensure!(
            extra.iter().all(|v| in_bounds(v)),
            "roargraph snapshot: online adjacency out of bounds"
        );
        anyhow::ensure!(
            primary_anchor.len() == extra.len()
                && primary_anchor.iter().all(|&a| a == u32::MAX || (a as usize) < n),
            "roargraph snapshot: anchor table invalid"
        );
        Ok(RoarGraph {
            keys,
            offsets,
            edges,
            entries,
            params,
            base_n,
            patch,
            extra,
            primary_anchor,
            train,
            pending,
            dead,
            dead_count,
            dead_at_rebuild,
        })
    }

    /// Make every node reachable from the entry set: BFS, then connect each
    /// unreachable node to its best (highest-IP) reachable node out of a
    /// deterministic sample, and symmetrically back.
    fn repair_connectivity(&self, mut adj: Vec<Vec<u32>>, sample: usize) -> Vec<Vec<u32>> {
        let n = adj.len();
        let mut reach = vec![false; n];
        let mut stack: Vec<u32> = self.entries.clone();
        for &e in &self.entries {
            reach[e as usize] = true;
        }
        while let Some(u) = stack.pop() {
            for &v in &adj[u as usize] {
                if !reach[v as usize] {
                    reach[v as usize] = true;
                    stack.push(v);
                }
            }
        }
        let reachable: Vec<u32> = (0..n as u32).filter(|&i| reach[i as usize]).collect();
        if reachable.is_empty() {
            return adj;
        }
        let step = (reachable.len() / sample.max(1)).max(1);
        for u in 0..n {
            if reach[u] {
                continue;
            }
            // Best reachable anchor in a strided sample.
            let mut best = reachable[0];
            let mut best_sim = f32::NEG_INFINITY;
            let mut j = 0;
            while j < reachable.len() {
                let r = reachable[j];
                let s = dot(self.keys.row(u), self.keys.row(r as usize));
                if s > best_sim {
                    best_sim = s;
                    best = r;
                }
                j += step;
            }
            adj[best as usize].push(u as u32);
            adj[u].push(best);
            // u (and anything hanging off it) is now reachable via best.
            let mut stack = vec![u as u32];
            reach[u] = true;
            while let Some(x) = stack.pop() {
                for &v in &adj[x as usize] {
                    if !reach[v as usize] {
                        reach[v as usize] = true;
                        stack.push(v);
                    }
                }
            }
        }
        adj
    }

    /// Flatten adjacency into CSR for cache-friendly traversal.
    fn freeze(&mut self, adj: Vec<Vec<u32>>) {
        let n = adj.len();
        self.offsets = Vec::with_capacity(n + 1);
        self.offsets.push(0);
        let total: usize = adj.iter().map(|a| a.len()).sum();
        self.edges = Vec::with_capacity(total);
        for a in adj {
            self.edges.extend_from_slice(&a);
            self.offsets.push(self.edges.len() as u32);
        }
    }

    #[inline]
    fn base_neighbors(&self, id: u32) -> &[u32] {
        if (id as usize) < self.base_n {
            &self.edges[self.offsets[id as usize] as usize..self.offsets[id as usize + 1] as usize]
        } else {
            &[]
        }
    }

    /// Gather the full out-edge list of `id` (CSR base + patch/extra).
    fn collect_neighbors(&self, id: u32, out: &mut Vec<u32>) {
        out.clear();
        out.extend_from_slice(self.base_neighbors(id));
        if (id as usize) < self.base_n {
            if let Some(p) = self.patch.get(&id) {
                out.extend_from_slice(p);
            }
        } else {
            out.extend_from_slice(&self.extra[id as usize - self.base_n]);
        }
    }

    /// Average out-degree of the frozen base graph (diagnostics / tests).
    pub fn avg_degree(&self) -> f32 {
        self.edges.len() as f32 / (self.offsets.len() - 1).max(1) as f32
    }

    /// Nodes covered by the last full (re)build.
    pub fn base_len(&self) -> usize {
        self.base_n
    }

    /// Inserts since the last full (re)build.
    pub fn pending_inserts(&self) -> usize {
        self.pending
    }

    /// Add a reverse edge `from -> to`, respecting the degree bound; the
    /// primary-anchor edge of an inserted node is never pruned away.
    fn push_reverse_edge(&mut self, from: u32, to: u32) {
        let cap = self.params.m.max(4);
        // Disjoint field borrows: the target list is mutable while keys and
        // anchors are read for pruning.
        let RoarGraph { patch, extra, keys, primary_anchor, base_n, .. } = self;
        let list = if (from as usize) < *base_n {
            patch.entry(from).or_default()
        } else {
            &mut extra[from as usize - *base_n]
        };
        if list.contains(&to) {
            return;
        }
        list.push(to);
        if list.len() <= cap {
            return;
        }
        // Prune to the `cap` highest-IP targets, keeping protected edges
        // (from == primary anchor of an inserted target).
        let mut scored: Vec<(bool, f32, u32)> = list
            .iter()
            .map(|&t| {
                let protected = (t as usize) >= *base_n
                    && primary_anchor[t as usize - *base_n] == from;
                (protected, dot(keys.row(from as usize), keys.row(t as usize)), t)
            })
            .collect();
        scored.sort_by(|a, b| b.0.cmp(&a.0).then(b.1.total_cmp(&a.1)).then(a.2.cmp(&b.2)));
        // Never drop a protected edge, even past the cap: orphaning an
        // inserted node silently destroys its reachability invariant.
        let keep = cap.max(scored.iter().filter(|s| s.0).count());
        *list = scored.into_iter().take(keep).map(|(_, _, t)| t).collect();
    }

    /// Full re-projection over the current key store from the retained
    /// training queries; clears the patch/extra overlays. Tombstones
    /// survive the rebuild (dense ids are permanent): dead nodes get wired
    /// as transit nodes again and stay filtered from results.
    fn rebuild(&mut self) {
        let keys = self.keys.clone();
        let train = self.train.clone();
        let dead = std::mem::take(&mut self.dead);
        let dead_count = self.dead_count;
        *self = RoarGraph::build(keys, &train, self.params);
        self.dead = dead;
        self.dead.resize(self.keys.rows(), false);
        self.dead_count = dead_count;
        self.dead_at_rebuild = dead_count;
        self.fix_entries();
    }

    /// Keep the entry set live: searches must start from nodes that can be
    /// returned, otherwise an all-dead entry set strands the beam.
    fn fix_entries(&mut self) {
        if self.dead_count == 0 {
            return;
        }
        let dead = &self.dead;
        self.entries.retain(|&e| !dead[e as usize]);
        if self.entries.is_empty() {
            if let Some(first_live) = (0..self.keys.rows()).find(|&i| !self.dead[i]) {
                self.entries.push(first_live as u32);
            }
        }
    }
}

impl VectorIndex for RoarGraph {
    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn tombstones(&self) -> usize {
        self.dead_count
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> SearchResult {
        if self.dead_count >= self.keys.rows() {
            return SearchResult::default();
        }
        let ef = params.ef.max(k);
        let n = self.keys.rows();
        let mut visited = VisitedSet::new(n);
        visited.clear();
        let mut scanned = 0usize;
        let mut frontier: BinaryHeap<Cand> = BinaryHeap::new();
        let mut results: BinaryHeap<std::cmp::Reverse<Cand>> = BinaryHeap::new();
        let mut nbuf: Vec<u32> = Vec::new();
        let mut batch: Vec<u32> = Vec::new();
        let mut sims: Vec<f32> = Vec::new();

        for &e in &self.entries {
            if visited.insert(e as usize) {
                let sim = self.keys.score(query, e as usize);
                scanned += 1;
                frontier.push(Cand { sim, id: e });
                if !self.dead[e as usize] {
                    results.push(std::cmp::Reverse(Cand { sim, id: e }));
                }
            }
        }
        while let Some(c) = frontier.pop() {
            let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
            if results.len() >= ef && c.sim < worst {
                break;
            }
            // Batch-score the unvisited out-edges of `c` against the
            // store's scan tier (quantized mirror when built): one kernel
            // dispatch per hop, prefetch ahead of the gather, instead of
            // one cold `dot` per edge.
            self.collect_neighbors(c.id, &mut nbuf);
            batch.clear();
            for &nb in &nbuf {
                if visited.insert(nb as usize) {
                    batch.push(nb);
                }
            }
            sims.clear();
            self.keys.score_ids(query, &batch, &mut sims);
            scanned += batch.len();
            for (&nb, &sim) in batch.iter().zip(sims.iter()) {
                let worst = results.peek().map(|r| r.0.sim).unwrap_or(f32::NEG_INFINITY);
                if results.len() < ef || sim > worst {
                    // Tombstoned nodes are traversed (they keep the
                    // frozen CSR connected) but never returned.
                    frontier.push(Cand { sim, id: nb });
                    if !self.dead[nb as usize] {
                        results.push(std::cmp::Reverse(Cand { sim, id: nb }));
                        if results.len() > ef {
                            results.pop();
                        }
                    }
                }
            }
        }
        let mut out: Vec<Cand> = results.into_iter().map(|r| r.0).collect();
        out.sort_by(|a, b| b.cmp(a));
        SearchResult {
            ids: out.iter().take(k).map(|c| c.id).collect(),
            scores: out.iter().take(k).map(|c| c.sim).collect(),
            scanned,
        }
    }

    fn name(&self) -> &'static str {
        "RetrievalAttention"
    }

    fn memory_bytes(&self) -> usize {
        self.offsets.len() * 4
            + self.edges.len() * 4
            + self.patch.values().map(|v| v.len() * 4 + 32).sum::<usize>()
            + self.extra.iter().map(|v| v.len() * 4 + 24).sum::<usize>()
            + self.train.as_slice().len() * 4
            + std::mem::size_of::<Self>()
    }

    fn supports_insert(&self) -> bool {
        true
    }

    /// Degree-bounded local repair with recent decode queries as the
    /// bipartite training side (see module docs).
    fn insert_batch(&mut self, keys: KeyStore, new: Range<usize>, ctx: &InsertContext<'_>) -> bool {
        debug_assert_eq!(keys.cols(), self.keys.cols());
        debug_assert_eq!(new.end, keys.rows());
        debug_assert_eq!(new.start, self.keys.rows());
        if new.is_empty() {
            self.keys = keys;
            return true;
        }
        self.keys = keys;
        let total = self.keys.rows();
        self.extra.resize(total - self.base_n, Vec::new());
        self.primary_anchor.resize(total - self.base_n, u32::MAX);
        self.dead.resize(total, false);

        let kb = self.params.kb.min(total).max(2);
        let search_params = SearchParams { ef: kb.max(64), nprobe: 0 };

        // --- Attention-aware candidate generation: each recent decode
        // query retrieves its top-kb from the existing graph; batch keys
        // that would make that list are projected star/chain-style into
        // the same list, crediting co-retrieval counts.
        let mut counts: HashMap<(u32, u32), u32> = HashMap::new();
        let credit = |a: u32, b: u32, counts: &mut HashMap<(u32, u32), u32>| {
            if a == b {
                return;
            }
            let key = if a < b { (a, b) } else { (b, a) };
            *counts.entry(key).or_insert(0) += 1;
        };
        if let Some(qs) = ctx.queries() {
            for qi in 0..qs.rows() {
                let q = qs.row(qi);
                let res = self.search(q, kb, &search_params);
                let min_score = res.scores.last().copied().unwrap_or(f32::NEG_INFINITY);
                let mut combined: Vec<Cand> = res
                    .ids
                    .iter()
                    .zip(res.scores.iter())
                    .map(|(&id, &sim)| Cand { sim, id })
                    .collect();
                for j in new.clone() {
                    let sim = dot(q, self.keys.row(j));
                    if sim >= min_score || combined.len() < kb {
                        combined.push(Cand { sim, id: j as u32 });
                    }
                }
                combined.sort_by(|a, b| b.cmp(a));
                combined.truncate(kb);
                // Only project pairs touching the online region: the base
                // CSR already encodes base↔base co-retrieval.
                let onl = |id: u32| (id as usize) >= self.base_n;
                if combined.len() < 2 {
                    continue;
                }
                let anchor = combined[0].id;
                for w in combined.windows(2) {
                    if onl(w[0].id) || onl(w[1].id) {
                        credit(w[0].id, w[1].id, &mut counts);
                    }
                }
                for c in &combined[1..] {
                    if onl(anchor) || onl(c.id) {
                        credit(anchor, c.id, &mut counts);
                    }
                }
            }
        }

        // Per-batch-node candidate lists from the projection.
        let mut cand: HashMap<u32, Vec<(u32, u32)>> = HashMap::new(); // node -> (partner, count)
        for (&(a, b), &cnt) in &counts {
            for (x, y) in [(a, b), (b, a)] {
                if (x as usize) >= new.start {
                    cand.entry(x).or_default().push((y, cnt));
                }
            }
        }

        // --- Wire each batch node: projection candidates ranked by
        // (co-retrieval count, IP), key-space beam search as fallback for
        // nodes no recent query claimed.
        for j in new.clone() {
            let jid = j as u32;
            let mut ranked: Vec<(u32, f32, u32)> = cand
                .remove(&jid)
                .unwrap_or_default()
                .into_iter()
                .map(|(p, cnt)| (cnt, dot(self.keys.row(j), self.keys.row(p as usize)), p))
                .collect();
            // Tie-break by id: candidate lists come out of a HashMap, so
            // without it equal (count, IP) pairs would keep randomized
            // iteration order and the wired graph would differ run-to-run.
            ranked.sort_by(|a, b| {
                b.0.cmp(&a.0).then(b.1.total_cmp(&a.1)).then(a.2.cmp(&b.2))
            });
            let mut selected: Vec<u32> = ranked
                .into_iter()
                .filter(|&(_, _, p)| p != jid)
                .take(self.params.m)
                .map(|(_, _, p)| p)
                .collect();
            // Reachability guarantee: every online node keeps one protected
            // edge from an *already-reachable* partner (base node or an
            // earlier-wired online node — reachable by induction). If the
            // recent queries only paired it with later batch members — or
            // claimed it not at all — fall back to a key-space beam over
            // the wired graph (the beam starts at the entries, so anything
            // it returns is reachable right now).
            let mut anchor = selected.iter().copied().find(|&p| (p as usize) < j);
            if anchor.is_none() {
                let res =
                    self.search(self.keys.row(j), self.params.m.min(8).max(2), &search_params);
                if let Some(&found) = res.ids.iter().find(|&&id| id != jid) {
                    if !selected.contains(&found) {
                        selected.insert(0, found);
                        selected.truncate(self.params.m.max(1));
                    }
                    anchor = Some(found);
                }
            }
            if let Some(a) = anchor {
                self.primary_anchor[j - self.base_n] = a;
            }
            // Merge (not overwrite): earlier batch members may already have
            // pushed reverse edges into this node's list.
            let slot = j - self.base_n;
            for p in selected.clone() {
                if !self.extra[slot].contains(&p) {
                    self.extra[slot].push(p);
                }
            }
            for &p in &selected {
                self.push_reverse_edge(p, jid);
            }
        }

        // --- Fold the recent queries into the retained training set and
        // rebuild once enough inserts have accumulated.
        if let Some(qs) = ctx.queries() {
            let mut train = std::mem::replace(&mut self.train, Matrix::zeros(0, 0));
            for qi in 0..qs.rows() {
                train.push_row(qs.row(qi));
            }
            self.train = train.keep_last_rows(TRAIN_CAP);
        }
        self.pending += new.len();
        if self.pending >= self.params.rebuild_threshold.max(1) {
            self.rebuild();
        }
        true
    }

    fn supports_remove(&self) -> bool {
        true
    }

    /// Tombstone + degree-bounded local bridge (see module docs): each
    /// dead node's live neighborhood is stitched together with patch
    /// edges, results filter the dead, and a 25% tombstone ratio triggers
    /// the amortised re-projection.
    fn remove_batch(&mut self, ids: &[u32]) -> bool {
        let mut fresh: Vec<u32> = Vec::new();
        for &id in ids {
            let i = id as usize;
            if i < self.dead.len() && !self.dead[i] {
                self.dead[i] = true;
                self.dead_count += 1;
                fresh.push(id);
            }
        }
        if fresh.is_empty() {
            return true;
        }
        // Bridge each hole: chain the dead node's best live neighbors so a
        // walk that used to route through it still has a short detour. The
        // reverse-edge helper enforces the degree bound and the protected
        // primary anchors of online-inserted nodes.
        let mut nbuf: Vec<u32> = Vec::new();
        for &r in &fresh {
            self.collect_neighbors(r, &mut nbuf);
            let mut live: Vec<u32> =
                nbuf.iter().copied().filter(|&w| !self.dead[w as usize]).collect();
            live.sort_unstable();
            live.dedup();
            // Best-first by similarity to the removed node: the bridge
            // chain should stitch together the neighbors most likely to
            // co-occur in a walk that used to route through it.
            let mut scored: Vec<(f32, u32)> = live
                .into_iter()
                .map(|w| (dot(self.keys.row(r as usize), self.keys.row(w as usize)), w))
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            scored.truncate(self.params.m.max(2));
            for w in 0..scored.len().saturating_sub(1) {
                self.push_reverse_edge(scored[w].1, scored[w + 1].1);
                self.push_reverse_edge(scored[w + 1].1, scored[w].1);
            }
        }
        self.fix_entries();
        // Ratio of tombstones accumulated *since the last re-projection*:
        // dense ids never free up between reclamation epochs, so the
        // all-time ratio would cross the threshold once and then rebuild
        // on every removal forever. The denominator is the LIVE count —
        // measured against total slots the trigger would fire ever more
        // rarely as a streaming session ages.
        if (self.dead_count - self.dead_at_rebuild) * 4 > self.keys.rows() - self.dead_count {
            self.rebuild();
        }
        true
    }

    fn supports_remap(&self) -> bool {
        true
    }

    fn scan_quantized(&self) -> bool {
        self.keys.is_quantized()
    }

    fn supports_exact_rerank(&self) -> bool {
        true
    }

    fn score_exact(&self, query: &[f32], id: u32) -> f32 {
        self.keys.score_exact(query, id as usize)
    }

    fn score_exact_batch(&self, query: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        self.keys.score_ids_exact(query, ids, out);
    }

    fn dead_ids(&self) -> Vec<u32> {
        super::collect_dead(&self.dead)
    }

    /// Relabel the whole graph (CSR base + patch/extra overlays) into the
    /// compacted id space and re-freeze it as the new base. Dead transit
    /// nodes vanish, but removal already bridged every hole with patch
    /// edges, and the standard connectivity repair re-attaches anything
    /// the bridges missed — so live-row search quality is preserved up to
    /// recall tolerance without paying a full re-projection.
    fn remap_dense(&mut self, plan: &RemapPlan) -> bool {
        let old_n = self.keys.rows();
        if plan.old_to_new.len() != old_n || plan.store.rows() != plan.new_len || plan.new_len == 0
        {
            return false;
        }
        let (dead, dead_count) = super::remap_dead(&self.dead, plan);
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); plan.new_len];
        let mut nbuf: Vec<u32> = Vec::new();
        for old in 0..old_n as u32 {
            let Some(new) = plan.map(old) else { continue };
            self.collect_neighbors(old, &mut nbuf);
            let list = &mut adj[new as usize];
            list.reserve(nbuf.len());
            for &nb in &nbuf {
                if let Some(nn) = plan.map(nb) {
                    if nn != new {
                        list.push(nn);
                    }
                }
            }
            list.sort_unstable();
            list.dedup();
        }
        // Entries are live after `fix_entries`, so they normally just
        // renumber; refill from the first live survivor if not.
        let mut entries: Vec<u32> = self.entries.iter().filter_map(|&e| plan.map(e)).collect();
        if entries.is_empty() {
            let first_live = (0..plan.new_len).find(|&i| !dead[i]).unwrap_or(0);
            entries.push(first_live as u32);
        }
        self.keys = plan.store.clone();
        self.entries = entries;
        self.dead = dead;
        self.dead_count = dead_count;
        self.dead_at_rebuild = dead_count;
        self.base_n = plan.new_len;
        self.patch.clear();
        self.extra.clear();
        self.primary_anchor.clear();
        self.pending = 0;
        let adj = self.repair_connectivity(adj, self.params.repair_sample);
        self.freeze(adj);
        true
    }

    fn supports_save(&self) -> bool {
        true
    }

    fn family_tag(&self) -> u8 {
        super::FAMILY_ROAR
    }

    /// The patch overlay is a `HashMap`, so it is written in ascending key
    /// order — snapshots of identical graphs are byte-identical, which the
    /// persistence tests rely on to diff round trips cheaply.
    fn save_state(&self, w: &mut crate::store::codec::SnapWriter<'_>) -> anyhow::Result<()> {
        w.usize(self.params.kb)?;
        w.usize(self.params.m)?;
        w.usize(self.params.repair_sample)?;
        w.usize(self.params.rebuild_threshold)?;
        w.usize(self.base_n)?;
        w.u32s(&self.offsets)?;
        w.u32s(&self.edges)?;
        w.u32s(&self.entries)?;
        let mut patch_keys: Vec<u32> = self.patch.keys().copied().collect();
        patch_keys.sort_unstable();
        w.usize(patch_keys.len())?;
        for k in patch_keys {
            w.u32(k)?;
            w.u32s(&self.patch[&k])?;
        }
        w.usize(self.extra.len())?;
        for adj in &self.extra {
            w.u32s(adj)?;
        }
        w.u32s(&self.primary_anchor)?;
        w.matrix(&self.train)?;
        w.usize(self.pending)?;
        w.bytes(&super::dead_to_bytes(&self.dead))?;
        w.usize(self.dead_at_rebuild)?;
        Ok(())
    }

    fn clone_index(&self) -> Box<dyn VectorIndex> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::exact_topk_store;

    use crate::util::rng::Rng;

    /// Simulated attention geometry: keys ~ N(0, I); queries live in a
    /// shifted, scaled subspace (OOD), like Q/K produced by different
    /// projection matrices.
    fn ood_setup(n: usize, nq: usize, d: usize, seed: u64) -> (KeyStore, Matrix) {
        let mut rng = Rng::seed_from(seed);
        let keys = KeyStore::from_matrix(Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5));
        // Queries: strong offset + anisotropic scale => OOD w.r.t. keys.
        let queries = Matrix::from_fn(nq, d, |_, c| {
            let base: f32 = rng.f32() - 0.5;
            base * if c % 2 == 0 { 3.0 } else { 0.3 } + if c < d / 4 { 2.0 } else { -1.0 }
        });
        (keys, queries)
    }

    #[test]
    fn ood_recall_beats_scan_budget() {
        let (keys, queries) = ood_setup(4000, 400, 16, 21);
        // Train on the first 300 queries, test on the remaining 100.
        let train = Matrix::from_fn(300, 16, |r, c| queries[(r, c)]);
        let idx = RoarGraph::build(keys.clone(), &train, RoarParams::default());
        let mut recall = 0.0;
        let mut scanned = 0usize;
        let ntest = 100;
        for t in 0..ntest {
            let q: Vec<f32> = (0..16).map(|c| queries[(300 + t, c)]).collect();
            let truth = exact_topk_store(&keys, &q, 10);
            let r = idx.search(&q, 10, &SearchParams { ef: 64, nprobe: 0 });
            recall += r.recall_against(&truth);
            scanned += r.scanned;
        }
        recall /= ntest as f32;
        let frac = scanned as f32 / (ntest * 4000) as f32;
        assert!(recall > 0.9, "OOD recall too low: {recall}");
        // The scan *fraction* shrinks with corpus size (beam work is ~ef*deg
        // regardless of n): at n=4000 a budget of ~20% is expected; the
        // paper's 1-3% figure at n=128K is asserted by the fig6 experiment
        // and the `index_search` bench.
        assert!(frac < 0.25, "scanned too much: {frac}");
    }

    #[test]
    fn all_nodes_reachable() {
        let (keys, queries) = ood_setup(500, 50, 8, 33);
        let idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        // Exhaustive beam must be able to visit everything.
        let q = vec![0.0f32; 8];
        let r = idx.search(&q, 500, &SearchParams { ef: 500, nprobe: 0 });
        assert_eq!(r.ids.len(), 500, "some nodes unreachable");
    }

    #[test]
    fn degree_bounded() {
        let (keys, queries) = ood_setup(1000, 200, 8, 5);
        let params = RoarParams { kb: 16, m: 8, repair_sample: 64, ..RoarParams::default() };
        let idx = RoarGraph::build(keys, &queries, params);
        // m + repair edges; allow slack of a few repair links.
        assert!(idx.avg_degree() <= 12.0, "avg degree too high: {}", idx.avg_degree());
    }

    #[test]
    fn single_key() {
        let keys = KeyStore::from_matrix(Matrix::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]));
        let queries = Matrix::from_vec(2, 4, vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let idx = RoarGraph::build(keys, &queries, RoarParams::default());
        let r = idx.search(&[0.5, 0.5, 0.0, 0.0], 3, &SearchParams::default());
        assert_eq!(r.ids, vec![0]);
    }

    #[test]
    fn inserted_nodes_searchable_and_reachable() {
        let (keys, queries) = ood_setup(600, 80, 8, 41);
        let mut idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        // Grow the store by 40 keys drawn from the same process.
        let (more, recent_q) = ood_setup(40, 16, 8, 42);
        let grown = keys.append_rows(more.to_matrix());
        let ctx = InsertContext { recent_queries: Some(&recent_q) };
        assert!(idx.insert_batch(grown.clone(), 600..640, &ctx));
        assert_eq!(idx.len(), 640);
        assert_eq!(idx.base_len(), 600);
        assert_eq!(idx.pending_inserts(), 40);
        // Every node — frozen and inserted — reachable under a full beam.
        let r = idx.search(&vec![0.0f32; 8], 640, &SearchParams { ef: 640, nprobe: 0 });
        assert_eq!(r.ids.len(), 640, "inserted nodes unreachable");
        // An inserted key queried directly must surface itself.
        let r = idx.search(grown.row(615), 5, &SearchParams { ef: 64, nprobe: 0 });
        assert!(r.ids.contains(&615), "inserted key not retrieved: {:?}", r.ids);
    }

    #[test]
    fn insert_without_queries_falls_back_to_key_space() {
        let (keys, queries) = ood_setup(300, 40, 8, 51);
        let mut idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        let grown = keys
            .append_rows(Matrix::from_vec(1, 8, vec![9.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]));
        assert!(idx.insert_batch(grown, 300..301, &InsertContext::none()));
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], 3, &SearchParams::default());
        assert!(r.ids.contains(&300), "fallback-wired key not retrieved");
    }

    #[test]
    fn rebuild_threshold_triggers_reprojection() {
        let (keys, queries) = ood_setup(200, 60, 8, 61);
        let params = RoarParams { rebuild_threshold: 32, ..RoarParams::default() };
        let mut idx = RoarGraph::build(keys.clone(), &queries, params);
        let (more, recent_q) = ood_setup(64, 16, 8, 62);
        let grown = keys.append_rows(more.to_matrix());
        let ctx = InsertContext { recent_queries: Some(&recent_q) };
        assert!(idx.insert_batch(grown, 200..264, &ctx));
        // 64 >= threshold 32: the graph must have re-projected over all keys.
        assert_eq!(idx.base_len(), 264, "rebuild did not trigger");
        assert_eq!(idx.pending_inserts(), 0);
        let r = idx.search(&vec![0.0f32; 8], 264, &SearchParams { ef: 264, nprobe: 0 });
        assert_eq!(r.ids.len(), 264, "rebuild lost nodes");
    }

    #[test]
    fn removed_nodes_filtered_but_traversed() {
        let (keys, queries) = ood_setup(500, 60, 8, 71);
        let mut idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        // Below the 25% rebuild ratio: pure tombstone + bridge path.
        let removed: Vec<u32> = (0..100).map(|i| (i * 5) as u32).collect();
        assert!(idx.remove_batch(&removed));
        assert_eq!(idx.tombstones(), 100);
        assert_eq!(idx.live_len(), 400);
        let r = idx.search(&vec![0.0f32; 8], 500, &SearchParams { ef: 500, nprobe: 0 });
        assert_eq!(r.ids.len(), 400, "every live node must stay reachable");
        for id in &r.ids {
            assert!(id % 5 != 0 || *id >= 500, "tombstoned id {id} returned");
        }
        // A removed key queried directly surfaces a neighbor, not itself.
        let probe = idx.search(keys.row(250), 5, &SearchParams { ef: 64, nprobe: 0 });
        assert!(!probe.ids.contains(&250));
    }

    #[test]
    fn remap_compacts_ids_and_keeps_live_set_searchable() {
        let (keys, queries) = ood_setup(500, 60, 8, 79);
        let mut idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        // Below the rebuild ratio: tombstone + bridge path only.
        let removed: Vec<u32> = (0..100).map(|i| (i * 5) as u32).collect();
        assert!(idx.remove_batch(&removed));
        assert_eq!(idx.dead_ids(), removed);
        let (plan, keep) = RemapPlan::from_dead(&removed, &keys, 1).expect("plan must build");
        assert_eq!(keep.len(), 400);
        assert!(idx.supports_remap());
        assert!(idx.remap_dense(&plan));
        assert_eq!(idx.len(), 400);
        assert_eq!(idx.base_len(), 400);
        assert_eq!(idx.tombstones(), 0);
        assert_eq!(idx.pending_inserts(), 0);
        // Every survivor reachable under a full beam, in the new id space.
        let r = idx.search(&vec![0.0f32; 8], 400, &SearchParams { ef: 400, nprobe: 0 });
        assert_eq!(r.ids.len(), 400, "remap lost reachability");
        for id in &r.ids {
            assert!((*id as usize) < 400, "stale dense id {id} after remap");
        }
        // A surviving key queried directly still surfaces itself.
        let probe_old = 251u32; // 251 % 5 != 0 -> survives
        let probe_new = plan.map(probe_old).unwrap();
        let r = idx.search(keys.row(probe_old as usize), 5, &SearchParams { ef: 64, nprobe: 0 });
        assert!(r.ids.contains(&probe_new), "survivor lost after remap: {:?}", r.ids);
        // Online inserts keep working against the compacted store.
        let grown = plan
            .store
            .append_rows(Matrix::from_vec(1, 8, vec![9.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]));
        assert!(idx.insert_batch(grown, 400..401, &InsertContext::none()));
        let r = idx.search(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], 3, &SearchParams::default());
        assert!(r.ids.contains(&400), "post-remap insert not retrieved");
    }

    #[test]
    fn heavy_removal_triggers_reprojection_and_stays_filtered() {
        let (keys, queries) = ood_setup(300, 50, 8, 73);
        let mut idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        let removed: Vec<u32> = (0..150).map(|i| i as u32).collect();
        assert!(idx.remove_batch(&removed));
        // 50% dead crosses the ratio: the graph re-projected; tombstones
        // must survive the rebuild.
        assert_eq!(idx.tombstones(), 150);
        assert_eq!(idx.pending_inserts(), 0);
        let r = idx.search(&vec![0.0f32; 8], 300, &SearchParams { ef: 300, nprobe: 0 });
        assert_eq!(r.ids.len(), 150);
        for id in &r.ids {
            assert!(*id >= 150, "tombstoned id {id} returned after rebuild");
        }
    }
}
