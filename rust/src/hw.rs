//! Hardware profiles and KV-cache memory arithmetic.
//!
//! Two roles:
//!
//! 1. **Memory accounting** (Table 1): bytes of KV cache per token for a
//!    model geometry, OOM boundaries for the vLLM baseline on a device
//!    budget (24GB RTX4090 / 40–80GB A100).
//! 2. **Device-time modeling**: our "device" is the PJRT CPU client, so raw
//!    device-side wall-clock is not an RTX4090's. Each profile carries a
//!    memory bandwidth figure from which the device-bound attention time is
//!    estimated (decode attention is bandwidth-bound: it reads the whole
//!    device-resident KV once per token). Experiments report both measured
//!    host-side time (real) and modeled device time (profile-scaled), and
//!    EXPERIMENTS.md labels which is which.



/// A device profile used for modeled latency/memory numbers.
#[derive(Clone, Debug)]
pub struct HwProfile {
    pub name: &'static str,
    /// Device memory budget in bytes.
    pub device_mem_bytes: usize,
    /// Effective device memory bandwidth (bytes/s) for KV reads.
    pub device_bw: f64,
    /// Host (CPU) effective bandwidth for index scans (bytes/s).
    pub host_bw: f64,
    /// Fixed per-decode-step device overhead (kernel launches etc.), sec.
    pub device_overhead_s: f64,
    /// Peak device compute (flops/s, fp16-class).
    pub device_flops: f64,
}

/// NVIDIA RTX4090 (24GB) + desktop CPU — the paper's §4.1 testbed.
pub const RTX4090: HwProfile = HwProfile {
    name: "rtx4090",
    device_mem_bytes: 24 * (1 << 30),
    device_bw: 1.0e12,        // ~1 TB/s GDDR6X
    host_bw: 40.0e9,          // ~40 GB/s dual-channel DDR4
    device_overhead_s: 2.0e-4,
    device_flops: 82.0e12,    // fp16 tensor-core peak
};

/// NVIDIA A100 80GB + EPYC — the paper's §A.4 testbed.
pub const A100: HwProfile = HwProfile {
    name: "a100",
    device_mem_bytes: 80 * (1 << 30),
    device_bw: 2.0e12,        // ~2 TB/s HBM2e
    host_bw: 150.0e9,         // 8-channel EPYC
    device_overhead_s: 2.0e-4,
    device_flops: 312.0e12,
};

/// The machine the tests actually run on (no scaling).
pub const LOCALHOST: HwProfile = HwProfile {
    name: "localhost",
    device_mem_bytes: usize::MAX,
    device_bw: 20.0e9,
    host_bw: 20.0e9,
    device_overhead_s: 0.0,
    device_flops: 50.0e9,
};

impl HwProfile {
    pub fn by_name(name: &str) -> Option<&'static HwProfile> {
        match name {
            "rtx4090" => Some(&RTX4090),
            "a100" => Some(&A100),
            "localhost" => Some(&LOCALHOST),
            _ => None,
        }
    }

    /// Modeled device time to attend over `tokens` KV pairs of a model.
    /// Decode attention is bandwidth-bound: read K and V once.
    pub fn attn_time_s(&self, geom: &ModelGeometry, tokens: usize) -> f64 {
        let bytes = geom.kv_bytes_per_token() as f64 * tokens as f64;
        self.device_overhead_s + bytes / self.device_bw
    }

    /// Modeled host time for a linear scan over `vectors` keys of dim `d`.
    pub fn scan_time_s(&self, vectors: usize, d: usize) -> f64 {
        (vectors * d * 4) as f64 / self.host_bw
    }
}

/// Attention geometry of a served model — enough to do all the paper's
/// memory arithmetic (Table 1 / Table 6).
#[derive(Clone, Copy, Debug)]
pub struct ModelGeometry {
    pub layers: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Bytes per stored element (2 = fp16, the paper's setting).
    pub elt_size: usize,
}

impl ModelGeometry {
    /// Llama-3-8B: 32 layers, 32 Q heads, 8 KV heads, head dim 128 (Table 6).
    pub const LLAMA3_8B: ModelGeometry =
        ModelGeometry { layers: 32, q_heads: 32, kv_heads: 8, head_dim: 128, elt_size: 2 };
    /// Yi-6B: 32 layers, 32 Q heads, 4 KV heads.
    pub const YI_6B: ModelGeometry =
        ModelGeometry { layers: 32, q_heads: 32, kv_heads: 4, head_dim: 128, elt_size: 2 };
    /// Yi-9B: 48 layers, 32 Q heads, 4 KV heads.
    pub const YI_9B: ModelGeometry =
        ModelGeometry { layers: 48, q_heads: 32, kv_heads: 4, head_dim: 128, elt_size: 2 };

    /// Bytes of KV cache per token: K + V across all layers and KV heads.
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.layers * self.kv_heads * self.head_dim * self.elt_size
    }

    /// Total KV bytes for a context of `tokens`.
    pub fn kv_bytes(&self, tokens: usize) -> usize {
        self.kv_bytes_per_token() * tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama3_kv_matches_paper_table1() {
        // Paper Table 1: Llama-3-8B KV cache = 15.6GB at 128K, 125GB at 1M.
        let g = ModelGeometry::LLAMA3_8B;
        let gb_128k = g.kv_bytes(128 * 1024) as f64 / (1u64 << 30) as f64;
        assert!((gb_128k - 16.0).abs() < 0.7, "128K KV = {gb_128k:.1} GB, paper says 15.6");
        let gb_1m = g.kv_bytes(1_000_000) as f64 / (1u64 << 30) as f64;
        assert!((gb_1m - 122.0).abs() < 5.0, "1M KV = {gb_1m:.1} GB, paper says 125");
    }

    #[test]
    fn vllm_oom_boundary_on_rtx4090() {
        // Table 4: vLLM OOMs at >=4K?? No — with model weights (~16GB) plus
        // KV, 24GB leaves ~8GB: 8GB / 128KB-per-token ≈ 65K tokens. The
        // paper reports OOM at every tested length because weights + runtime
        // overhead already consume the margin. We assert the KV for 128K
        // alone exceeds the leftover budget.
        let g = ModelGeometry::LLAMA3_8B;
        let weights: usize = 16 * (1 << 30);
        let leftover = RTX4090.device_mem_bytes - weights;
        assert!(g.kv_bytes(128 * 1024) > leftover);
    }

    #[test]
    fn yi9b_has_more_layers() {
        assert!(
            ModelGeometry::YI_9B.kv_bytes_per_token() > ModelGeometry::YI_6B.kv_bytes_per_token()
        );
    }

    #[test]
    fn attn_time_grows_linearly() {
        let g = ModelGeometry::LLAMA3_8B;
        let t1 = RTX4090.attn_time_s(&g, 4096);
        let t2 = RTX4090.attn_time_s(&g, 8192);
        assert!(t2 > t1);
        let ratio = (t2 - RTX4090.device_overhead_s) / (t1 - RTX4090.device_overhead_s);
        assert!((ratio - 2.0).abs() < 1e-9);
    }
}
