//! Portable scalar kernels — the reference semantics of the subsystem.
//!
//! The f32 paths use an 8-accumulator unrolling whose lane structure is
//! reproduced exactly by the AVX2 and NEON backends (multiply + add, no
//! FMA contraction, [`tree8`] reduction order), so every backend returns
//! bit-identical f32 scores. LLVM auto-vectorises this form on its own,
//! which is why the scalar fallback is merely slower, not pathological.

/// Fixed-association horizontal reduction of the 8 unrolled accumulators.
/// Every backend funnels through this exact expression tree — it is what
/// makes the scalar and SIMD paths bit-for-bit identical.
#[inline]
pub fn tree8(s: &[f32; 8]) -> f32 {
    (((s[0] + s[1]) + (s[2] + s[3])) + (s[4] + s[5])) + (s[6] + s[7])
}

/// Inner product, 8-way unrolled: accumulator `l` sums elements
/// `l, l+8, l+16, ...` — exactly SIMD lane `l`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut s = [0.0f32; 8];
    for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
        for ((acc, x), y) in s.iter_mut().zip(ca).zip(cb) {
            *acc += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
        tail += x * y;
    }
    tree8(&s) + tail
}

/// Squared Euclidean distance with the same lane structure as [`dot`].
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut s = [0.0f32; 8];
    for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
        for ((acc, x), y) in s.iter_mut().zip(ca).zip(cb) {
            let d = x - y;
            *acc += d * d;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
        let d = x - y;
        tail += d * d;
    }
    tree8(&s) + tail
}

pub fn dot_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(dot(q, row));
    }
}

pub fn dot_gather(q: &[f32], rows: &[f32], cols: usize, ids: &[u32], out: &mut Vec<f32>) {
    out.reserve(ids.len());
    for &id in ids {
        let off = id as usize * cols;
        out.push(dot(q, &rows[off..off + cols]));
    }
}

/// Multi-query gather scores, query-major output, id-major loop: each
/// gathered row is loaded once and scored against every query with the
/// same [`dot`] as the single-query form (so scores are bit-identical).
pub fn dot_gather_mq(
    qs: &[f32],
    nq: usize,
    rows: &[f32],
    cols: usize,
    ids: &[u32],
    out: &mut Vec<f32>,
) {
    let base = out.len();
    out.resize(base + nq * ids.len(), 0.0);
    for (j, &id) in ids.iter().enumerate() {
        let off = id as usize * cols;
        let row = &rows[off..off + cols];
        for qi in 0..nq {
            out[base + qi * ids.len() + j] = dot(&qs[qi * cols..(qi + 1) * cols], row);
        }
    }
}

pub fn l2_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(l2_sq(q, row));
    }
}

/// Decode one bf16 (bit-truncated f32) value.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Inner product against one bf16 row.
#[inline]
pub fn dot_f16(q: &[f32], row: &[u16]) -> f32 {
    debug_assert_eq!(q.len(), row.len());
    let mut s = 0.0f32;
    for (x, &h) in q.iter().zip(row.iter()) {
        s += x * f16_to_f32(h);
    }
    s
}

/// Unscaled inner product against one int8 row.
#[inline]
pub fn dot_i8(q: &[f32], row: &[i8]) -> f32 {
    debug_assert_eq!(q.len(), row.len());
    let mut s = 0.0f32;
    for (x, &v) in q.iter().zip(row.iter()) {
        s += x * v as f32;
    }
    s
}

pub fn dot_rows_f16(q: &[f32], rows: &[u16], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(dot_f16(q, row));
    }
}

pub fn dot_rows_i8(q: &[f32], rows: &[i8], scales: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for (row, &scale) in rows.chunks_exact(cols).zip(scales.iter()) {
        out.push(scale * dot_i8(q, row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive_within_tolerance() {
        let a: Vec<f32> = (0..67).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..67).map(|i| (66 - i) as f32 * 0.25).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2 * naive.abs().max(1.0));
    }

    #[test]
    fn l2_matches_naive_within_tolerance() {
        let a: Vec<f32> = (0..53).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..53).map(|i| (i as f32 * 0.11).cos()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((l2_sq(&a, &b) - naive).abs() < 1e-4 * naive.max(1.0));
    }

    #[test]
    fn f16_roundtrip_is_truncation() {
        for v in [0.0f32, 1.0, -3.25, 1e-8, 1e8] {
            let h = (v.to_bits() >> 16) as u16;
            let back = f16_to_f32(h);
            // Truncation keeps sign + exponent + 7 mantissa bits.
            assert!((back - v).abs() <= v.abs() / 128.0 + f32::MIN_POSITIVE);
        }
    }
}
