//! The scoring-kernel subsystem: batched, runtime-dispatched SIMD scoring
//! of query vectors against key bytes.
//!
//! At 128K-row geometry the decode-time cost of this crate is dominated by
//! CPU-side vector scoring (the paper's Table 5 "vector search" phase),
//! and that scoring is **memory-bandwidth-bound**, not compute-bound:
//! every graph hop and every id-set gather streams cold key rows through
//! the cache hierarchy once. This module is therefore organised around two
//! ideas:
//!
//! 1. **Batching.** The one-`dot`-per-candidate loops of the index
//!    families amortise nothing: the query reloads per call and the
//!    hardware prefetcher never sees the gather ahead of time.
//!    [`dot_rows`] / [`dot_gather`] / [`l2_rows`] score 8–10⁵ candidate
//!    rows per call — graph neighbor lists, IVF posting lists, flat scans
//!    and the `attend_subset` id gather all go through them.
//! 2. **Fewer key bytes.** The quantized scan tier ([`quant::QuantChunk`])
//!    stores a bf16 (bit-truncated f32, 2 B/dim) or symmetric-int8
//!    (1 B/dim + one f32 scale per row) mirror of sealed store chunks, so
//!    a bandwidth-bound scan moves 2–4× fewer bytes. Exactness is
//!    confined to where it matters: the final attention read and the
//!    `rerank` re-scoring pass stay f32.
//!
//! ## Dispatch
//!
//! CPU features are detected **at runtime** once ([`active`]): AVX2+FMA on
//! x86_64, NEON on aarch64, the portable scalar path everywhere else. The
//! env toggle `RA_KERNEL=scalar` force-disables SIMD (CI runs the whole
//! test suite under it). The f32 `dot`/`l2_sq` paths are **bit-for-bit
//! identical** across all three backends: the SIMD lanes reproduce the
//! scalar 8-accumulator unrolling exactly (multiply + add, no FMA
//! contraction, fixed [`scalar::tree8`] reduction order), so switching
//! kernels can never change a search result, only its latency. The
//! quantized paths are approximate by construction and use FMA freely.
//!
//! | op            | scalar | AVX2+FMA | NEON |
//! |---------------|--------|----------|------|
//! | `dot`/`l2_sq` | 8-acc unrolled | 8-lane mul+add (bit-exact) | 2×4-lane mul+add (bit-exact) |
//! | `dot_rows` / `dot_gather` | per-row | batched + prefetch | batched |
//! | `dot_f16` (bf16) | decode + mul | cvt+shift + FMA | widen+shift, mul+add |
//! | `dot_i8`      | decode + mul | sign-extend cvt + FMA | sign-extend cvt, mul+add |

pub mod quant;
pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod x86;

#[cfg(target_arch = "aarch64")]
pub mod neon;

pub use quant::{QuantChunk, QuantMode};

use crate::util::sync::OnceLock;

/// Which kernel backend is live for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// Portable Rust (also the `RA_KERNEL=scalar` forced fallback).
    Scalar,
    /// AVX2 + FMA (x86_64, runtime-detected).
    Avx2,
    /// NEON (aarch64 baseline).
    Neon,
}

impl Dispatch {
    pub fn label(self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Avx2 => "avx2+fma",
            Dispatch::Neon => "neon",
        }
    }
}

static ACTIVE: OnceLock<Dispatch> = OnceLock::new();

fn detect() -> Dispatch {
    // Force-disable toggle: the whole suite must stay green with SIMD off.
    if std::env::var("RA_KERNEL").map(|v| v.eq_ignore_ascii_case("scalar")).unwrap_or(false) {
        return Dispatch::Scalar;
    }
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Dispatch {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Dispatch::Avx2
    } else {
        Dispatch::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Dispatch {
    // NEON is a baseline feature of aarch64.
    Dispatch::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Dispatch {
    Dispatch::Scalar
}

/// The backend selected for this process (detected once, then cached).
/// First call also publishes the choice as the `kernel.dispatch` label in
/// the process metrics registry (the stats verb reports which backend a
/// fleet replica actually dispatched to).
#[inline]
pub fn active() -> Dispatch {
    *ACTIVE.get_or_init(|| {
        let d = detect();
        crate::telemetry::registry().set_label("kernel.dispatch", d.label());
        d
    })
}

/// Best-effort software prefetch of the cache line at `p` (no-op off
/// x86_64). Safe to call with any pointer: PREFETCH never faults and the
/// address is only hinted, never dereferenced — build it with
/// `wrapping_add` so no out-of-allocation pointer arithmetic is performed.
#[inline]
pub fn prefetch<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: PREFETCH is a pure hint — it never faults and never
    // dereferences, so any pointer value (null, dangling, misaligned) is
    // acceptable; SSE is baseline on x86_64 so the instruction exists.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// Inner product `a · b`. Bit-identical across every backend.
///
/// The length check is a real assert (not debug-only): the SIMD backends
/// trust it, so a mismatch from safe code must panic, never read out of
/// bounds.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot operand lengths differ");
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm is reached only when `active()` returned Avx2,
        // i.e. runtime detection confirmed AVX2+FMA — the target-feature
        // contract of the x86 kernel; operand lengths were checked above.
        Dispatch::Avx2 => unsafe { x86::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Dispatch::Neon => neon::dot(a, b),
        _ => scalar::dot(a, b),
    }
}

/// Squared Euclidean distance. Bit-identical across every backend.
/// Length equality is enforced (see [`dot`]).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "l2_sq operand lengths differ");
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm is reached only when `active()` returned Avx2,
        // i.e. runtime detection confirmed AVX2+FMA — the target-feature
        // contract of the x86 kernel; operand lengths were checked above.
        Dispatch::Avx2 => unsafe { x86::l2_sq(a, b) },
        #[cfg(target_arch = "aarch64")]
        Dispatch::Neon => neon::l2_sq(a, b),
        _ => scalar::l2_sq(a, b),
    }
}

/// Scores of `q` against every row of a contiguous row-major buffer
/// (`rows.len() / cols` rows), appended to `out`. One dispatch for the
/// whole batch; the streaming access pattern keeps the prefetcher ahead.
#[inline]
pub fn dot_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    if cols == 0 {
        return;
    }
    assert_eq!(q.len(), cols, "query length != row width");
    debug_assert_eq!(rows.len() % cols, 0, "rows buffer is not row-aligned");
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm is reached only when `active()` returned Avx2,
        // i.e. runtime detection confirmed AVX2+FMA — the target-feature
        // contract of the x86 kernel; operand lengths were checked above.
        Dispatch::Avx2 => unsafe { x86::dot_rows(q, rows, cols, out) },
        #[cfg(target_arch = "aarch64")]
        Dispatch::Neon => neon::dot_rows(q, rows, cols, out),
        _ => scalar::dot_rows(q, rows, cols, out),
    }
}

/// Gather-scores of `q` against the rows named by `ids` in a contiguous
/// row-major buffer, appended to `out`. The x86 path issues software
/// prefetches a few ids ahead of the gather.
#[inline]
pub fn dot_gather(q: &[f32], rows: &[f32], cols: usize, ids: &[u32], out: &mut Vec<f32>) {
    if cols == 0 || ids.is_empty() {
        return;
    }
    assert_eq!(q.len(), cols, "query length != row width");
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm is reached only when `active()` returned Avx2,
        // i.e. runtime detection confirmed AVX2+FMA — the target-feature
        // contract of the x86 kernel; operand lengths were checked above.
        Dispatch::Avx2 => unsafe { x86::dot_gather(q, rows, cols, ids, out) },
        #[cfg(target_arch = "aarch64")]
        Dispatch::Neon => neon::dot_gather(q, rows, cols, ids, out),
        _ => scalar::dot_gather(q, rows, cols, ids, out),
    }
}

/// Multi-query gather-scores: `nq` queries (concatenated in `qs`, each
/// `cols` wide) against the rows named by `ids`, appended to `out`
/// **query-major** — `out[qi * ids.len() + j] = dot(q_qi, row ids[j])`.
///
/// The inner loop is id-major: each gathered key row is loaded ONCE and
/// scored against every query while it is cache-hot — for a GQA group of
/// `nq` heads sharing a key store this reads `nq`× fewer key bytes than
/// `nq` separate [`dot_gather`] calls. Per (query, row) the reduction is
/// the same backend `dot`, so the scores are bit-identical to the
/// single-query form (property-locked below).
#[inline]
pub fn dot_gather_mq(
    qs: &[f32],
    nq: usize,
    rows: &[f32],
    cols: usize,
    ids: &[u32],
    out: &mut Vec<f32>,
) {
    if cols == 0 || nq == 0 || ids.is_empty() {
        return;
    }
    assert_eq!(qs.len(), nq * cols, "query block length != nq × row width");
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm is reached only when `active()` returned Avx2,
        // i.e. runtime detection confirmed AVX2+FMA — the target-feature
        // contract of the x86 kernel; operand lengths were checked above.
        Dispatch::Avx2 => unsafe { x86::dot_gather_mq(qs, nq, rows, cols, ids, out) },
        #[cfg(target_arch = "aarch64")]
        Dispatch::Neon => neon::dot_gather_mq(qs, nq, rows, cols, ids, out),
        _ => scalar::dot_gather_mq(qs, nq, rows, cols, ids, out),
    }
}

/// Squared distances of `q` to every row of a contiguous row-major buffer,
/// appended to `out` (IVF/k-means centroid assignment).
#[inline]
pub fn l2_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    if cols == 0 {
        return;
    }
    assert_eq!(q.len(), cols, "query length != row width");
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm is reached only when `active()` returned Avx2,
        // i.e. runtime detection confirmed AVX2+FMA — the target-feature
        // contract of the x86 kernel; operand lengths were checked above.
        Dispatch::Avx2 => unsafe { x86::l2_rows(q, rows, cols, out) },
        #[cfg(target_arch = "aarch64")]
        Dispatch::Neon => neon::l2_rows(q, rows, cols, out),
        _ => scalar::l2_rows(q, rows, cols, out),
    }
}

/// Inner product of `q` with one bf16 (bit-truncated f32) row.
#[inline]
pub fn dot_f16(q: &[f32], row: &[u16]) -> f32 {
    assert_eq!(q.len(), row.len(), "dot_f16 operand lengths differ");
    #[cfg(target_arch = "x86_64")]
    if active() == Dispatch::Avx2 {
        // SAFETY: Avx2 dispatch means runtime detection confirmed
        // AVX2+FMA — the target-feature contract of the x86 kernel;
        // operand lengths were checked above.
        return unsafe { x86::dot_f16(q, row) };
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Dispatch::Neon {
        return neon::dot_f16(q, row);
    }
    scalar::dot_f16(q, row)
}

/// Unscaled inner product of `q` with one int8 row (the caller multiplies
/// by the row's symmetric scale).
#[inline]
pub fn dot_i8(q: &[f32], row: &[i8]) -> f32 {
    assert_eq!(q.len(), row.len(), "dot_i8 operand lengths differ");
    #[cfg(target_arch = "x86_64")]
    if active() == Dispatch::Avx2 {
        // SAFETY: Avx2 dispatch means runtime detection confirmed
        // AVX2+FMA — the target-feature contract of the x86 kernel;
        // operand lengths were checked above.
        return unsafe { x86::dot_i8(q, row) };
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Dispatch::Neon {
        return neon::dot_i8(q, row);
    }
    scalar::dot_i8(q, row)
}

/// Scores of `q` against every contiguous bf16 row, appended to `out`.
#[inline]
pub fn dot_rows_f16(q: &[f32], rows: &[u16], cols: usize, out: &mut Vec<f32>) {
    if cols == 0 {
        return;
    }
    assert_eq!(q.len(), cols, "query length != row width");
    #[cfg(target_arch = "x86_64")]
    if active() == Dispatch::Avx2 {
        // SAFETY: Avx2 dispatch means runtime detection confirmed
        // AVX2+FMA — the target-feature contract of the x86 kernel;
        // operand lengths were checked above.
        return unsafe { x86::dot_rows_f16(q, rows, cols, out) };
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Dispatch::Neon {
        return neon::dot_rows_f16(q, rows, cols, out);
    }
    scalar::dot_rows_f16(q, rows, cols, out)
}

/// Scores of `q` against every contiguous int8 row with its per-row scale
/// applied, appended to `out`.
#[inline]
pub fn dot_rows_i8(q: &[f32], rows: &[i8], scales: &[f32], cols: usize, out: &mut Vec<f32>) {
    if cols == 0 {
        return;
    }
    assert_eq!(q.len(), cols, "query length != row width");
    #[cfg(target_arch = "x86_64")]
    if active() == Dispatch::Avx2 {
        // SAFETY: Avx2 dispatch means runtime detection confirmed
        // AVX2+FMA — the target-feature contract of the x86 kernel;
        // operand lengths were checked above.
        return unsafe { x86::dot_rows_i8(q, rows, scales, cols, out) };
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Dispatch::Neon {
        return neon::dot_rows_i8(q, rows, scales, cols, out);
    }
    scalar::dot_rows_i8(q, rows, scales, cols, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::seed_from(seed);
        let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        (a, b)
    }

    #[test]
    fn dispatched_dot_matches_scalar_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 67, 257] {
            let (a, b) = vecs(n, n as u64 + 1);
            assert_eq!(
                dot(&a, &b).to_bits(),
                scalar::dot(&a, &b).to_bits(),
                "dot diverged at n={n} under {:?}",
                active()
            );
            assert_eq!(
                l2_sq(&a, &b).to_bits(),
                scalar::l2_sq(&a, &b).to_bits(),
                "l2_sq diverged at n={n} under {:?}",
                active()
            );
        }
    }

    #[test]
    fn batch_forms_match_row_form() {
        let cols = 48;
        let rows_n = 37;
        let (q, _) = vecs(cols, 3);
        let (rows, _) = vecs(cols * rows_n, 4);
        let mut batched = Vec::new();
        dot_rows(&q, &rows, cols, &mut batched);
        assert_eq!(batched.len(), rows_n);
        for (r, &s) in batched.iter().enumerate() {
            let want = dot(&q, &rows[r * cols..(r + 1) * cols]);
            assert_eq!(s.to_bits(), want.to_bits(), "dot_rows row {r}");
        }
        let ids: Vec<u32> = (0..rows_n as u32).rev().collect();
        let mut gathered = Vec::new();
        dot_gather(&q, &rows, cols, &ids, &mut gathered);
        for (j, &id) in ids.iter().enumerate() {
            let want = batched[id as usize];
            assert_eq!(gathered[j].to_bits(), want.to_bits(), "dot_gather id {id}");
        }
        let mut l2b = Vec::new();
        l2_rows(&q, &rows, cols, &mut l2b);
        for (r, &s) in l2b.iter().enumerate() {
            let want = l2_sq(&q, &rows[r * cols..(r + 1) * cols]);
            assert_eq!(s.to_bits(), want.to_bits(), "l2_rows row {r}");
        }
    }

    #[test]
    fn multi_query_gather_matches_per_query_gather_bitwise() {
        // The wave scheduler's fused scoring path: id-major multi-query
        // gather must reproduce the per-query gather bit-for-bit for
        // every query, including odd widths and a single id.
        for (nq, cols, rows_n) in [(1usize, 48usize, 37usize), (4, 33, 19), (8, 64, 1)] {
            let (qs, _) = vecs(nq * cols, (nq * cols) as u64 + 11);
            let (rows, _) = vecs(cols * rows_n, (cols * rows_n) as u64 + 13);
            let ids: Vec<u32> = (0..rows_n as u32).rev().collect();
            let mut fused = Vec::new();
            dot_gather_mq(&qs, nq, &rows, cols, &ids, &mut fused);
            assert_eq!(fused.len(), nq * ids.len());
            for qi in 0..nq {
                let mut solo = Vec::new();
                dot_gather(&qs[qi * cols..(qi + 1) * cols], &rows, cols, &ids, &mut solo);
                for (j, &want) in solo.iter().enumerate() {
                    assert_eq!(
                        fused[qi * ids.len() + j].to_bits(),
                        want.to_bits(),
                        "dot_gather_mq q{qi} id {j} under {:?}",
                        active()
                    );
                }
            }
        }
        // Degenerate inputs append nothing.
        let mut out = vec![1.0f32];
        dot_gather_mq(&[], 0, &[1.0, 2.0], 2, &[0], &mut out);
        dot_gather_mq(&[1.0, 2.0], 1, &[1.0, 2.0], 2, &[], &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn quantized_dots_approximate_f32() {
        let cols = 64;
        let (q, row) = vecs(cols, 9);
        let exact = dot(&q, &row);
        // bf16 truncation: ~3 decimal digits of the key survive.
        let h: Vec<u16> = row.iter().map(|v| (v.to_bits() >> 16) as u16).collect();
        let approx = dot_f16(&q, &h);
        assert!(
            (approx - exact).abs() < 0.2 * exact.abs().max(1.0),
            "f16 dot too far: {approx} vs {exact}"
        );
        // int8 symmetric: ~0.5% per-coordinate error.
        let max = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = max / 127.0;
        let qrow: Vec<i8> =
            row.iter().map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8).collect();
        let approx = scale * dot_i8(&q, &qrow);
        assert!(
            (approx - exact).abs() < 0.2 * exact.abs().max(1.0),
            "i8 dot too far: {approx} vs {exact}"
        );
        // Batched forms agree with the row forms.
        let mut out = Vec::new();
        dot_rows_f16(&q, &h, cols, &mut out);
        assert_eq!(out[0].to_bits(), dot_f16(&q, &h).to_bits());
        out.clear();
        dot_rows_i8(&q, &qrow, &[scale], cols, &mut out);
        assert!((out[0] - scale * dot_i8(&q, &qrow)).abs() < 1e-6);
    }

    #[test]
    fn active_is_stable_and_labeled() {
        let a = active();
        assert_eq!(a, active(), "dispatch must be cached");
        assert!(!a.label().is_empty());
    }
}
