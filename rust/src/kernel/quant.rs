//! The quantized scan tier: compressed per-chunk mirrors of the segmented
//! key store (RetroInfer-style "the KV cache is a vector storage engine").
//!
//! A scan-tier mirror exists to be *streamed*, not to be exact: index
//! traversals (graph hops, IVF posting lists, flat scans) rank candidates
//! against it, moving 2–4× fewer bytes per candidate, while the final
//! attention read and the `retrieval.quant.rerank` exact re-scoring pass
//! stay f32 — quantization error is confined to candidate *ordering*,
//! exactly where ANN search already tolerates approximation.
//!
//! Two formats:
//!
//! * [`QuantMode::Fp16`] — bit-truncated f32 (the top 16 bits: sign,
//!   exponent, 7 mantissa bits — i.e. bfloat16). 2 B/dim, ~0.4% relative
//!   error, no per-row metadata.
//! * [`QuantMode::Int8`] — symmetric per-row int8: `v ≈ scale · q` with
//!   `scale = max|row| / 127`. 1 B/dim + 4 B/row, the paper-adjacent
//!   "compress the scan tier" point on the bandwidth/accuracy curve.
//!
//! Mirrors are built chunk-at-a-time where chunks are born — store
//! append/merge/compact, which run at prefill-build and maintenance-worker
//! time — so quantization cost never lands on the token path.

use crate::tensor::Matrix;

/// Scan-tier quantization mode (`retrieval.quant.mode`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QuantMode {
    /// No mirror: scans read the f32 rows (the exact baseline).
    #[default]
    Off,
    /// Bit-truncated f32 (bfloat16), 2 B/dim.
    Fp16,
    /// Symmetric per-row int8, 1 B/dim + one f32 scale per row.
    Int8,
}

impl QuantMode {
    pub const ALL: [QuantMode; 3] = [QuantMode::Off, QuantMode::Fp16, QuantMode::Int8];

    pub fn enabled(self) -> bool {
        self != QuantMode::Off
    }

    pub fn label(self) -> &'static str {
        match self {
            QuantMode::Off => "off",
            QuantMode::Fp16 => "fp16",
            QuantMode::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Option<QuantMode> {
        QuantMode::ALL.iter().copied().find(|m| m.label().eq_ignore_ascii_case(s))
    }
}

/// A quantized mirror of one store chunk. Immutable once built (mirrors
/// ride the same `Arc`-sharing discipline as the chunks they shadow).
#[derive(Clone, Debug)]
pub enum QuantChunk {
    /// Row-major bf16 payload.
    F16 { cols: usize, data: Vec<u16> },
    /// Row-major int8 payload + one symmetric scale per row.
    I8 { cols: usize, data: Vec<i8>, scales: Vec<f32> },
}

impl QuantChunk {
    /// Quantize a chunk; `None` for [`QuantMode::Off`].
    pub fn build(mode: QuantMode, m: &Matrix) -> Option<QuantChunk> {
        match mode {
            QuantMode::Off => None,
            QuantMode::Fp16 => {
                let data = m.as_slice().iter().map(|v| (v.to_bits() >> 16) as u16).collect();
                Some(QuantChunk::F16 { cols: m.cols(), data })
            }
            QuantMode::Int8 => {
                let cols = m.cols();
                let mut data: Vec<i8> = Vec::with_capacity(m.rows() * cols);
                let mut scales: Vec<f32> = Vec::with_capacity(m.rows());
                for r in 0..m.rows() {
                    let row = m.row(r);
                    let max = row.iter().fold(0.0f32, |acc, v| acc.max(v.abs()));
                    let scale = if max > 0.0 { max / 127.0 } else { 1.0 };
                    let inv = 1.0 / scale;
                    scales.push(scale);
                    data.extend(
                        row.iter().map(|v| (v * inv).round().clamp(-127.0, 127.0) as i8),
                    );
                }
                Some(QuantChunk::I8 { cols, data, scales })
            }
        }
    }

    pub fn mode(&self) -> QuantMode {
        match self {
            QuantChunk::F16 { .. } => QuantMode::Fp16,
            QuantChunk::I8 { .. } => QuantMode::Int8,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            QuantChunk::F16 { cols, .. } | QuantChunk::I8 { cols, .. } => *cols,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            QuantChunk::F16 { cols, data } => data.len() / (*cols).max(1),
            QuantChunk::I8 { scales, .. } => scales.len(),
        }
    }

    /// Approximate score of `q` against chunk-local row `local`.
    #[inline]
    pub fn score(&self, q: &[f32], local: usize) -> f32 {
        match self {
            QuantChunk::F16 { cols, data } => {
                super::dot_f16(q, &data[local * cols..(local + 1) * cols])
            }
            QuantChunk::I8 { cols, data, scales } => {
                scales[local] * super::dot_i8(q, &data[local * cols..(local + 1) * cols])
            }
        }
    }

    /// Batched contiguous scan of chunk-local rows `[lo, hi)`, appended to
    /// `out` (the flat-scan fast path: one dispatch, streaming reads).
    pub fn score_range(&self, q: &[f32], lo: usize, hi: usize, out: &mut Vec<f32>) {
        debug_assert!(lo <= hi && hi <= self.rows());
        match self {
            QuantChunk::F16 { cols, data } => {
                super::dot_rows_f16(q, &data[lo * cols..hi * cols], *cols, out)
            }
            QuantChunk::I8 { cols, data, scales } => {
                super::dot_rows_i8(q, &data[lo * cols..hi * cols], &scales[lo..hi], *cols, out)
            }
        }
    }

    /// Batched gather-scan by chunk-local row ids, appended to `out`. The
    /// payload is matched once (not per id) and the gather prefetches a
    /// few ids ahead, mirroring the f32 `dot_gather` discipline — the
    /// quantized rows are the bandwidth product, so they get at least the
    /// same amortization.
    pub fn score_ids(&self, q: &[f32], locals: &[u32], out: &mut Vec<f32>) {
        const AHEAD: usize = 4;
        out.reserve(locals.len());
        match self {
            QuantChunk::F16 { cols, data } => {
                for (i, &l) in locals.iter().enumerate() {
                    if let Some(&nxt) = locals.get(i + AHEAD) {
                        super::prefetch(data.as_ptr().wrapping_add(nxt as usize * cols));
                    }
                    let l = l as usize;
                    out.push(super::dot_f16(q, &data[l * cols..(l + 1) * cols]));
                }
            }
            QuantChunk::I8 { cols, data, scales } => {
                for (i, &l) in locals.iter().enumerate() {
                    if let Some(&nxt) = locals.get(i + AHEAD) {
                        super::prefetch(data.as_ptr().wrapping_add(nxt as usize * cols));
                    }
                    let l = l as usize;
                    out.push(scales[l] * super::dot_i8(q, &data[l * cols..(l + 1) * cols]));
                }
            }
        }
    }

    /// Heap bytes of the mirror payload (memory accounting).
    pub fn bytes(&self) -> usize {
        match self {
            QuantChunk::F16 { data, .. } => data.len() * 2,
            QuantChunk::I8 { data, scales, .. } => data.len() + scales.len() * 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mat(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn off_builds_nothing() {
        assert!(QuantChunk::build(QuantMode::Off, &mat(4, 8, 1)).is_none());
    }

    #[test]
    fn parse_labels_roundtrip() {
        for m in QuantMode::ALL {
            assert_eq!(QuantMode::parse(m.label()), Some(m));
        }
        assert_eq!(QuantMode::parse("nope"), None);
        assert!(!QuantMode::Off.enabled());
        assert!(QuantMode::Int8.enabled());
    }

    #[test]
    fn scores_track_exact_within_tolerance() {
        let m = mat(32, 64, 7);
        let q: Vec<f32> = (0..64).map(|i| ((i * 13 % 29) as f32 - 14.0) * 0.1).collect();
        for mode in [QuantMode::Fp16, QuantMode::Int8] {
            let ch = QuantChunk::build(mode, &m).expect("mirror");
            assert_eq!(ch.rows(), 32);
            assert_eq!(ch.cols(), 64);
            assert_eq!(ch.mode(), mode);
            assert!(ch.bytes() < m.as_slice().len() * 4, "mirror must be smaller than f32");
            let mut ranged = Vec::new();
            ch.score_range(&q, 0, 32, &mut ranged);
            for r in 0..32 {
                let exact = crate::kernel::dot(&q, m.row(r));
                let approx = ch.score(&q, r);
                assert!(
                    (approx - exact).abs() < 0.2 * exact.abs().max(1.0),
                    "{mode:?} row {r}: {approx} vs {exact}"
                );
                assert_eq!(ranged[r].to_bits(), approx.to_bits(), "range/row mismatch");
            }
            let locals: Vec<u32> = (0..32u32).step_by(5).collect();
            let mut gathered = Vec::new();
            ch.score_ids(&q, &locals, &mut gathered);
            for (j, &l) in locals.iter().enumerate() {
                assert_eq!(gathered[j].to_bits(), ch.score(&q, l as usize).to_bits());
            }
        }
    }

    #[test]
    fn int8_handles_zero_rows() {
        let m = Matrix::zeros(3, 8);
        let ch = QuantChunk::build(QuantMode::Int8, &m).expect("mirror");
        assert_eq!(ch.score(&[1.0; 8], 1), 0.0);
    }
}
