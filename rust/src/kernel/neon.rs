//! NEON kernels (aarch64 — NEON is a baseline feature, so no runtime
//! detection is needed beyond the architecture itself).
//!
//! The f32 paths mirror the scalar 8-accumulator unrolling as two 4-lane
//! vectors (multiply + add, no fused contraction) and reduce through the
//! shared [`super::scalar::tree8`] tree, so they are bit-for-bit identical
//! to the scalar and AVX2 backends. The quantized (bf16/int8) paths
//! delegate to the scalar loops, which LLVM auto-vectorises for NEON —
//! the bandwidth win of the smaller payload is format-, not
//! intrinsic-, driven.

use core::arch::aarch64::*;

/// Inner product, bit-identical to [`super::scalar::dot`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        for i in 0..chunks {
            let pa = ap.add(i * 8);
            let pb = bp.add(i * 8);
            // mul + add (not vfmaq): lanes reproduce scalar accumulators.
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(pa), vld1q_f32(pb)));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4))));
        }
        let lanes = [
            vgetq_lane_f32::<0>(acc0),
            vgetq_lane_f32::<1>(acc0),
            vgetq_lane_f32::<2>(acc0),
            vgetq_lane_f32::<3>(acc0),
            vgetq_lane_f32::<0>(acc1),
            vgetq_lane_f32::<1>(acc1),
            vgetq_lane_f32::<2>(acc1),
            vgetq_lane_f32::<3>(acc1),
        ];
        let mut tail = 0.0f32;
        for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
            tail += x * y;
        }
        super::scalar::tree8(&lanes) + tail
    }
}

/// Squared Euclidean distance, bit-identical to [`super::scalar::l2_sq`].
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        for i in 0..chunks {
            let pa = ap.add(i * 8);
            let pb = bp.add(i * 8);
            let d0 = vsubq_f32(vld1q_f32(pa), vld1q_f32(pb));
            let d1 = vsubq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4)));
            acc0 = vaddq_f32(acc0, vmulq_f32(d0, d0));
            acc1 = vaddq_f32(acc1, vmulq_f32(d1, d1));
        }
        let lanes = [
            vgetq_lane_f32::<0>(acc0),
            vgetq_lane_f32::<1>(acc0),
            vgetq_lane_f32::<2>(acc0),
            vgetq_lane_f32::<3>(acc0),
            vgetq_lane_f32::<0>(acc1),
            vgetq_lane_f32::<1>(acc1),
            vgetq_lane_f32::<2>(acc1),
            vgetq_lane_f32::<3>(acc1),
        ];
        let mut tail = 0.0f32;
        for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
            let d = x - y;
            tail += d * d;
        }
        super::scalar::tree8(&lanes) + tail
    }
}

/// Batched contiguous row scores.
pub fn dot_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(dot(q, row));
    }
}

/// Batched gather scores.
pub fn dot_gather(q: &[f32], rows: &[f32], cols: usize, ids: &[u32], out: &mut Vec<f32>) {
    out.reserve(ids.len());
    for &id in ids {
        let off = id as usize * cols;
        out.push(dot(q, &rows[off..off + cols]));
    }
}

/// Batched contiguous row squared distances.
pub fn l2_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(l2_sq(q, row));
    }
}
