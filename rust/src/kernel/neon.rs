//! NEON kernels (aarch64 — NEON is a baseline feature, so no runtime
//! detection is needed beyond the architecture itself).
//!
//! The f32 paths mirror the scalar 8-accumulator unrolling as two 4-lane
//! vectors (multiply + add, no fused contraction) and reduce through the
//! shared [`super::scalar::tree8`] tree, so they are bit-for-bit identical
//! to the scalar and AVX2 backends. The quantized (bf16/int8) paths are
//! intrinsic too (the PR-4 "NEON-intrinsic f16/i8" follow-up): bf16 rows
//! widen u16→u32, shift into f32 bit position and reinterpret; int8 rows
//! sign-extend i8→i16→i32 and convert — both then run the same 2×4-lane
//! multiply+add as the f32 kernels. Quantized scores are approximate by
//! construction, so (as on AVX2) they need not match the scalar loop
//! bitwise — only the batch forms must match the row forms bitwise, which
//! holds because the batch forms call the row forms per row.

use core::arch::aarch64::*;

/// Inner product, bit-identical to [`super::scalar::dot`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    // SAFETY: NEON is a baseline aarch64 feature, so the intrinsics are
    // always executable; every vld1q_f32 reads lanes i*8..i*8+8 with
    // i < chunks = n/8, staying inside both slices (the public dispatch
    // wrapper asserts a.len() == b.len()).
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        for i in 0..chunks {
            let pa = ap.add(i * 8);
            let pb = bp.add(i * 8);
            // mul + add (not vfmaq): lanes reproduce scalar accumulators.
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(pa), vld1q_f32(pb)));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4))));
        }
        let lanes = [
            vgetq_lane_f32::<0>(acc0),
            vgetq_lane_f32::<1>(acc0),
            vgetq_lane_f32::<2>(acc0),
            vgetq_lane_f32::<3>(acc0),
            vgetq_lane_f32::<0>(acc1),
            vgetq_lane_f32::<1>(acc1),
            vgetq_lane_f32::<2>(acc1),
            vgetq_lane_f32::<3>(acc1),
        ];
        let mut tail = 0.0f32;
        for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
            tail += x * y;
        }
        super::scalar::tree8(&lanes) + tail
    }
}

/// Squared Euclidean distance, bit-identical to [`super::scalar::l2_sq`].
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    // SAFETY: as in [`dot`] — baseline NEON, and every load stays within
    // the first chunks*8 <= len elements of both equal-length slices.
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        for i in 0..chunks {
            let pa = ap.add(i * 8);
            let pb = bp.add(i * 8);
            let d0 = vsubq_f32(vld1q_f32(pa), vld1q_f32(pb));
            let d1 = vsubq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4)));
            acc0 = vaddq_f32(acc0, vmulq_f32(d0, d0));
            acc1 = vaddq_f32(acc1, vmulq_f32(d1, d1));
        }
        let lanes = [
            vgetq_lane_f32::<0>(acc0),
            vgetq_lane_f32::<1>(acc0),
            vgetq_lane_f32::<2>(acc0),
            vgetq_lane_f32::<3>(acc0),
            vgetq_lane_f32::<0>(acc1),
            vgetq_lane_f32::<1>(acc1),
            vgetq_lane_f32::<2>(acc1),
            vgetq_lane_f32::<3>(acc1),
        ];
        let mut tail = 0.0f32;
        for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
            let d = x - y;
            tail += d * d;
        }
        super::scalar::tree8(&lanes) + tail
    }
}

/// Batched contiguous row scores.
pub fn dot_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(dot(q, row));
    }
}

/// Batched gather scores.
pub fn dot_gather(q: &[f32], rows: &[f32], cols: usize, ids: &[u32], out: &mut Vec<f32>) {
    out.reserve(ids.len());
    for &id in ids {
        let off = id as usize * cols;
        out.push(dot(q, &rows[off..off + cols]));
    }
}

/// Multi-query gather scores, query-major output, id-major loop: each
/// gathered row is loaded once and scored against every query with the
/// same [`dot`] as the single-query form (so scores are bit-identical).
pub fn dot_gather_mq(
    qs: &[f32],
    nq: usize,
    rows: &[f32],
    cols: usize,
    ids: &[u32],
    out: &mut Vec<f32>,
) {
    let base = out.len();
    out.resize(base + nq * ids.len(), 0.0);
    for (j, &id) in ids.iter().enumerate() {
        let off = id as usize * cols;
        let row = &rows[off..off + cols];
        for qi in 0..nq {
            out[base + qi * ids.len() + j] = dot(&qs[qi * cols..(qi + 1) * cols], row);
        }
    }
}

/// Batched contiguous row squared distances.
pub fn l2_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(l2_sq(q, row));
    }
}

/// bf16 (bit-truncated f32) row inner product: widen 8×u16 → 2×4×u32,
/// shift into the high half and reinterpret as f32, then the standard
/// 2×4-lane multiply+add.
#[inline]
pub fn dot_f16(q: &[f32], row: &[u16]) -> f32 {
    debug_assert_eq!(q.len(), row.len());
    let n = q.len();
    let chunks = n / 8;
    // SAFETY: baseline NEON; vld1q_u16/vld1q_f32 read lanes i*8..i*8+8
    // with i < chunks = n/8, inside both equal-length slices (length
    // equality is asserted by the public dispatch wrapper).
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let (qp, rp) = (q.as_ptr(), row.as_ptr());
        for i in 0..chunks {
            let h = vld1q_u16(rp.add(i * 8));
            let lo = vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vget_low_u16(h))));
            let hi = vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vget_high_u16(h))));
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(qp.add(i * 8)), lo));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(qp.add(i * 8 + 4)), hi));
        }
        let lanes = [
            vgetq_lane_f32::<0>(acc0),
            vgetq_lane_f32::<1>(acc0),
            vgetq_lane_f32::<2>(acc0),
            vgetq_lane_f32::<3>(acc0),
            vgetq_lane_f32::<0>(acc1),
            vgetq_lane_f32::<1>(acc1),
            vgetq_lane_f32::<2>(acc1),
            vgetq_lane_f32::<3>(acc1),
        ];
        let mut tail = 0.0f32;
        for (x, &h) in q[chunks * 8..].iter().zip(&row[chunks * 8..]) {
            tail += x * super::scalar::f16_to_f32(h);
        }
        super::scalar::tree8(&lanes) + tail
    }
}

/// int8 row inner product (unscaled): sign-extend 8×i8 → i16 → 2×4×i32,
/// convert to f32, then the standard 2×4-lane multiply+add.
#[inline]
pub fn dot_i8(q: &[f32], row: &[i8]) -> f32 {
    debug_assert_eq!(q.len(), row.len());
    let n = q.len();
    let chunks = n / 8;
    // SAFETY: baseline NEON; vld1_s8/vld1q_f32 read lanes i*8..i*8+8 with
    // i < chunks = n/8, inside both equal-length slices (length equality
    // is asserted by the public dispatch wrapper).
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let (qp, rp) = (q.as_ptr(), row.as_ptr());
        for i in 0..chunks {
            let w = vmovl_s8(vld1_s8(rp.add(i * 8)));
            let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w)));
            let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w)));
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(qp.add(i * 8)), lo));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(qp.add(i * 8 + 4)), hi));
        }
        let lanes = [
            vgetq_lane_f32::<0>(acc0),
            vgetq_lane_f32::<1>(acc0),
            vgetq_lane_f32::<2>(acc0),
            vgetq_lane_f32::<3>(acc0),
            vgetq_lane_f32::<0>(acc1),
            vgetq_lane_f32::<1>(acc1),
            vgetq_lane_f32::<2>(acc1),
            vgetq_lane_f32::<3>(acc1),
        ];
        let mut tail = 0.0f32;
        for (x, &v) in q[chunks * 8..].iter().zip(&row[chunks * 8..]) {
            tail += x * v as f32;
        }
        super::scalar::tree8(&lanes) + tail
    }
}

/// Batched contiguous bf16 row scores (bitwise equal to [`dot_f16`] per
/// row — the batch/row consistency the quant property tests pin down).
pub fn dot_rows_f16(q: &[f32], rows: &[u16], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(dot_f16(q, row));
    }
}

/// Batched contiguous int8 row scores with per-row scales applied.
pub fn dot_rows_i8(q: &[f32], rows: &[i8], scales: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for (row, &scale) in rows.chunks_exact(cols).zip(scales.iter()) {
        out.push(scale * dot_i8(q, row));
    }
}
