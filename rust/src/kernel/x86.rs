//! AVX2 + FMA kernels (x86_64).
//!
//! Every function here carries `#[target_feature(enable = "avx2,fma")]`
//! and is reached only through the dispatch wrappers in the parent module
//! after runtime feature detection. The f32 paths deliberately use
//! multiply + add (no FMA contraction) with the shared
//! [`super::scalar::tree8`] reduction so they are bit-for-bit identical
//! to the scalar fallback; the quantized paths are approximate by
//! construction and use FMA for throughput.

use core::arch::x86_64::*;

/// How many gather ids ahead the software prefetch runs. Row payloads are
/// 1–4 cache lines at head-dim 64; four ids of headroom hides most of the
/// DRAM latency without thrashing the fill buffers.
const PREFETCH_AHEAD: usize = 4;

/// Horizontal reduction matching [`super::scalar::tree8`] bit-for-bit.
///
/// # Safety
/// Requires AVX; only called from the `#[target_feature(avx2,fma)]`
/// kernels below, whose own contract guarantees it.
#[inline]
unsafe fn sum8(v: __m256) -> f32 {
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), v);
    super::scalar::tree8(&lanes)
}

/// Inner product, bit-identical to [`super::scalar::dot`].
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    for i in 0..chunks {
        let va = _mm256_loadu_ps(ap.add(i * 8));
        let vb = _mm256_loadu_ps(bp.add(i * 8));
        // mul + add (not FMA): lane l reproduces scalar accumulator s[l].
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
    }
    let mut tail = 0.0f32;
    for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
        tail += x * y;
    }
    sum8(acc) + tail
}

/// Squared Euclidean distance, bit-identical to [`super::scalar::l2_sq`].
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    for i in 0..chunks {
        let d = _mm256_sub_ps(_mm256_loadu_ps(ap.add(i * 8)), _mm256_loadu_ps(bp.add(i * 8)));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
    }
    let mut tail = 0.0f32;
    for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
        let d = x - y;
        tail += d * d;
    }
    sum8(acc) + tail
}

/// Batched contiguous row scores.
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(dot(q, row));
    }
}

/// Batched gather scores with software prefetch ahead of the gather.
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_gather(q: &[f32], rows: &[f32], cols: usize, ids: &[u32], out: &mut Vec<f32>) {
    out.reserve(ids.len());
    let base = rows.as_ptr();
    for (i, &id) in ids.iter().enumerate() {
        if let Some(&nxt) = ids.get(i + PREFETCH_AHEAD) {
            // wrapping_add: prefetch never faults, but computing an
            // out-of-allocation pointer with `add` would still be UB if a
            // caller ever passed a bogus id (the scoring slice below
            // bounds-checks it properly).
            _mm_prefetch::<_MM_HINT_T0>(base.wrapping_add(nxt as usize * cols) as *const i8);
        }
        let off = id as usize * cols;
        out.push(dot(q, &rows[off..off + cols]));
    }
}

/// Multi-query gather scores, query-major output, id-major loop: each
/// gathered row is loaded once (with the same [`PREFETCH_AHEAD`] software
/// prefetch as [`dot_gather`]) and scored against every query with the
/// same [`dot`], so scores are bit-identical to the single-query form.
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_gather_mq(
    qs: &[f32],
    nq: usize,
    rows: &[f32],
    cols: usize,
    ids: &[u32],
    out: &mut Vec<f32>,
) {
    let base_len = out.len();
    out.resize(base_len + nq * ids.len(), 0.0);
    let base = rows.as_ptr();
    for (j, &id) in ids.iter().enumerate() {
        if let Some(&nxt) = ids.get(j + PREFETCH_AHEAD) {
            // wrapping_add: prefetch never faults, but computing an
            // out-of-allocation pointer with `add` would still be UB if a
            // caller ever passed a bogus id (the scoring slice below
            // bounds-checks it properly).
            _mm_prefetch::<_MM_HINT_T0>(base.wrapping_add(nxt as usize * cols) as *const i8);
        }
        let off = id as usize * cols;
        let row = &rows[off..off + cols];
        for qi in 0..nq {
            out[base_len + qi * ids.len() + j] = dot(&qs[qi * cols..(qi + 1) * cols], row);
        }
    }
}

/// Batched contiguous row squared distances.
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn l2_rows(q: &[f32], rows: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(l2_sq(q, row));
    }
}

/// bf16 row inner product: widen 8×u16 → 8×u32, shift into the f32
/// exponent position, FMA against the query.
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_f16(q: &[f32], row: &[u16]) -> f32 {
    debug_assert_eq!(q.len(), row.len());
    let n = q.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    let (qp, rp) = (q.as_ptr(), row.as_ptr());
    for i in 0..chunks {
        let h = _mm_loadu_si128(rp.add(i * 8) as *const __m128i);
        let k = _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)));
        acc = _mm256_fmadd_ps(k, _mm256_loadu_ps(qp.add(i * 8)), acc);
    }
    let mut s = sum8(acc);
    for (x, &h) in q[chunks * 8..].iter().zip(&row[chunks * 8..]) {
        s += x * super::scalar::f16_to_f32(h);
    }
    s
}

/// int8 row inner product (unscaled): sign-extend 8×i8 → 8×i32, convert,
/// FMA against the query.
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_i8(q: &[f32], row: &[i8]) -> f32 {
    debug_assert_eq!(q.len(), row.len());
    let n = q.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    let (qp, rp) = (q.as_ptr(), row.as_ptr());
    for i in 0..chunks {
        let b = _mm_loadl_epi64(rp.add(i * 8) as *const __m128i);
        let k = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(b));
        acc = _mm256_fmadd_ps(k, _mm256_loadu_ps(qp.add(i * 8)), acc);
    }
    let mut s = sum8(acc);
    for (x, &v) in q[chunks * 8..].iter().zip(&row[chunks * 8..]) {
        s += x * v as f32;
    }
    s
}

/// Batched contiguous bf16 row scores.
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_rows_f16(q: &[f32], rows: &[u16], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for row in rows.chunks_exact(cols) {
        out.push(dot_f16(q, row));
    }
}

/// Batched contiguous int8 row scores with per-row scales applied.
///
/// # Safety
/// Requires AVX2 + FMA (runtime-detected by the caller).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_rows_i8(q: &[f32], rows: &[i8], scales: &[f32], cols: usize, out: &mut Vec<f32>) {
    out.reserve(rows.len() / cols);
    for (row, &scale) in rows.chunks_exact(cols).zip(scales.iter()) {
        out.push(scale * dot_i8(q, row));
    }
}
