//! Unified telemetry: the process metrics registry, the structured span
//! facade, and the crash-surviving flight recorder.
//!
//! Three parts, one module, threaded through every layer of the serving
//! stack (see docs/observability.md for the full metric-name registry,
//! the span taxonomy, and the knob table):
//!
//! 1. **Metrics registry** ([`registry`]): a process-wide registry of
//!    named counters, gauges, and histograms. Registration (first use of
//!    a name) takes a short-lived `RwLock` write; every *update* is a
//!    single atomic on a pre-fetched [`Arc`] handle — lock-free on the
//!    hot path. Histograms are **fixed-size log-bucketed** (one bucket
//!    per power of two, [`HIST_BUCKETS`] buckets total) rather than
//!    raw-sample vectors, so a histogram's memory is a constant ~700
//!    bytes no matter how many million requests it has absorbed.
//!    Populated by the coordinator (queue depth, wave occupancy,
//!    respawns), the store (resident/disk bytes, parks, resumes,
//!    recovered, quarantined), maintenance (drains, reclaims, tombstone
//!    ratio), policy (streaming fraction, index bytes avoided), and the
//!    kernel (dispatch backend, quantized vs exact scores). Exposed via
//!    the server's `{"stats": true}` verb, `Client::stats()`, and the
//!    `stats` CLI subcommand.
//!
//! 2. **Span facade** ([`SpanAcc`], [`Stopwatch`], [`span_record`]):
//!    structured tracing of the decode wave — prefill, embed, QKV,
//!    device attention, retrieval, candidate assembly, host attention,
//!    γ-combine, FFN, maintenance publish — plus the phases the old
//!    ad-hoc `PhaseTimer` plumbing could not see (snapshot, restore,
//!    wave-scheduling gaps). Per-request span trees are **aggregated**
//!    (fixed [`Phase`] slots: count + total seconds each), so a
//!    thousand-token request emits a bounded tree into its done event
//!    instead of a thousand raw spans. Collection is gated on the
//!    `serving.telemetry.spans` knob through [`spans_on`] — one relaxed
//!    atomic load, no allocation, no timing when disabled — and the
//!    batched-vs-serial equivalence suite proves decoded tokens are
//!    bit-identical with spans on (timing never feeds back into
//!    compute). Opt-in: `serving.telemetry.trace_path` additionally
//!    streams every span as a `chrome://tracing`-compatible JSON event
//!    (array format; the trailing `]` is optional, so the file is
//!    loadable even mid-run or after a crash).
//!
//! 3. **Flight recorder** ([`flightrec`], [`flightrec_dump`]): a bounded
//!    in-memory ring of recent structured events — admissions,
//!    retirements, maintenance jobs, failpoint hits, quarantines,
//!    respawns. The replica supervisor dumps it to
//!    `spill_dir/flightrec-<ts>.jsonl` when a worker dies, turning "the
//!    replica panicked" into a replayable event history whose tail
//!    explains the crash. Capacity is `serving.telemetry
//!    .flightrec_capacity` (0 disables recording entirely).
//!
//! Concurrency: every atomic comes from the `util::sync` facade, so
//! `make loom` swaps in the instrumented twins; all registry state lives
//! behind a runtime-initialized `OnceLock`, never a const-constructed
//! static. All orderings here are `Relaxed` (this file is on the
//! linter's allowlist): telemetry values are monotone diagnostics — no
//! other memory is published through them.

use crate::config::TelemetryConfig;
use crate::util::json::Value;
use crate::util::sync::{
    Arc, AtomicBool, AtomicU64, Mutex, OnceLock, Ordering, PoisonError, RwLock,
};
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Metrics registry
// ---------------------------------------------------------------------------

/// A monotone event counter.
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        // Relaxed (allowlisted counter): monotone diagnostic, publishes
        // nothing.
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-writer-wins instantaneous value (stored as f64 bits).
pub struct Gauge(AtomicU64);

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        // Relaxed (allowlisted counter): last-writer-wins diagnostic.
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    pub fn set_u64(&self, v: u64) {
        self.set(v as f64);
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Number of log₂ buckets per histogram. Bucket `i` covers values in
/// `[2^(i-HIST_EXP_OFFSET), 2^(i-HIST_EXP_OFFSET+1))`, spanning ~1e-12
/// (sub-nanosecond latencies) to ~5e11 (hundreds of GB), which brackets
/// every quantity the stack records.
pub const HIST_BUCKETS: usize = 80;
const HIST_EXP_OFFSET: i64 = 40;

/// Bounded-memory latency/size distribution: fixed log-bucketed counts
/// plus exact sum/count/max. Unlike `metrics::LatencyHistogram` (a
/// raw-sample vector for offline bench percentiles), this never grows —
/// the per-bucket resolution (one power of two, quantile error ≤ 2×) is
/// the price of million-request uptimes at constant memory.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    /// f64 bits, accumulated by CAS (the facade's atomics have no
    /// fetch-add for floats).
    sum_bits: AtomicU64,
    /// f64 bits; non-negative floats order like their bit patterns.
    max_bits: AtomicU64,
}

fn bucket_index(v: f64) -> usize {
    if v <= 0.0 || !v.is_finite() {
        return 0;
    }
    // Floor of log2(v) straight from the IEEE exponent field.
    let exp = ((v.to_bits() >> 52) & 0x7ff) as i64 - 1023;
    (exp + HIST_EXP_OFFSET).clamp(0, HIST_BUCKETS as i64 - 1) as usize
}

/// Geometric midpoint of bucket `i` — the representative value quantile
/// queries report.
fn bucket_value(i: usize) -> f64 {
    1.5 * ((i as i64 - HIST_EXP_OFFSET) as f64).exp2()
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            max_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Record one observation. Lock-free, allocation-free.
    pub fn record(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        // Relaxed (allowlisted counters): independent diagnostics; a
        // snapshot racing an update misattributes at most one sample.
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        let bits = v.to_bits();
        let mut cur = self.max_bits.load(Ordering::Relaxed);
        while bits > cur {
            match self.max_bits.compare_exchange_weak(cur, bits, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Nearest-rank quantile over the bucketed counts; reports the
    /// matched bucket's geometric midpoint (error ≤ one octave).
    pub fn quantile(&self, q: f64) -> f64 {
        let total: u64 = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                return bucket_value(i);
            }
        }
        bucket_value(HIST_BUCKETS - 1)
    }

    fn to_json(&self) -> Value {
        let count = self.count();
        let sum = self.sum();
        let mut o = Value::obj();
        o.set("count", count)
            .set("sum", sum)
            .set("mean", if count == 0 { 0.0 } else { sum / count as f64 })
            .set("p50", self.quantile(0.50))
            .set("p90", self.quantile(0.90))
            .set("p99", self.quantile(0.99))
            .set("max", self.max());
        o
    }
}

/// The process-wide metric registry (see [`registry`]).
pub struct Registry {
    counters: RwLock<HashMap<&'static str, Arc<Counter>>>,
    gauges: RwLock<HashMap<&'static str, Arc<Gauge>>>,
    histograms: RwLock<HashMap<&'static str, Arc<Histogram>>>,
    /// Non-numeric facts (e.g. the kernel dispatch backend).
    labels: RwLock<HashMap<&'static str, &'static str>>,
}

fn get_or_register<T>(
    map: &RwLock<HashMap<&'static str, Arc<T>>>,
    name: &'static str,
    make: impl FnOnce() -> T,
) -> Arc<T> {
    if let Some(v) = map.read().unwrap_or_else(PoisonError::into_inner).get(name) {
        return Arc::clone(v);
    }
    let mut w = map.write().unwrap_or_else(PoisonError::into_inner);
    Arc::clone(w.entry(name).or_insert_with(|| Arc::new(make())))
}

impl Registry {
    fn new() -> Registry {
        Registry {
            counters: RwLock::new(HashMap::new()),
            gauges: RwLock::new(HashMap::new()),
            histograms: RwLock::new(HashMap::new()),
            labels: RwLock::new(HashMap::new()),
        }
    }

    /// Get-or-register a counter. Hold the returned handle on hot paths
    /// (updates through it never touch the registry lock).
    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        get_or_register(&self.counters, name, || Counter(AtomicU64::new(0)))
    }

    pub fn gauge(&self, name: &'static str) -> Arc<Gauge> {
        get_or_register(&self.gauges, name, || Gauge(AtomicU64::new(0f64.to_bits())))
    }

    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        get_or_register(&self.histograms, name, Histogram::new)
    }

    /// Record a non-numeric fact (last writer wins).
    pub fn set_label(&self, name: &'static str, value: &'static str) {
        self.labels.write().unwrap_or_else(PoisonError::into_inner).insert(name, value);
    }

    /// A point-in-time JSON snapshot of everything registered:
    /// `{"counters": {...}, "gauges": {...}, "histograms": {...},
    /// "labels": {...}}`, keys sorted (the JSON object is a BTreeMap).
    pub fn snapshot(&self) -> Value {
        let mut counters = Value::obj();
        for (k, v) in self.counters.read().unwrap_or_else(PoisonError::into_inner).iter() {
            counters.set(k, v.get());
        }
        let mut gauges = Value::obj();
        for (k, v) in self.gauges.read().unwrap_or_else(PoisonError::into_inner).iter() {
            gauges.set(k, v.get());
        }
        let mut histograms = Value::obj();
        for (k, v) in self.histograms.read().unwrap_or_else(PoisonError::into_inner).iter() {
            histograms.set(k, v.to_json());
        }
        let mut labels = Value::obj();
        for (k, v) in self.labels.read().unwrap_or_else(PoisonError::into_inner).iter() {
            labels.set(k, *v);
        }
        let mut out = Value::obj();
        out.set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", histograms)
            .set("labels", labels);
        out
    }
}

/// The process-wide registry (lazily constructed; loom-safe because
/// nothing here is a const-initialized facade atomic).
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

// ---------------------------------------------------------------------------
// Span facade
// ---------------------------------------------------------------------------

/// The span taxonomy — every timed phase on the serving path. Decode-wave
/// phases (`Embed` … `Ffn`) nest under `decode` in the emitted tree;
/// fused phases (retrieval, host attention) are attributed to each live
/// session as an equal share, exactly like the `PhaseBreakdown` shares
/// the done event has always carried.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Prefill = 0,
    Embed = 1,
    Qkv = 2,
    DeviceAttn = 3,
    Retrieval = 4,
    Candidates = 5,
    HostAttn = 6,
    GammaCombine = 7,
    Ffn = 8,
    Maintenance = 9,
    Snapshot = 10,
    Restore = 11,
}

/// Number of [`Phase`] variants (the fixed width of a [`SpanAcc`]).
pub const PHASE_COUNT: usize = 12;

const PHASE_NAMES: [&str; PHASE_COUNT] = [
    "prefill",
    "embed",
    "qkv",
    "device_attn",
    "retrieval",
    "candidates",
    "host_attn",
    "gamma_combine",
    "ffn",
    "maintenance",
    "snapshot",
    "restore",
];

/// Decode-wave children (indices into [`PHASE_NAMES`]).
const DECODE_CHILDREN: std::ops::Range<usize> = 1..9;

impl Phase {
    pub fn name(self) -> &'static str {
        PHASE_NAMES[self as usize]
    }
}

/// One aggregated span slot: how many times the phase ran and the total
/// seconds it took.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanCell {
    pub count: u64,
    pub total_s: f64,
}

/// A bounded, aggregated per-request span tree: one [`SpanCell`] per
/// [`Phase`]. Cheap to reset, merge, and carry through `RequestMetrics`
/// regardless of how many tokens the request decoded.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanAcc {
    cells: [SpanCell; PHASE_COUNT],
}

impl SpanAcc {
    pub fn reset(&mut self) {
        self.cells = [SpanCell::default(); PHASE_COUNT];
    }

    #[inline]
    pub fn record(&mut self, phase: Phase, secs: f64) {
        let c = &mut self.cells[phase as usize];
        c.count += 1;
        c.total_s += secs;
    }

    pub fn merge(&mut self, other: &SpanAcc) {
        for (a, b) in self.cells.iter_mut().zip(other.cells.iter()) {
            a.count += b.count;
            a.total_s += b.total_s;
        }
    }

    pub fn cell(&self, phase: Phase) -> SpanCell {
        self.cells[phase as usize]
    }

    pub fn is_empty(&self) -> bool {
        self.cells.iter().all(|c| c.count == 0)
    }

    pub fn total_s(&self) -> f64 {
        self.cells.iter().map(|c| c.total_s).sum()
    }

    /// The emitted span tree: top-level `prefill` / `decode` (children:
    /// embed…ffn) / `maintenance` / `snapshot` / `restore`, empty slots
    /// omitted.
    pub fn to_json(&self) -> Value {
        fn cell_json(c: SpanCell) -> Value {
            let mut o = Value::obj();
            o.set("count", c.count).set("total_s", c.total_s);
            o
        }
        let mut out = Value::obj();
        let top = [Phase::Prefill, Phase::Maintenance, Phase::Snapshot, Phase::Restore];
        for p in top {
            let c = self.cells[p as usize];
            if c.count > 0 {
                out.set(p.name(), cell_json(c));
            }
        }
        let mut decode = Value::obj();
        let mut decode_total = 0.0;
        let mut any = false;
        for i in DECODE_CHILDREN {
            let c = self.cells[i];
            if c.count > 0 {
                decode.set(PHASE_NAMES[i], cell_json(c));
                decode_total += c.total_s;
                any = true;
            }
        }
        if any {
            decode.set("total_s", decode_total);
            out.set("decode", decode);
        }
        out
    }
}

/// The one timing mechanism (replaces the old `metrics::PhaseTimer`):
/// start, then `stop_into` a breakdown slot — which also returns the
/// elapsed seconds so the same measurement can feed a [`SpanAcc`] and
/// the trace file without reading the clock twice.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    #[inline]
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    #[inline]
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    #[inline]
    pub fn started(&self) -> Instant {
        self.start
    }

    /// Add the elapsed seconds into a breakdown slot; returns them.
    #[inline]
    pub fn stop_into(&self, slot: &mut f64) -> f64 {
        let s = self.elapsed_s();
        *slot += s;
        s
    }
}

struct TraceState {
    /// Span collection on/off (`serving.telemetry.spans`).
    spans: AtomicBool,
    /// Whether a chrome-trace writer is open (checked before the mutex).
    trace_open: AtomicBool,
    /// Timebase for trace timestamps.
    epoch: Instant,
    trace: Mutex<Option<std::io::BufWriter<std::fs::File>>>,
    flightrec: Mutex<FlightRing>,
}

fn state() -> &'static TraceState {
    static STATE: OnceLock<TraceState> = OnceLock::new();
    STATE.get_or_init(|| TraceState {
        spans: AtomicBool::new(false),
        trace_open: AtomicBool::new(false),
        epoch: Instant::now(),
        trace: Mutex::new(None),
        flightrec: Mutex::new(FlightRing::new(FLIGHTREC_DEFAULT_CAPACITY)),
    })
}

/// Apply the `serving.telemetry` knobs: toggles span collection, sizes
/// the flight-recorder ring, and (once) opens the chrome-trace writer if
/// a path is configured. Engines call this at construction, so every
/// entry point — serial generate, replica workers, tests — honors the
/// same config without extra plumbing.
pub fn configure(cfg: &TelemetryConfig) {
    let st = state();
    // Sticky-on: the most permissive config in the process wins. Engines
    // with different configs coexist (replicas, control engines in
    // tests), and a later spans-off construction must not silently
    // disable the tracing an earlier spans-on engine asked for. Span
    // state is pure timing, so over-collection is always safe.
    if cfg.spans {
        // Relaxed (allowlisted): a pure on/off diagnostic gate.
        st.spans.store(true, Ordering::Relaxed);
    }
    {
        let mut ring = st.flightrec.lock().unwrap_or_else(PoisonError::into_inner);
        ring.set_capacity(cfg.flightrec_capacity);
    }
    if !cfg.trace_path.is_empty() && !st.trace_open.load(Ordering::Relaxed) {
        let mut g = st.trace.lock().unwrap_or_else(PoisonError::into_inner);
        if g.is_none() {
            if let Ok(f) = std::fs::File::create(&cfg.trace_path) {
                let mut w = std::io::BufWriter::new(f);
                // Chrome trace "JSON array format": the trailing `]` is
                // optional, so the file stays loadable after a crash.
                let _ = writeln!(w, "[");
                *g = Some(w);
                st.trace_open.store(true, Ordering::Relaxed);
            }
        }
    }
}

/// Whether span collection is enabled. One relaxed load — the entire
/// cost of the disabled path.
#[inline]
pub fn spans_on() -> bool {
    state().spans.load(Ordering::Relaxed)
}

/// Record a completed span into a per-request accumulator and, when the
/// trace file is open, emit a chrome-trace complete event (`ph: "X"`).
/// `tid` groups events per session/worker lane in the trace viewer.
/// No-op when spans are disabled — one relaxed load, no allocation, and
/// no extra clock reads upstream (callers pass seconds they already
/// measured for the phase breakdown).
#[inline]
pub fn span_record(acc: &mut SpanAcc, phase: Phase, started: Instant, secs: f64, tid: u64) {
    if !spans_on() {
        return;
    }
    acc.record(phase, secs);
    trace_emit(phase.name(), started, secs, tid);
}

/// Emit one chrome-trace event if the writer is open (cheap gate first).
pub fn trace_emit(name: &str, started: Instant, secs: f64, tid: u64) {
    let st = state();
    if !st.trace_open.load(Ordering::Relaxed) {
        return;
    }
    let ts_us = started.checked_duration_since(st.epoch).unwrap_or_default().as_micros();
    let dur_us = (secs * 1e6).max(0.0) as u64;
    let mut g = st.trace.lock().unwrap_or_else(PoisonError::into_inner);
    if let Some(w) = g.as_mut() {
        let _ = writeln!(
            w,
            "{{\"name\":{name:?},\"cat\":\"ra\",\"ph\":\"X\",\"pid\":1,\"tid\":{tid},\"ts\":{ts_us},\"dur\":{dur_us}}},"
        );
        let _ = w.flush();
    }
}

// ---------------------------------------------------------------------------
// Flight recorder
// ---------------------------------------------------------------------------

/// Default bounded ring capacity (`serving.telemetry.flightrec_capacity`).
pub const FLIGHTREC_DEFAULT_CAPACITY: usize = 256;

#[derive(Clone, Debug)]
struct FlightEvent {
    /// Monotone sequence number (orders same-millisecond events).
    seq: u64,
    /// Unix milliseconds at record time.
    ts_ms: u64,
    kind: &'static str,
    detail: String,
}

struct FlightRing {
    cap: usize,
    next_seq: u64,
    events: VecDeque<FlightEvent>,
}

impl FlightRing {
    fn new(cap: usize) -> FlightRing {
        FlightRing { cap, next_seq: 0, events: VecDeque::new() }
    }

    fn set_capacity(&mut self, cap: usize) {
        self.cap = cap;
        while self.events.len() > self.cap {
            self.events.pop_front();
        }
    }

    fn push(&mut self, kind: &'static str, detail: String) {
        if self.cap == 0 {
            return;
        }
        let ts_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        self.events.push_back(FlightEvent { seq: self.next_seq, ts_ms, kind, detail });
        self.next_seq += 1;
        while self.events.len() > self.cap {
            self.events.pop_front();
        }
    }
}

/// Append one structured event to the flight-recorder ring. Off the
/// token loop only (admissions, retirements, maintenance completions,
/// failpoint hits, quarantines, respawns) — it takes a mutex and
/// allocates the detail string.
pub fn flightrec(kind: &'static str, detail: impl Into<String>) {
    let st = state();
    let mut ring = st.flightrec.lock().unwrap_or_else(PoisonError::into_inner);
    ring.push(kind, detail.into());
}

/// Events currently held in the ring.
pub fn flightrec_len() -> usize {
    state().flightrec.lock().unwrap_or_else(PoisonError::into_inner).events.len()
}

/// Dump the ring to `dir/flightrec-<unix_ms>.jsonl` (one JSON object per
/// line: `{"seq", "ts_ms", "kind", "detail"}`, oldest first). Best
/// effort and non-panicking — the caller is the crash path; returns the
/// written path, or `None` when the ring is empty or IO failed.
pub fn flightrec_dump(dir: &Path) -> Option<PathBuf> {
    let events: Vec<FlightEvent> = {
        let ring = state().flightrec.lock().unwrap_or_else(PoisonError::into_inner);
        ring.events.iter().cloned().collect()
    };
    if events.is_empty() {
        return None;
    }
    if std::fs::create_dir_all(dir).is_err() {
        return None;
    }
    let ts = events.last().map(|e| e.ts_ms).unwrap_or(0);
    let path = dir.join(format!("flightrec-{ts}.jsonl"));
    let f = std::fs::File::create(&path).ok()?;
    let mut w = std::io::BufWriter::new(f);
    for e in &events {
        let mut o = Value::obj();
        o.set("seq", e.seq).set("ts_ms", e.ts_ms).set("kind", e.kind).set(
            "detail",
            e.detail.as_str(),
        );
        if writeln!(w, "{}", o.to_string()).is_err() {
            return None;
        }
    }
    w.flush().ok()?;
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_register_once() {
        let r = registry();
        let a = r.counter("test.telemetry.counter");
        let b = r.counter("test.telemetry.counter");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "same name must share one cell");
        let g = r.gauge("test.telemetry.gauge");
        g.set(1.5);
        assert!((r.gauge("test.telemetry.gauge").get() - 1.5).abs() < 1e-12);
        r.set_label("test.telemetry.label", "value");
        let snap = r.snapshot();
        assert_eq!(
            snap.get("counters").and_then(|c| c.get("test.telemetry.counter")).and_then(Value::as_u64),
            Some(3)
        );
        assert_eq!(
            snap.get("labels").and_then(|l| l.get("test.telemetry.label")).and_then(Value::as_str),
            Some("value")
        );
    }

    #[test]
    fn histogram_is_bounded_and_quantiles_are_monotone() {
        let h = Histogram::new();
        // A million observations cost no memory growth by construction:
        // the type is a fixed array of buckets.
        for i in 1..=1000u64 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.sum() - 500.5).abs() < 1e-6);
        let (p50, p90, p99) = (h.quantile(0.5), h.quantile(0.9), h.quantile(0.99));
        assert!(p50 <= p90 && p90 <= p99, "quantiles monotone: {p50} {p90} {p99}");
        // Log-bucket resolution: within one octave of the true value.
        assert!(p50 > 0.25 && p50 < 1.0, "p50 of ~0.5 within an octave: {p50}");
        assert!(h.max() >= 1.0 - 1e-9);
        // Degenerate inputs land in bucket 0 instead of poisoning stats.
        h.record(f64::NAN);
        h.record(-3.0);
        assert_eq!(h.count(), 1002);
    }

    #[test]
    fn bucket_index_covers_extremes() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(f64::INFINITY), 0);
        assert_eq!(bucket_index(1e-300), 0);
        assert_eq!(bucket_index(1e300), HIST_BUCKETS - 1);
        // 1.0 has exponent 0 → bucket HIST_EXP_OFFSET.
        assert_eq!(bucket_index(1.0), HIST_EXP_OFFSET as usize);
        assert!(bucket_value(bucket_index(1.0)) >= 1.0);
    }

    #[test]
    fn span_acc_tree_shape() {
        let mut acc = SpanAcc::default();
        assert!(acc.is_empty());
        acc.record(Phase::Prefill, 0.5);
        acc.record(Phase::Retrieval, 0.1);
        acc.record(Phase::Retrieval, 0.1);
        acc.record(Phase::HostAttn, 0.2);
        let mut other = SpanAcc::default();
        other.record(Phase::Snapshot, 0.3);
        acc.merge(&other);
        assert_eq!(acc.cell(Phase::Retrieval).count, 2);
        assert!((acc.total_s() - 1.2).abs() < 1e-12);
        let j = acc.to_json();
        assert!(j.get("prefill").is_some());
        assert!(j.get("snapshot").is_some());
        let decode = j.get("decode").expect("decode subtree");
        assert!(decode.get("retrieval").is_some());
        assert!((decode.get("total_s").and_then(Value::as_f64).unwrap() - 0.4).abs() < 1e-12);
        // Empty slots are omitted entirely.
        assert!(j.get("restore").is_none());
        assert!(decode.get("ffn").is_none());
    }

    #[test]
    fn flight_ring_is_bounded_and_dumps_jsonl() {
        let st = state();
        {
            let mut ring = st.flightrec.lock().unwrap_or_else(PoisonError::into_inner);
            ring.set_capacity(4);
            ring.events.clear();
        }
        for i in 0..10 {
            flightrec("test.event", format!("event {i}"));
        }
        assert_eq!(flightrec_len(), 4, "ring bounded at capacity");
        let dir = std::env::temp_dir().join(format!("ra-flightrec-test-{}", std::process::id()));
        let path = flightrec_dump(&dir).expect("dump succeeds");
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        for line in &lines {
            let v = crate::util::json::parse(line).expect("each line parses");
            assert_eq!(v.req_str("kind").unwrap(), "test.event");
        }
        // The tail is the most recent event.
        let last = crate::util::json::parse(lines[3]).unwrap();
        assert!(last.req_str("detail").unwrap().contains("event 9"));
        std::fs::remove_dir_all(&dir).ok();
        // Restore the default capacity for other tests in this binary.
        let mut ring = st.flightrec.lock().unwrap_or_else(PoisonError::into_inner);
        ring.set_capacity(FLIGHTREC_DEFAULT_CAPACITY);
        ring.events.clear();
    }

    #[test]
    fn stopwatch_accumulates_into_slot() {
        let mut slot = 0.0;
        let t = Stopwatch::start();
        let s = t.stop_into(&mut slot);
        assert!(s >= 0.0 && (slot - s).abs() < 1e-15);
        let s2 = t.stop_into(&mut slot);
        assert!(slot >= s + s2 - 1e-12);
    }
}
