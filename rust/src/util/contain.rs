//! Panic containment for per-session work inside a shared worker.
//!
//! A replica worker multiplexes many sessions; a panic in one session's
//! decode step must not strand the others (the slot protocol promises a
//! terminal event for every admitted job). [`contained`] converts such a
//! panic into the same `Err` the fallible path already produces, so the
//! existing per-slot error machinery (poison the session, emit
//! `Event::Failed`, keep decoding survivors) handles both shapes.
//!
//! On `AssertUnwindSafe`: the closures this wraps operate on state that is
//! either (a) poisoned and dropped on failure — the session and its
//! activation are never retained once the slot errors — or (b) rebuilt
//! from scratch by the supervisor (the respawned worker starts from an
//! empty registry plus the durable spill tier). Nothing broken-invariant
//! survives the unwind, which is exactly the condition `AssertUnwindSafe`
//! asserts. Fused cross-session phases are NOT wrapped: a panic inside
//! `parallel::par_map` propagates through `thread::scope` and is handled
//! one level up (the worker loop fails the whole wave and respawn-or-
//! continues), because mid-kernel shared buffers cannot be attributed to
//! one session.

use anyhow::{anyhow, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Run `f`, converting a panic into `Err` tagged with `what`. The panic
/// payload's message is preserved when it is a string (the common case:
/// `panic!`, `assert!`, index-out-of-bounds all produce strings).
pub fn contained<T>(what: &str, f: impl FnOnce() -> Result<T>) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Err(anyhow!("panic in {what}: {msg}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ok_and_err_pass_through() {
        assert_eq!(contained("t", || Ok(7u32)).unwrap(), 7);
        let e = contained::<u32>("t", || Err(anyhow!("boom"))).unwrap_err();
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn panic_becomes_error_with_message() {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // keep test output clean
        let e = contained::<u32>("slot 3", || panic!("kaboom {}", 42)).unwrap_err();
        let v = contained::<u32>("vec", || {
            let v: Vec<u32> = vec![];
            Ok(v[9])
        })
        .unwrap_err();
        std::panic::set_hook(prev);
        assert!(e.to_string().contains("slot 3"), "{e}");
        assert!(e.to_string().contains("kaboom 42"), "{e}");
        assert!(v.to_string().contains("panic in vec"), "{v}");
    }
}
