//! Scoped fork-join parallelism over `std::thread`.
//!
//! Used for the paper's Appendix C "Multi-head Parallelism on the CPU
//! side": per-head index searches are independent, so they fan out across
//! physical cores. `std::thread::scope` gives us borrowed inputs without
//! `'static` bounds; chunking keeps spawn overhead negligible for the
//! work sizes involved (each head search is ~10⁵–10⁶ dot products).

use crate::util::sync::{AtomicUsize, Ordering};

/// Number of worker threads to use (physical parallelism).
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel map preserving input order. Spawns at most `num_threads()`
/// workers; items are claimed dynamically (work stealing by atomic
/// counter), so uneven item costs still balance.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = num_threads().min(n);
    if workers <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    // Relaxed (allowlisted counter): fetch_add only hands out unique
    // indices; the claimed item's data is synchronized by scope join.
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());

    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let out_ptr = &out_ptr;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index i is claimed exactly once (atomic
                // counter) and out lives for the whole scope.
                unsafe { *out_ptr.0.add(i) = Some(r) };
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker filled every slot")).collect()
}

/// Parallel map over an index range.
pub fn par_map_range<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, |&i| f(i))
}

/// Run a closure for each item in parallel (no results collected).
pub fn par_for_each<T, F>(items: &[T], f: F)
where
    T: Sync,
    F: Fn(&T) + Sync,
{
    let _ = par_map(items, |t| f(t));
}

/// Parallel in-place zip: `f(i, &mut items[i], &ctx[i])` for each index up
/// to the shorter length. Lets hot loops fill caller-owned scratch buffers
/// concurrently (the decode path's per-head id assembly) instead of
/// choosing between reuse and parallelism.
pub fn par_zip_mut<T, U, F>(items: &mut [T], ctx: &[U], f: F)
where
    T: Send,
    U: Sync,
    F: Fn(usize, &mut T, &U) + Sync,
{
    let n = items.len().min(ctx.len());
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n);
    if workers <= 1 {
        for (i, (t, u)) in items.iter_mut().zip(ctx).enumerate() {
            f(i, t, u);
        }
        return;
    }
    // Relaxed (allowlisted counter): unique-index claim, as in par_map.
    let next = AtomicUsize::new(0);
    let items_ptr = SendPtr(items.as_mut_ptr());
    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let items_ptr = &items_ptr;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // SAFETY: each index i is claimed exactly once (atomic
                // counter) and items outlives the scope.
                let t = unsafe { &mut *items_ptr.0.add(i) };
                f(i, t, &ctx[i]);
            });
        }
    });
}

struct SendPtr<T>(*mut T);
// SAFETY: the pointer is only dereferenced at disjoint indices.
unsafe impl<T> Sync for SendPtr<T> {}
// SAFETY: as above — each slot is written by exactly one worker, and the
// owning scope outlives every worker.
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = par_map(&[] as &[usize], |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // Items with wildly different costs must all complete.
        let items: Vec<usize> = (0..64).collect();
        let out = par_map(&items, |&x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i as u64);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn range_variant() {
        let out = par_map_range(10, |i| i * i);
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49, 64, 81]);
    }

    #[test]
    fn zip_mut_fills_every_slot_in_order() {
        let mut bufs: Vec<Vec<u32>> = vec![Vec::new(); 37];
        let ctx: Vec<u32> = (0..37).collect();
        par_zip_mut(&mut bufs, &ctx, |i, buf, &c| {
            buf.clear();
            buf.push(i as u32);
            buf.push(c * 2);
        });
        for (i, buf) in bufs.iter().enumerate() {
            assert_eq!(buf, &vec![i as u32, i as u32 * 2], "slot {i}");
        }
        // Shorter ctx bounds the zip; empty inputs are a no-op.
        let mut two: Vec<u32> = vec![0, 0];
        par_zip_mut(&mut two, &[7u32], |_, t, &c| *t = c);
        assert_eq!(two, vec![7, 0]);
        par_zip_mut(&mut [] as &mut [u32], &ctx, |_, _, _| unreachable!());
    }

    #[test]
    fn borrows_environment() {
        let data = vec![1.0f32; 128];
        let out = par_map_range(8, |i| data[i * 16]);
        assert_eq!(out, vec![1.0; 8]);
    }
}
