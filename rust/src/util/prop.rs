//! Tiny property-testing driver: run a predicate over many seeded random
//! cases; on failure, report the seed so the case replays deterministically.

use super::rng::Rng;

/// Run `cases` random trials of `prop`. Each trial gets its own forked RNG.
/// Panics with the failing seed on the first violated property.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    check_seeded(name, 0xC0FFEE, cases, &mut prop);
}

/// Like [`check`] with an explicit base seed (to replay a failure).
pub fn check_seeded(
    name: &str,
    base_seed: u64,
    cases: usize,
    prop: &mut impl FnMut(&mut Rng) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("uniform in range", 50, |rng| {
            let v = rng.f32();
            prop_assert!((0.0..1.0).contains(&v), "out of range: {v}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_reports_seed() {
        check("always fails", 5, |_| Err("nope".into()));
    }
}
