//! Deterministic fault injection: named failure sites on the serving
//! stack's IO and publish paths.
//!
//! Production code instruments a site with [`trigger`]:
//!
//! ```ignore
//! crate::util::failpoint::trigger("spill.write")?;
//! ```
//!
//! Without the `failpoints` cargo feature the call compiles to an inlined
//! `Ok(())` — zero branches, zero data, zero cost on the token path. With
//! the feature (`make test-faults`), a test arms a site with a
//! [`FailAction`] and every trigger consults the registry:
//!
//! * [`FailAction::Error`] — after skipping `after` hits, the next
//!   `times` triggers return an `Err` tagged with the site name (the
//!   shape of a transient IO failure or a refused publish).
//! * [`FailAction::Panic`] — after skipping `after` hits, the next
//!   trigger panics (the shape of a logic bug inside a wave step). The
//!   panic message carries the site name so containment layers can
//!   attribute it.
//!
//! Determinism is by construction: actions key off a per-site **hit
//! counter**, not wall clock or RNG, so a test that arms
//! `Error { after: 2, times: 1 }` fails exactly the third trigger, every
//! run, regardless of thread scheduling (the registry is a mutex; hit
//! order across sessions in one wave is fixed by the serial per-slot
//! loop). Sites may also be armed from the environment before the first
//! trigger: `RA_FAILPOINTS="spill.write=error:0:1,wave.decode=panic:2"`
//! (comma-separated `site=error:after:times` / `site=panic:after`).
//!
//! Every instrumented site is listed in [`SITES`]; the fault-injection
//! matrix (`tests/fault_injection.rs`) iterates that registry so a new
//! site cannot be added without a degradation story. See
//! docs/robustness.md for the per-site semantics.

/// Every instrumented site, in dependency order. Keep this in sync with
/// the `trigger` call sites and the table in docs/robustness.md.
pub const SITES: &[&str] = &[
    // Spill tier (store/spill.rs): temp-file write, fsync+rename commit,
    // and restore-side open/read.
    "spill.write",
    "spill.commit",
    "spill.read",
    // Snapshot codec boundaries (model/engine.rs): serialization into a
    // parked snapshot and parse back out of one.
    "codec.snapshot",
    "codec.restore",
    // Maintenance publish points (model/maintain.rs): a failure here must
    // surface as a clean `Done { ok: false }` retry, never a torn index.
    "maint.drain.publish",
    "maint.compact.publish",
    // Per-session portion of the fused wave step (model/engine.rs).
    "wave.decode",
    // Cache-level restore of a parked session (store/cache.rs).
    "session.restore",
    // Top of the replica worker loop (coordinator/mod.rs). Panic-only:
    // arming `Panic` here kills the worker thread between waves, which is
    // how tests drive the supervised-respawn + durable-recovery path.
    // `Error` actions are ignored at this site (no job to fail).
    "worker.step",
];

/// What an armed site does when triggered (feature `failpoints` only;
/// the type exists unconditionally so test helpers can name it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailAction {
    /// Skip `after` hits, then fail the next `times` triggers with an
    /// `Err`. `times = u64::MAX` fails forever (a hard-down disk).
    Error { after: u64, times: u64 },
    /// Skip `after` hits, then panic on the next trigger.
    Panic { after: u64 },
}

#[cfg(feature = "failpoints")]
mod armed {
    use super::FailAction;
    use crate::util::sync::{Mutex, OnceLock, PoisonError};
    use anyhow::{bail, Result};
    use std::collections::HashMap;

    struct Site {
        action: Option<FailAction>,
        hits: u64,
    }

    fn registry() -> &'static Mutex<HashMap<&'static str, Site>> {
        static REG: OnceLock<Mutex<HashMap<&'static str, Site>>> = OnceLock::new();
        REG.get_or_init(|| {
            let mut m = HashMap::new();
            for &s in super::SITES {
                m.insert(s, Site { action: None, hits: 0 });
            }
            if let Ok(spec) = std::env::var("RA_FAILPOINTS") {
                for part in spec.split(',').filter(|p| !p.is_empty()) {
                    if let Some((site, action)) = parse_env(part) {
                        if let Some(e) = m.get_mut(site) {
                            e.action = Some(action);
                        }
                    }
                }
            }
            Mutex::new(m)
        })
    }

    /// `site=error:after:times` or `site=panic:after` (counts optional;
    /// `error` alone means fail the first trigger once). Returns a
    /// 'static site name only for registered sites.
    fn parse_env(part: &str) -> Option<(&'static str, FailAction)> {
        let (name, spec) = part.split_once('=')?;
        let site = super::SITES.iter().copied().find(|s| *s == name.trim())?;
        let mut f = spec.trim().split(':');
        let kind = f.next()?;
        let after = f.next().and_then(|x| x.parse().ok()).unwrap_or(0);
        let action = match kind {
            "error" => FailAction::Error {
                after,
                times: f.next().and_then(|x| x.parse().ok()).unwrap_or(1),
            },
            "panic" => FailAction::Panic { after },
            _ => return None,
        };
        Some((site, action))
    }

    /// Arm a site. Panics on an unregistered name: a typo in a test must
    /// fail the test, not silently inject nothing.
    pub fn arm(site: &str, action: FailAction) {
        let mut reg = registry().lock().unwrap_or_else(PoisonError::into_inner);
        let e = reg.get_mut(site).unwrap_or_else(|| panic!("unregistered failpoint `{site}`"));
        e.action = Some(action);
        e.hits = 0;
    }

    /// Disarm one site (its hit counter keeps counting).
    pub fn disarm(site: &str) {
        let mut reg = registry().lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(e) = reg.get_mut(site) {
            e.action = None;
        }
    }

    /// Disarm every site and zero all hit counters. Tests run this first:
    /// the registry is process-global and the matrix is serialized
    /// (`--test-threads=1`), so each case starts from a clean slate.
    pub fn reset() {
        let mut reg = registry().lock().unwrap_or_else(PoisonError::into_inner);
        for e in reg.values_mut() {
            e.action = None;
            e.hits = 0;
        }
    }

    /// Times a site has been triggered since the last `reset`/`arm`.
    pub fn hits(site: &str) -> u64 {
        let reg = registry().lock().unwrap_or_else(PoisonError::into_inner);
        reg.get(site).map(|e| e.hits).unwrap_or(0)
    }

    pub fn trigger(site: &str) -> Result<()> {
        let mut reg = registry().lock().unwrap_or_else(PoisonError::into_inner);
        let Some(e) = reg.get_mut(site) else {
            return Ok(());
        };
        let hit = e.hits;
        e.hits += 1;
        match e.action {
            Some(FailAction::Error { after, times }) if hit >= after => {
                if hit - after < times {
                    drop(reg);
                    // Flight-recorder breadcrumb BEFORE the injected
                    // failure: a crash dump's tail names the fault that
                    // caused it.
                    crate::telemetry::flightrec(
                        "failpoint",
                        format!("injected fault at `{site}` (hit {hit})"),
                    );
                    bail!("injected fault at failpoint `{site}` (hit {hit})");
                }
                Ok(())
            }
            Some(FailAction::Panic { after }) if hit >= after => {
                e.action = None; // one-shot: a respawned path must not re-trip
                drop(reg);
                crate::telemetry::flightrec(
                    "failpoint",
                    format!("injected panic at `{site}` (hit {hit})"),
                );
                panic!("injected panic at failpoint `{site}` (hit {hit})");
            }
            _ => Ok(()),
        }
    }
}

#[cfg(feature = "failpoints")]
pub use armed::{arm, disarm, hits, reset, trigger};

/// Release/tier-1 build: every site compiles to an inlined `Ok(())`.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn trigger(_site: &str) -> anyhow::Result<()> {
    Ok(())
}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    // Unit tests share the process-global registry with anything else in
    // the lib test binary, so keep them in one test body.
    #[test]
    fn counting_actions_are_deterministic() {
        reset();
        assert!(trigger("spill.write").is_ok(), "unarmed sites pass");
        arm("spill.write", FailAction::Error { after: 1, times: 2 });
        assert!(trigger("spill.write").is_ok(), "hit 0 skipped");
        assert!(trigger("spill.write").is_err(), "hit 1 fails");
        let err = trigger("spill.write").expect_err("hit 2 fails");
        assert!(err.to_string().contains("spill.write"), "error names the site");
        assert!(trigger("spill.write").is_ok(), "budget exhausted");
        assert_eq!(hits("spill.write"), 4);
        disarm("spill.write");
        assert!(trigger("spill.write").is_ok());

        arm("wave.decode", FailAction::Panic { after: 0 });
        let p = std::panic::catch_unwind(|| trigger("wave.decode"));
        assert!(p.is_err(), "armed panic fires");
        assert!(trigger("wave.decode").is_ok(), "panic is one-shot");
        reset();
        assert_eq!(hits("wave.decode"), 0);
    }
}
