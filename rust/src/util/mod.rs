//! In-crate replacements for the usual ecosystem crates.
//!
//! The build is fully offline against a vendored crate set that contains
//! only `xla` and `anyhow`, so the substrate utilities every serving stack
//! leans on are implemented here from scratch:
//!
//! * [`json`] — JSON value model, parser and serializer (config files,
//!   experiment output, the TCP wire protocol).
//! * [`rng`] — deterministic PRNG (SplitMix64 core) with uniform/normal
//!   sampling for synthetic weights and workloads.
//! * [`parallel`] — scoped fork-join parallel map over `std::thread`
//!   (the multi-head CPU parallelism of Appendix C).
//! * [`bench`] — a minimal criterion-style measurement harness used by the
//!   `benches/` targets.
//! * [`prop`] — a small property-testing driver (randomised input sweeps
//!   with seed reporting on failure).
//! * [`swap`] — generation-counted `Arc` publication for the
//!   double-buffered index swap of the online-maintenance worker.
//! * [`sync`] — the loom-checkable synchronization facade every
//!   concurrency-bearing module must import instead of `std::sync`
//!   (enforced by `cargo xtask lint`; see docs/concurrency.md).
//! * [`failpoint`] — deterministic fault injection sites (zero-cost
//!   unless the `failpoints` feature is on; see docs/robustness.md).
//! * [`contain`] — panic→`Err` containment for per-session work inside
//!   a shared replica worker.

pub mod bench;
pub mod contain;
pub mod failpoint;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod swap;
pub mod sync;
