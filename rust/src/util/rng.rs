//! Deterministic PRNG for synthetic weights and workloads.
//!
//! SplitMix64 core (Steele et al.): tiny state, excellent statistical
//! quality for simulation purposes, and fully deterministic across
//! platforms — experiment outputs are reproducible from the seed recorded
//! in EXPERIMENTS.md.

/// SplitMix64 generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// The raw generator state (session persistence: HNSW's level-draw
    /// stream must survive a snapshot so post-restore inserts stay
    /// deterministic with the never-snapshotted session).
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Resume a generator at a previously captured [`Rng::state`].
    pub fn from_state(state: u64) -> Self {
        Rng { state }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n), order randomised.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            // Rejection sampling for sparse draws.
            let mut seen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Derive an independent stream (for per-thread / per-head RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seed_from(1);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::seed_from(2);
        let mean: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(4);
        let s = r.sample_indices(100, 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        let all = r.sample_indices(10, 10);
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::seed_from(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
