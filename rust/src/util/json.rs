//! Minimal JSON: a value model, a recursive-descent parser and a
//! serializer. Covers the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); numbers are represented as `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        match self {
            Value::Obj(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Typed getters with path context in the error.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Value {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Value {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Value {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<f32> for Value {
    fn from(n: f32) -> Value {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Value {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse a JSON document. Returns an error with byte offset on failure.
pub fn parse(input: &str) -> anyhow::Result<Value> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!("expected `{}` at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => anyhow::bail!("unexpected character at byte {}", self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> anyhow::Result<Value> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])?;
        let num = text.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number `{text}`: {e}"))?;
        Ok(Value::Num(num))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => anyhow::bail!("expected `,` or `]` at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => anyhow::bail!("expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3], "b": {"c": "hi\nthere", "d": true}, "e": null}"#;
        let v = parse(text).unwrap();
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("hi\nthere"));
    }

    #[test]
    fn integers_serialize_without_dot() {
        let v = Value::Num(42.0);
        assert_eq!(v.to_string(), "42");
    }

    #[test]
    fn escapes_roundtrip() {
        let mut o = Value::obj();
        o.set("s", "quote\" slash\\ nl\n tab\t");
        let back = parse(&o.to_string()).unwrap();
        assert_eq!(back.get("s").unwrap().as_str(), Some("quote\" slash\\ nl\n tab\t"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{}extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::obj());
    }

    #[test]
    fn pretty_parses_back() {
        let mut o = Value::obj();
        o.set("nested", {
            let mut n = Value::obj();
            n.set("list", vec![1usize, 2, 3]);
            n
        });
        let pretty = o.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), o);
    }
}
