//! Double-buffered publication: a generation-stamped `Arc` slot.
//!
//! The online-maintenance worker mutates a private *back* buffer and
//! publishes it here with one pointer swap; decode-time readers grab the
//! current *front* with a single short read-lock acquisition (held only
//! for the `Arc` clone — never across a search), so a reader can never
//! observe a half-updated structure: it either sees the complete old
//! front or the complete new one. The generation counter is bumped under
//! the writer lock, so `load_with_generation` returns a mutually
//! consistent (generation, snapshot) pair — the invariant the
//! `maintenance_concurrency` suite asserts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// A swappable, generation-counted shared value.
pub struct Published<T: ?Sized> {
    slot: RwLock<Arc<T>>,
    generation: AtomicU64,
}

impl<T> Published<T> {
    pub fn new(value: T) -> Self {
        Published { slot: RwLock::new(Arc::new(value)), generation: AtomicU64::new(0) }
    }
}

impl<T: ?Sized> Published<T> {
    pub fn from_arc(value: Arc<T>) -> Self {
        Published { slot: RwLock::new(value), generation: AtomicU64::new(0) }
    }

    /// Snapshot the current front (one Arc clone under a read lock).
    pub fn load(&self) -> Arc<T> {
        self.slot.read().expect("Published slot poisoned").clone()
    }

    /// Snapshot with its generation; the pair is consistent because the
    /// writer bumps the counter while holding the write lock.
    pub fn load_with_generation(&self) -> (u64, Arc<T>) {
        let slot = self.slot.read().expect("Published slot poisoned");
        (self.generation.load(Ordering::Acquire), slot.clone())
    }

    /// Swaps generated so far.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Swap in a new front; returns the displaced one (the caller keeps it
    /// as the next back buffer — left/right double buffering).
    pub fn publish(&self, value: Arc<T>) -> Arc<T> {
        let mut slot = self.slot.write().expect("Published slot poisoned");
        let old = std::mem::replace(&mut *slot, value);
        self.generation.fetch_add(1, Ordering::AcqRel);
        old
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_returns_the_old_front() {
        let p = Published::new(1u32);
        assert_eq!(*p.load(), 1);
        assert_eq!(p.generation(), 0);
        let old = p.publish(Arc::new(2));
        assert_eq!(*old, 1);
        assert_eq!(*p.load(), 2);
        assert_eq!(p.generation(), 1);
    }

    #[test]
    fn generation_pairs_with_snapshot() {
        let p = Published::new(vec![0u64; 8]);
        for g in 1..=5u64 {
            p.publish(Arc::new(vec![g; 8]));
            let (gen, snap) = p.load_with_generation();
            assert_eq!(gen, g);
            assert!(snap.iter().all(|&v| v == g), "torn snapshot at gen {g}");
        }
    }

    #[test]
    fn concurrent_readers_never_see_torn_state() {
        // Writer publishes vectors whose every element equals the
        // generation; readers must never observe a mixed vector.
        let p = Arc::new(Published::new(vec![0u64; 64]));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let p = p.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut last_gen = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let (gen, snap) = p.load_with_generation();
                    assert!(gen >= last_gen, "generation went backwards");
                    last_gen = gen;
                    let first = snap[0];
                    assert!(snap.iter().all(|&v| v == first), "torn read at gen {gen}");
                }
            }));
        }
        for g in 1..=500u64 {
            p.publish(Arc::new(vec![g; 64]));
        }
        stop.store(true, Ordering::Release);
        for r in readers {
            r.join().expect("reader panicked");
        }
        assert_eq!(p.generation(), 500);
    }
}
