//! Double-buffered publication: a generation-stamped `Arc` slot.
//!
//! The online-maintenance worker mutates a private *back* buffer and
//! publishes it here with one pointer swap; decode-time readers grab the
//! current *front* with a single short read-lock acquisition (held only
//! for the `Arc` clone — never across a search), so a reader can never
//! observe a half-updated structure: it either sees the complete old
//! front or the complete new one. The generation counter is bumped under
//! the writer lock, so `load_with_generation` returns a mutually
//! consistent (generation, snapshot) pair — the invariant the
//! `maintenance_concurrency` suite stresses and `tests/loom_models.rs`
//! model-checks exhaustively.
//!
//! Poisoning: a panicking publisher must not cascade into every decode
//! reader, so all lock acquisitions recover from poison instead of
//! unwrapping. That is sound here because the slot's only invariant is
//! "holds a complete `Arc`", and the `Arc` swap itself cannot panic
//! halfway — `mem::replace` is a plain pointer move — so a poisoned slot
//! still holds a complete front.

use crate::util::sync::{Arc, AtomicU64, Ordering, PoisonError, RwLock};

/// A swappable, generation-counted shared value.
pub struct Published<T: ?Sized> {
    slot: RwLock<Arc<T>>,
    generation: AtomicU64,
}

impl<T> Published<T> {
    pub fn new(value: T) -> Self {
        Published { slot: RwLock::new(Arc::new(value)), generation: AtomicU64::new(0) }
    }
}

impl<T: ?Sized> Published<T> {
    pub fn from_arc(value: Arc<T>) -> Self {
        Published { slot: RwLock::new(value), generation: AtomicU64::new(0) }
    }

    /// Snapshot the current front (one Arc clone under a read lock).
    pub fn load(&self) -> Arc<T> {
        self.slot.read().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Snapshot with its generation; the pair is consistent because the
    /// writer bumps the counter while holding the write lock.
    pub fn load_with_generation(&self) -> (u64, Arc<T>) {
        let slot = self.slot.read().unwrap_or_else(PoisonError::into_inner);
        (self.generation.load(Ordering::Acquire), slot.clone())
    }

    /// Swaps generated so far.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Swap in a new front; returns the displaced one (the caller keeps it
    /// as the next back buffer — left/right double buffering).
    pub fn publish(&self, value: Arc<T>) -> Arc<T> {
        let mut slot = self.slot.write().unwrap_or_else(PoisonError::into_inner);
        let old = std::mem::replace(&mut *slot, value);
        // AcqRel pairs with the Acquire loads above: a reader that sees
        // generation g also sees the slot contents published with it
        // (the write lock already orders the pair; the ordering keeps
        // `generation()` meaningful for lock-free gen polling too).
        self.generation.fetch_add(1, Ordering::AcqRel);
        old
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::AtomicBool;

    #[test]
    fn publish_returns_the_old_front() {
        let p = Published::new(1u32);
        assert_eq!(*p.load(), 1);
        assert_eq!(p.generation(), 0);
        let old = p.publish(Arc::new(2));
        assert_eq!(*old, 1);
        assert_eq!(*p.load(), 2);
        assert_eq!(p.generation(), 1);
    }

    #[test]
    fn generation_pairs_with_snapshot() {
        let p = Published::new(vec![0u64; 8]);
        for g in 1..=5u64 {
            p.publish(Arc::new(vec![g; 8]));
            let (gen, snap) = p.load_with_generation();
            assert_eq!(gen, g);
            assert!(snap.iter().all(|&v| v == g), "torn snapshot at gen {g}");
        }
    }

    #[test]
    fn concurrent_readers_never_see_torn_state() {
        // Writer publishes vectors whose every element equals the
        // generation; readers must never observe a mixed vector.
        let p = Arc::new(Published::new(vec![0u64; 64]));
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let p = p.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut last_gen = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let (gen, snap) = p.load_with_generation();
                    assert!(gen >= last_gen, "generation went backwards");
                    last_gen = gen;
                    let first = snap[0];
                    assert!(snap.iter().all(|&v| v == first), "torn read at gen {gen}");
                }
            }));
        }
        for g in 1..=500u64 {
            p.publish(Arc::new(vec![g; 64]));
        }
        stop.store(true, Ordering::Release);
        for r in readers {
            r.join().expect("reader panicked");
        }
        assert_eq!(p.generation(), 500);
    }

    #[test]
    fn readers_survive_a_panicking_publisher() {
        // A writer that panics while holding the slot poisons the lock;
        // readers and later publishers must recover, not cascade-panic.
        let p = Arc::new(Published::new(7u32));
        let p2 = p.clone();
        let poisoner = std::thread::spawn(move || {
            let _guard = p2.slot.write().unwrap_or_else(PoisonError::into_inner);
            panic!("publisher died mid-publish");
        });
        assert!(poisoner.join().is_err(), "poisoner must have panicked");
        // The slot still holds the last complete front.
        assert_eq!(*p.load(), 7);
        let (gen, snap) = p.load_with_generation();
        assert_eq!((gen, *snap), (0, 7));
        // Publishing through the poisoned lock keeps working.
        let old = p.publish(Arc::new(8));
        assert_eq!(*old, 7);
        assert_eq!(*p.load(), 8);
        assert_eq!(p.generation(), 1);
    }
}
