//! The loom-checkable synchronization facade.
//!
//! Every concurrency-bearing module imports its primitives from here
//! instead of `std::sync` (enforced by `cargo xtask lint`: direct
//! `std::sync::atomic` / `std::sync::RwLock` imports outside this file
//! fail the build). Under a normal build the re-exports are exactly the
//! `std` types — zero cost. Under `RUSTFLAGS="--cfg loom"` (`make loom`)
//! they swap for the vendored model checker's instrumented twins, so
//! `tests/loom_models.rs` can exhaustively explore the interleavings of
//! `Published`, the GroupShared id-map publish protocol, and the worker
//! accounting without any change to the code under test.
//!
//! What is modeled and what is not:
//!
//! * `Arc`, `Mutex`, `RwLock`, `AtomicBool`/`AtomicU32`/`AtomicU64`/
//!   `AtomicUsize` — swapped for loom twins (`Arc` stays `std`; the
//!   checker explores interleavings, not leaks).
//! * [`yield_now`] — `std::thread::yield_now` normally; under loom a
//!   voluntary scheduling point. Spin-retry loops MUST use this (not
//!   `std::thread::yield_now`) or the model checker cannot hand the
//!   token to the writer the loop is waiting on.
//! * `mpsc`, `OnceLock`, `PoisonError` — always `std`: channels and
//!   one-shot init are not modeled (loom tests avoid them), and poison
//!   recovery is pure API surface.
//!
//! The `Ordering` policy that goes with the facade (when `Relaxed` is
//! acceptable, which pairs must be Acquire/Release) is documented in
//! docs/concurrency.md and enforced by the linter's Relaxed allowlist.

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex, MutexGuard, RwLock};

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Arc, Mutex, MutexGuard, RwLock};

// Channels, one-shot init and poison plumbing are never modeled.
pub use std::sync::{mpsc, OnceLock, PoisonError};

/// Voluntary yield for spin-retry loops (see module docs).
#[cfg(not(loom))]
pub fn yield_now() {
    std::thread::yield_now();
}

/// Voluntary yield for spin-retry loops (see module docs).
#[cfg(loom)]
pub fn yield_now() {
    loom::thread::yield_now();
}
