//! Minimal measurement harness for the `benches/` targets (criterion is
//! not in the vendored crate set). Warmup + timed iterations, mean / p50 /
//! min, and a black-box to defeat constant folding.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

/// Re-exported black box.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<48} iters={:<5} mean={:>12?} p50={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.min
        )
    }
}

/// Benchmark runner: measures `f` with warmup until either `target_time`
/// elapses or `max_iters` iterations have run.
pub struct Bencher {
    pub warmup: usize,
    pub target_time: Duration,
    pub max_iters: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 3,
            target_time: Duration::from_secs(2),
            max_iters: 1000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Quick profile for CI-ish runs.
    pub fn quick() -> Self {
        Bencher {
            warmup: 1,
            target_time: Duration::from_millis(300),
            max_iters: 50,
            results: Vec::new(),
        }
    }

    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchStats {
        for _ in 0..self.warmup {
            bb(f());
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (start.elapsed() < self.target_time || samples.len() < 5)
        {
            let t = Instant::now();
            bb(f());
            samples.push(t.elapsed());
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let stats = BenchStats {
            name: name.to_string(),
            iters: samples.len(),
            mean,
            p50: samples[samples.len() / 2],
            min: samples[0],
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Write results as a JSON array (consumed by EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::Arr(
            self.results
                .iter()
                .map(|s| {
                    let mut o = Value::obj();
                    o.set("name", s.name.as_str());
                    o.set("iters", s.iters);
                    o.set("mean_s", s.mean.as_secs_f64());
                    o.set("p50_s", s.p50.as_secs_f64());
                    o.set("min_s", s.min.as_secs_f64());
                    o
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher {
            warmup: 1,
            target_time: Duration::from_millis(20),
            max_iters: 10,
            results: vec![],
        };
        let s = b.bench("noop-ish", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.iters >= 5);
        assert!(s.min <= s.mean);
    }

    #[test]
    fn json_output_has_all_cases() {
        let mut b = Bencher {
            warmup: 0,
            target_time: Duration::from_millis(5),
            max_iters: 5,
            results: vec![],
        };
        b.bench("a", || 1);
        b.bench("b", || 2);
        let j = b.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 2);
    }
}
