//! # RetrievalAttention
//!
//! A reproduction of *RetrievalAttention: Accelerating Long-Context LLM
//! Inference via Vector Retrieval* (Liu et al., 2024) as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! The crate is organised bottom-up:
//!
//! * [`tensor`] — minimal dense f32 linear algebra used by the host-side
//!   (CPU) attention and index code.
//! * [`kernel`] — the scoring-kernel subsystem: batched, runtime-
//!   dispatched SIMD scoring (AVX2+FMA / NEON / bit-identical scalar
//!   fallback, `RA_KERNEL=scalar` force-toggle) plus the quantized scan
//!   tier (bf16 / symmetric int8 chunk mirrors) every hot scoring loop in
//!   the crate goes through.
//! * [`index`] — the **online** ANNS substrate: exact KNN
//!   ([`index::flat`]), IVF ([`index::ivf`]), HNSW ([`index::hnsw`]), and
//!   the paper's attention-aware projected bipartite graph
//!   ([`index::roargraph`]). Every family supports
//!   [`index::VectorIndex::insert_batch`] (RoarGraph wires decoded keys
//!   attention-aware from recent decode queries, with a degree-bounded
//!   local repair and an amortised rebuild threshold) **and**
//!   [`index::VectorIndex::remove_batch`] (tombstones + flat/IVF
//!   compaction, graph re-link) — the KV cache is a live vector store
//!   with a full insert/delete lifecycle.
//! * [`kvcache`] — paged KV storage with device/host tiering,
//!   static-pattern (sink + window) selection, the indexed/overflow drain
//!   boundary for online maintenance, the retired tier of the eviction
//!   policy, and the segmented dense key store
//!   ([`kvcache::SegmentedStore`]) whose appends never recopy the
//!   immutable prefix.
//! * [`attention`] — full/sparse attention, the exact two-set
//!   gamma-combine of Appendix B, and sparsity/OOD profiling.
//! * [`baselines`] — StreamingLLM, SnapKV, InfLLM, Quest, InfiniGen and a
//!   vLLM-like full-cache comparator.
//! * [`model`] — synthetic GQA transformer presets plus a constructed
//!   induction-head model used for end-to-end task accuracy. The engine
//!   drains overflow buffers into the per-head indexes on a configurable
//!   watermark, keeping per-token decode cost bounded for arbitrarily
//!   long generations.
//! * [`policy`] — the per-head retrieval-vs-streaming policy layer
//!   (DuoAttention): streaming heads keep a constant-length sink+window
//!   set and no index at all, assigned by a free online attention-mass
//!   calibration pass or static config overrides.
//! * [`runtime`] — artifact loading and execution (the "device"): PJRT
//!   when compiled artifacts exist, a native Rust executor of the same
//!   entry points otherwise.
//! * [`store`] — session persistence: versioned binary snapshots of a
//!   session's full host state (KV + group maps + all four index families,
//!   structurally — restore never re-prefills and never rebuilds an
//!   index) and the disk-spilling multi-turn session cache built on them.
//! * [`coordinator`] — request scheduling, batching, sessions, routing,
//!   and the per-replica session registry (open/continue/close).
//! * [`server`] — tokio front-end (in-process + TCP json-lines).
//! * [`workload`] — ∞-Bench/RULER/needle-style synthetic task generators.
//! * [`experiments`] — one driver per paper table/figure.
//! * [`hw`] — hardware profiles and KV-cache memory arithmetic.
//! * [`metrics`] — latency histograms and per-phase breakdowns.
//! * [`telemetry`] — the unified observability layer: the process-wide
//!   metrics registry (lock-free counters/gauges + bounded log-bucketed
//!   histograms), the span facade tracing the decode wave (zero-cost
//!   when disabled, opt-in chrome://tracing output), and the bounded
//!   flight recorder the supervisor dumps on a worker crash.

// Clippy is *enforced* crate-wide (deny, not advisory): the bug-shaped
// bundles are hard errors everywhere — `make clippy` and the CI lint job
// rely on these attributes, not on command-line flags. Style/complexity
// stay warnings (visible, not red) so a rustc upgrade cannot brick the
// build over idiom churn.
#![deny(clippy::correctness, clippy::suspicious, clippy::perf)]
#![warn(clippy::all)]

pub mod attention;
#[macro_use]
pub mod util;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod hw;
pub mod index;
// The kernel subsystem additionally denies the style/complexity bundles:
// it is small, hot, and unsafe-bearing, so it holds the strictest bar
// (the `make clippy-kernel` CI gate relies on this attribute).
#[deny(clippy::all)]
pub mod kernel;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod server;
pub mod store;
pub mod telemetry;
pub mod tensor;
pub mod workload;
