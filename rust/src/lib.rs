//! # RetrievalAttention
//!
//! A reproduction of *RetrievalAttention: Accelerating Long-Context LLM
//! Inference via Vector Retrieval* (Liu et al., 2024) as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! The crate is organised bottom-up:
//!
//! * [`tensor`] — minimal dense f32 linear algebra used by the host-side
//!   (CPU) attention and index code.
//! * [`index`] — the ANNS substrate: exact KNN ([`index::flat`]), IVF
//!   ([`index::ivf`]), HNSW ([`index::hnsw`]), and the paper's
//!   attention-aware projected bipartite graph ([`index::roargraph`]).
//! * [`kvcache`] — paged KV storage with device/host tiering and
//!   static-pattern (sink + window) selection.
//! * [`attention`] — full/sparse attention, the exact two-set
//!   gamma-combine of Appendix B, and sparsity/OOD profiling.
//! * [`baselines`] — StreamingLLM, SnapKV, InfLLM, Quest, InfiniGen and a
//!   vLLM-like full-cache comparator.
//! * [`model`] — synthetic GQA transformer presets plus a constructed
//!   induction-head model used for end-to-end task accuracy.
//! * [`runtime`] — PJRT artifact loading and execution (the "device").
//! * [`coordinator`] — request scheduling, batching, sessions, routing.
//! * [`server`] — tokio front-end (in-process + TCP json-lines).
//! * [`workload`] — ∞-Bench/RULER/needle-style synthetic task generators.
//! * [`experiments`] — one driver per paper table/figure.
//! * [`hw`] — hardware profiles and KV-cache memory arithmetic.
//! * [`metrics`] — latency histograms and per-phase breakdowns.

pub mod attention;
#[macro_use]
pub mod util;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod hw;
pub mod index;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod workload;
