//! Out-of-distribution measurement (§2.4, Fig 3b).
//!
//! The paper quantifies the Q/K distribution gap with the Mahalanobis
//! distance from a vector to the key distribution: queries sit >10× farther
//! from K than keys themselves do. We reproduce the measurement with a
//! shrinkage-regularised covariance (keeps the estimate well-conditioned
//! for head dims up to 128 with a few thousand samples).

use crate::tensor::{col_mean, Matrix};

/// Gaussian summary of a vector population: mean + inverse covariance.
pub struct Distribution {
    mean: Vec<f32>,
    cov_inv: Matrix,
}

impl Distribution {
    /// Fit from samples (rows). `shrink` in [0,1] blends the empirical
    /// covariance toward its diagonal average (Ledoit-Wolf-style).
    pub fn fit(samples: &Matrix, shrink: f32) -> Self {
        let n = samples.rows();
        let d = samples.cols();
        assert!(n > 1, "need at least 2 samples");
        let mean = col_mean(samples);
        let mut cov = Matrix::zeros(d, d);
        for r in 0..n {
            let row = samples.row(r);
            for i in 0..d {
                let xi = row[i] - mean[i];
                let cov_row = cov.row_mut(i);
                for j in 0..d {
                    cov_row[j] += xi * (row[j] - mean[j]);
                }
            }
        }
        let inv_n = 1.0 / (n - 1) as f32;
        for v in cov.as_mut_slice() {
            *v *= inv_n;
        }
        // Shrink toward sigma^2 * I.
        let trace: f32 = (0..d).map(|i| cov[(i, i)]).sum();
        let sigma2 = (trace / d as f32).max(1e-6);
        for i in 0..d {
            for j in 0..d {
                let target = if i == j { sigma2 } else { 0.0 };
                cov[(i, j)] = (1.0 - shrink) * cov[(i, j)] + shrink * target;
            }
        }
        let cov_inv = invert(&cov);
        Distribution { mean, cov_inv }
    }

    /// Mahalanobis distance from `x` to this distribution.
    pub fn mahalanobis(&self, x: &[f32]) -> f32 {
        let d = self.mean.len();
        let diff: Vec<f32> = x.iter().zip(&self.mean).map(|(a, b)| a - b).collect();
        let mut acc = 0.0f32;
        for i in 0..d {
            let mut t = 0.0f32;
            let row = self.cov_inv.row(i);
            for j in 0..d {
                t += row[j] * diff[j];
            }
            acc += diff[i] * t;
        }
        acc.max(0.0).sqrt()
    }
}

/// Gauss-Jordan inversion with partial pivoting (d ≤ 128, off hot path).
fn invert(a: &Matrix) -> Matrix {
    let d = a.rows();
    assert_eq!(d, a.cols());
    let mut aug = Matrix::from_fn(d, 2 * d, |r, c| {
        if c < d {
            a[(r, c)]
        } else if c - d == r {
            1.0
        } else {
            0.0
        }
    });
    for col in 0..d {
        // Pivot.
        let mut piv = col;
        for r in col + 1..d {
            if aug[(r, col)].abs() > aug[(piv, col)].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..2 * d {
                let tmp = aug[(col, c)];
                aug[(col, c)] = aug[(piv, c)];
                aug[(piv, c)] = tmp;
            }
        }
        let p = aug[(col, col)];
        assert!(p.abs() > 1e-12, "singular covariance (increase shrinkage)");
        let inv_p = 1.0 / p;
        for c in 0..2 * d {
            aug[(col, c)] *= inv_p;
        }
        for r in 0..d {
            if r == col {
                continue;
            }
            let f = aug[(r, col)];
            if f == 0.0 {
                continue;
            }
            for c in 0..2 * d {
                aug[(r, c)] -= f * aug[(col, c)];
            }
        }
    }
    Matrix::from_fn(d, d, |r, c| aug[(r, c + d)])
}

/// Fig 3b summary: mean Mahalanobis distance of query samples and of
/// held-out key samples to the key distribution.
pub struct OodReport {
    pub q_to_k: f32,
    pub k_to_k: f32,
}

impl OodReport {
    /// How many times farther queries are than in-distribution keys —
    /// the paper reports >10×.
    pub fn gap(&self) -> f32 {
        self.q_to_k / self.k_to_k.max(1e-9)
    }
}

/// Compute the Fig 3b measurement: fit the key distribution on `keys_fit`,
/// then average distances of `queries` and of `keys_holdout`.
pub fn measure_ood(keys_fit: &Matrix, keys_holdout: &Matrix, queries: &Matrix) -> OodReport {
    let dist = Distribution::fit(keys_fit, 0.1);
    let avg = |m: &Matrix| -> f32 {
        (0..m.rows()).map(|r| dist.mahalanobis(m.row(r))).sum::<f32>() / m.rows().max(1) as f32
    };
    OodReport { q_to_k: avg(queries), k_to_k: avg(keys_holdout) }
}

#[cfg(test)]
mod tests {
    use super::*;
    
    use crate::util::rng::Rng;

    #[test]
    fn invert_identity() {
        let i = Matrix::from_fn(4, 4, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(invert(&i), i);
    }

    #[test]
    fn invert_known_2x2() {
        let a = Matrix::from_vec(2, 2, vec![4.0, 7.0, 2.0, 6.0]);
        let inv = invert(&a);
        let prod = a.matmul(&inv);
        for r in 0..2 {
            for c in 0..2 {
                let expect = if r == c { 1.0 } else { 0.0 };
                assert!((prod[(r, c)] - expect).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn in_distribution_distance_small() {
        let mut rng = Rng::seed_from(3);
        let keys = Matrix::from_fn(2000, 8, |_, _| rng.f32() - 0.5);
        let holdout = Matrix::from_fn(200, 8, |_, _| rng.f32() - 0.5);
        // Queries: shifted far away.
        let queries = Matrix::from_fn(200, 8, |_, _| rng.f32() - 0.5 + 5.0);
        let rep = measure_ood(&keys, &holdout, &queries);
        assert!(rep.k_to_k < 4.0, "in-dist distance should be ~sqrt(d): {}", rep.k_to_k);
        assert!(rep.gap() > 5.0, "OOD queries must be far: gap={}", rep.gap());
    }

    #[test]
    fn mahalanobis_accounts_for_scale() {
        // A point 3 units along a high-variance axis is *closer* in
        // Mahalanobis terms than 3 units along a low-variance axis.
        let mut rng = Rng::seed_from(4);
        let samples = Matrix::from_fn(5000, 2, |_, c| {
            (rng.f32() - 0.5) * if c == 0 { 10.0 } else { 0.5 }
        });
        let dist = Distribution::fit(&samples, 0.0);
        let wide = dist.mahalanobis(&[3.0, 0.0]);
        let narrow = dist.mahalanobis(&[0.0, 3.0]);
        assert!(narrow > 3.0 * wide, "wide={wide} narrow={narrow}");
    }
}
