//! Dynamic-sparsity profiling (§2.3, Fig 2).
//!
//! The paper quantifies attention sparsity with the *recovery ratio*: the
//! cumulative softmax mass captured by the top-k critical tokens. Fig 2
//! contrasts the ratio for a per-query dynamic top-k (≈89% mean at
//! top-1000 / 100K context) against a static top-k frozen at the first
//! decode step (drops to ≈71%) — the observation motivating retrieval.

use crate::tensor::{argtopk, Matrix};

/// Softmax mass captured by the exact per-query top-`k` tokens.
pub fn dynamic_recovery(q: &[f32], keys: &Matrix, k: usize, scale: f32) -> f32 {
    let s = super::scores(q, keys, scale);
    argtopk(&s, k).into_iter().map(|i| s[i]).sum()
}

/// Softmax mass captured by a *fixed* token set for this query.
pub fn static_recovery(q: &[f32], keys: &Matrix, ids: &[u32], scale: f32) -> f32 {
    let s = super::scores(q, keys, scale);
    ids.iter().map(|&i| s[i as usize]).sum()
}

/// Exact top-`k` critical token ids for a query (the Fig 2 "first token"
/// static set is this, captured at step 0).
pub fn critical_ids(q: &[f32], keys: &Matrix, k: usize, scale: f32) -> Vec<u32> {
    let s = super::scores(q, keys, scale);
    argtopk(&s, k).into_iter().map(|i| i as u32).collect()
}

/// Fig 2 datapoint for one head: recovery ratios of `queries` (decode
/// steps) under (a) per-query dynamic top-k, (b) the static top-k of the
/// first query.
pub struct HeadSparsity {
    pub dynamic: Vec<f32>,
    pub static_first: Vec<f32>,
}

pub fn profile_head(queries: &Matrix, keys: &Matrix, k: usize, scale: f32) -> HeadSparsity {
    assert!(queries.rows() > 0);
    let first_set = critical_ids(queries.row(0), keys, k, scale);
    let mut dynamic = Vec::with_capacity(queries.rows());
    let mut static_first = Vec::with_capacity(queries.rows());
    for t in 0..queries.rows() {
        let q = queries.row(t);
        dynamic.push(dynamic_recovery(q, keys, k, scale));
        static_first.push(static_recovery(q, keys, &first_set, scale));
    }
    HeadSparsity { dynamic, static_first }
}

#[cfg(test)]
mod tests {
    use super::*;
    
    use crate::util::rng::Rng;

    #[test]
    fn dynamic_dominates_static() {
        // For any query, the exact top-k mass upper-bounds any fixed set of
        // the same size.
        let mut rng = Rng::seed_from(7);
        let keys = Matrix::from_fn(500, 8, |_, _| rng.f32() - 0.5);
        let queries = Matrix::from_fn(10, 8, |_, _| 2.0 * rng.f32() - 1.0);
        let prof = profile_head(&queries, &keys, 50, 0.35);
        for (d, s) in prof.dynamic.iter().zip(prof.static_first.iter()) {
            assert!(d + 1e-6 >= *s, "dynamic {d} < static {s}");
        }
        // At t=0 the static set *is* the dynamic set.
        assert!((prof.dynamic[0] - prof.static_first[0]).abs() < 1e-6);
    }

    #[test]
    fn recovery_of_full_set_is_one() {
        let mut rng = Rng::seed_from(8);
        let keys = Matrix::from_fn(100, 4, |_, _| rng.f32());
        let q: Vec<f32> = (0..4).map(|_| rng.f32()).collect();
        let r = dynamic_recovery(&q, &keys, 100, 0.5);
        assert!((r - 1.0).abs() < 1e-5);
    }

    #[test]
    fn sharper_distribution_sparser() {
        // Scaling logits up concentrates mass => higher top-k recovery.
        let mut rng = Rng::seed_from(9);
        let keys = Matrix::from_fn(200, 8, |_, _| rng.f32() - 0.5);
        let q: Vec<f32> = (0..8).map(|_| rng.f32() - 0.5).collect();
        let soft = dynamic_recovery(&q, &keys, 10, 0.1);
        let sharp = dynamic_recovery(&q, &keys, 10, 10.0);
        assert!(sharp > soft);
    }
}
