//! The attention engine: full attention, subset (sparse) attention, and the
//! exact two-set combination of Appendix B.1.
//!
//! The decode-time contract (Algorithm 1): the device computes
//! `(o_W, lse_W)` over the static set `W` via the AOT FlashAttention
//! artifact; the host computes `(o_Ω, lse_Ω)` over the retrieved set `Ω`;
//! [`combine`] merges them with the `γ₁/γ₂` rescaling of Eq. 4/5, which is
//! *exact*: the merged output equals attention computed jointly over
//! `W ∪ Ω` (verified by unit and property tests).

pub mod budget;
pub mod ood;
pub mod sparsity;

use crate::kernel;
use crate::tensor::{axpy, Matrix};

/// A partial attention output over some token subset: the within-subset
/// softmax-weighted value sum plus the subset's log-sum-exp of the scaled
/// logits. `(o, lse)` is exactly what the Pallas `flash_decode` kernel
/// returns from the device side.
#[derive(Clone, Debug)]
pub struct PartialAttention {
    pub o: Vec<f32>,
    pub lse: f32,
}

impl PartialAttention {
    /// The additive identity: an empty subset.
    pub fn empty(d: usize) -> Self {
        PartialAttention { o: vec![0.0; d], lse: f32::NEG_INFINITY }
    }
}

/// Attention of `q` over the tokens `ids` of `(keys, values)`, returning
/// the partial `(o, lse)` pair. `scale` is `1/sqrt(d_head)`.
pub fn attend_subset(
    q: &[f32],
    keys: &Matrix,
    values: &Matrix,
    ids: &[u32],
    scale: f32,
) -> PartialAttention {
    let d = values.cols();
    if ids.is_empty() {
        return PartialAttention::empty(d);
    }
    // Batched logits first (one kernel dispatch for the whole id-set
    // gather — the keys read is the bandwidth hot spot; this is always
    // against the full-precision keys), then a two-pass softmax over the
    // in-cache logit vector: same exact result as the online form, no
    // per-id rescale of the accumulator.
    let mut z: Vec<f32> = Vec::with_capacity(ids.len());
    kernel::dot_gather(q, keys.as_slice(), keys.cols(), ids, &mut z);
    let mut m = f32::NEG_INFINITY;
    for v in z.iter_mut() {
        *v *= scale;
        if *v > m {
            m = *v;
        }
    }
    let mut l = 0.0f32;
    let mut acc = vec![0.0f32; d];
    for (&id, &zv) in ids.iter().zip(z.iter()) {
        let p = (zv - m).exp();
        l += p;
        axpy(p, values.row(id as usize), &mut acc);
    }
    let inv = 1.0 / l;
    for a in acc.iter_mut() {
        *a *= inv;
    }
    PartialAttention { o: acc, lse: m + l.ln() }
}

/// Multi-query attention for one GQA group: every query head's attention
/// over its own candidate id set of the group's shared `(keys, values)`.
/// The heads' id sets are unioned and ALL heads are scored against the
/// union rows in one batched multi-query gather
/// ([`kernel::dot_gather_mq`]) — each candidate key row is read once per
/// group instead of once per head. This is the wave scheduler's fused
/// host-attention read.
///
/// **Bit-identical** to calling [`attend_subset`] once per head: the
/// per-(query, row) dot products go through the same backend `dot`
/// reduction, and each head's two-pass softmax accumulates in its own id
/// order over exactly the logit values `dot_gather` would have produced.
pub fn attend_group_mq(
    qs: &[f32],
    keys: &Matrix,
    values: &Matrix,
    per_head_ids: &[&[u32]],
    scale: f32,
) -> Vec<PartialAttention> {
    let d = values.cols();
    let cols = keys.cols();
    let nq = per_head_ids.len();
    debug_assert_eq!(qs.len(), nq * cols, "query block length != heads × head_dim");
    // Union of every head's candidate set (sorted ⇒ binary-searchable).
    let mut union: Vec<u32> =
        Vec::with_capacity(per_head_ids.iter().map(|ids| ids.len()).sum());
    for ids in per_head_ids {
        union.extend_from_slice(ids);
    }
    union.sort_unstable();
    union.dedup();
    if union.is_empty() {
        return (0..nq).map(|_| PartialAttention::empty(d)).collect();
    }
    // One multi-query gather: every head scored against the union rows.
    let mut z_all: Vec<f32> = Vec::with_capacity(nq * union.len());
    kernel::dot_gather_mq(qs, nq, keys.as_slice(), cols, &union, &mut z_all);
    (0..nq)
        .map(|h| {
            let ids = per_head_ids[h];
            if ids.is_empty() {
                return PartialAttention::empty(d);
            }
            let zrow = &z_all[h * union.len()..(h + 1) * union.len()];
            // This head's logits in ITS id order — the exact values a
            // per-head `dot_gather` would produce, picked out of the
            // union row (every id is in the union by construction).
            let mut z: Vec<f32> = Vec::with_capacity(ids.len());
            for &id in ids {
                let j = union
                    .binary_search(&id)
                    .expect("candidate id missing from its own union");
                z.push(zrow[j]);
            }
            // Two-pass softmax, op-for-op the `attend_subset` form.
            let mut m = f32::NEG_INFINITY;
            for v in z.iter_mut() {
                *v *= scale;
                if *v > m {
                    m = *v;
                }
            }
            let mut l = 0.0f32;
            let mut acc = vec![0.0f32; d];
            for (&id, &zv) in ids.iter().zip(z.iter()) {
                let p = (zv - m).exp();
                l += p;
                axpy(p, values.row(id as usize), &mut acc);
            }
            let inv = 1.0 / l;
            for a in acc.iter_mut() {
                *a *= inv;
            }
            PartialAttention { o: acc, lse: m + l.ln() }
        })
        .collect()
}

/// Full attention over all tokens `0..keys.rows()`.
pub fn full_attention(q: &[f32], keys: &Matrix, values: &Matrix, scale: f32) -> Vec<f32> {
    let ids: Vec<u32> = (0..keys.rows() as u32).collect();
    attend_subset(q, keys, values, &ids, scale).o
}

/// Merge disjoint partial attentions exactly (Eq. 4/5): the γ factors are
/// `exp(lse_i - lse_total)` with `lse_total = logaddexp(lse_1, ..., lse_n)`.
pub fn combine(parts: &[PartialAttention]) -> PartialAttention {
    let d = parts.iter().map(|p| p.o.len()).max().unwrap_or(0);
    let m = parts.iter().map(|p| p.lse).fold(f32::NEG_INFINITY, f32::max);
    if m == f32::NEG_INFINITY {
        return PartialAttention::empty(d);
    }
    // total = m + ln(sum exp(lse_i - m))
    let sum: f32 = parts.iter().map(|p| (p.lse - m).exp()).sum();
    let lse = m + sum.ln();
    let mut o = vec![0.0f32; d];
    for p in parts {
        let gamma = (p.lse - lse).exp();
        if gamma > 0.0 {
            axpy(gamma, &p.o, &mut o);
        }
    }
    PartialAttention { o, lse }
}

/// Borrow-based [`combine`] for the decode hot path: merges `(o, lse)`
/// pairs straight into `out` (which must already have the head dimension)
/// without cloning any partial. Empty partials pass `(&[], NEG_INFINITY)`.
/// Returns the merged log-sum-exp.
pub fn combine_into(parts: &[(&[f32], f32)], out: &mut [f32]) -> f32 {
    for o in out.iter_mut() {
        *o = 0.0;
    }
    let m = parts.iter().map(|p| p.1).fold(f32::NEG_INFINITY, f32::max);
    if m == f32::NEG_INFINITY {
        return f32::NEG_INFINITY;
    }
    let sum: f32 = parts.iter().map(|p| (p.1 - m).exp()).sum();
    let lse = m + sum.ln();
    for &(o, lse_p) in parts {
        let gamma = (lse_p - lse).exp();
        if gamma > 0.0 && !o.is_empty() {
            axpy(gamma, o, out);
        }
    }
    lse
}

/// Raw scaled attention logits of `q` against every key (profiling paths):
/// one batched kernel call over the contiguous key matrix.
pub fn logits(q: &[f32], keys: &Matrix, scale: f32) -> Vec<f32> {
    let mut z = Vec::with_capacity(keys.rows());
    kernel::dot_rows(q, keys.as_slice(), keys.cols(), &mut z);
    for v in z.iter_mut() {
        *v *= scale;
    }
    z
}

/// Softmax scores of `q` against every key.
pub fn scores(q: &[f32], keys: &Matrix, scale: f32) -> Vec<f32> {
    let mut z = logits(q, keys, scale);
    crate::tensor::softmax_inplace(&mut z);
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    
    use crate::util::rng::Rng;

    fn setup(n: usize, d: usize, seed: u64) -> (Vec<f32>, Matrix, Matrix) {
        let mut rng = Rng::seed_from(seed);
        let q: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        let k = Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5);
        let v = Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5);
        (q, k, v)
    }

    #[test]
    fn subset_of_everything_is_full_attention() {
        let (q, k, v) = setup(50, 8, 1);
        let ids: Vec<u32> = (0..50).collect();
        let part = attend_subset(&q, &k, &v, &ids, 0.35);
        let full = full_attention(&q, &k, &v, 0.35);
        for (a, b) in part.o.iter().zip(full.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn combine_is_exact() {
        // Split tokens into two disjoint sets; combining the partials must
        // equal attention over the union — the Appendix B.1 guarantee.
        let (q, k, v) = setup(100, 16, 2);
        let scale = 1.0 / 4.0;
        let w: Vec<u32> = (0..30).collect();
        let omega: Vec<u32> = (30..100).collect();
        let p1 = attend_subset(&q, &k, &v, &w, scale);
        let p2 = attend_subset(&q, &k, &v, &omega, scale);
        let merged = combine(&[p1, p2]);
        let full = full_attention(&q, &k, &v, scale);
        for (a, b) in merged.o.iter().zip(full.iter()) {
            assert!((a - b).abs() < 1e-5, "combine must be exact: {a} vs {b}");
        }
    }

    #[test]
    fn combine_three_way() {
        let (q, k, v) = setup(60, 8, 3);
        let scale = 0.5;
        let sets: Vec<Vec<u32>> = vec![(0..10).collect(), (10..35).collect(), (35..60).collect()];
        let parts: Vec<PartialAttention> =
            sets.iter().map(|s| attend_subset(&q, &k, &v, s, scale)).collect();
        let merged = combine(&parts);
        let full = full_attention(&q, &k, &v, scale);
        for (a, b) in merged.o.iter().zip(full.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn combine_with_empty_partial() {
        let (q, k, v) = setup(20, 4, 4);
        let ids: Vec<u32> = (0..20).collect();
        let p = attend_subset(&q, &k, &v, &ids, 0.5);
        let merged = combine(&[p.clone(), PartialAttention::empty(4)]);
        for (a, b) in merged.o.iter().zip(p.o.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
        assert!((merged.lse - p.lse).abs() < 1e-5);
    }

    #[test]
    fn combine_into_matches_combine() {
        let (q, k, v) = setup(80, 8, 5);
        let scale = 0.3;
        let a: Vec<u32> = (0..25).collect();
        let b: Vec<u32> = (25..80).collect();
        let p1 = attend_subset(&q, &k, &v, &a, scale);
        let p2 = attend_subset(&q, &k, &v, &b, scale);
        let merged = combine(&[p1.clone(), p2.clone()]);
        let mut out = vec![0.0f32; 8];
        let lse = combine_into(&[(p1.o.as_slice(), p1.lse), (p2.o.as_slice(), p2.lse)], &mut out);
        assert!((lse - merged.lse).abs() < 1e-6);
        for (x, y) in out.iter().zip(merged.o.iter()) {
            assert!((x - y).abs() < 1e-6, "combine_into diverged: {x} vs {y}");
        }
        // Empty partials are the identity under the borrow form too.
        let empty: &[f32] = &[];
        let mut out2 = vec![7.0f32; 8];
        let lse2 =
            combine_into(&[(p1.o.as_slice(), p1.lse), (empty, f32::NEG_INFINITY)], &mut out2);
        assert!((lse2 - p1.lse).abs() < 1e-5);
        for (x, y) in out2.iter().zip(p1.o.iter()) {
            assert!((x - y).abs() < 1e-6);
        }
        // All-empty: -inf lse, zeroed output.
        let mut out3 = vec![3.0f32; 4];
        assert_eq!(combine_into(&[(empty, f32::NEG_INFINITY)], &mut out3), f32::NEG_INFINITY);
        assert_eq!(out3, vec![0.0; 4]);
    }

    #[test]
    fn group_mq_is_bitwise_identical_to_per_head_subset() {
        // The wave scheduler's fused read must not perturb a single bit:
        // every head of the group, scored through the union gather, must
        // reproduce `attend_subset` exactly — overlapping sets, disjoint
        // sets, a head owning the whole range, and an empty head.
        let n = 120usize;
        let d = 16usize;
        let nq = 4usize;
        let mut rng = Rng::seed_from(77);
        let k = Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5);
        let v = Matrix::from_fn(n, d, |_, _| rng.f32() - 0.5);
        let qs: Vec<f32> = (0..nq * d).map(|_| rng.f32() - 0.5).collect();
        let sets: Vec<Vec<u32>> = vec![
            (0..40).collect(),
            (20..90).step_by(3).collect(),
            (0..n as u32).collect(),
            Vec::new(),
        ];
        let per_head: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
        let scale = 0.31;
        let fused = attend_group_mq(&qs, &k, &v, &per_head, scale);
        assert_eq!(fused.len(), nq);
        for h in 0..nq {
            let solo = attend_subset(&qs[h * d..(h + 1) * d], &k, &v, &sets[h], scale);
            assert_eq!(solo.lse.to_bits(), fused[h].lse.to_bits(), "head {h} lse diverged");
            for (a, b) in solo.o.iter().zip(fused[h].o.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "head {h} output diverged");
            }
        }
        // All-empty group: every head is the empty partial.
        let empty_sets: Vec<&[u32]> = vec![&[], &[], &[], &[]];
        for p in attend_group_mq(&qs, &k, &v, &empty_sets, scale) {
            assert_eq!(p.o, vec![0.0; d]);
            assert_eq!(p.lse, f32::NEG_INFINITY);
        }
    }

    #[test]
    fn numerically_stable_with_huge_logits() {
        let q = vec![100.0f32, 0.0];
        let k = Matrix::from_vec(2, 2, vec![10.0, 0.0, 9.9, 0.0]);
        let v = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let out = full_attention(&q, &k, &v, 1.0);
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(out[0] > 0.9, "sharp softmax should pick token 0");
    }

    #[test]
    fn empty_subset_is_identity_under_combine() {
        let e = PartialAttention::empty(3);
        let merged = combine(&[e]);
        assert_eq!(merged.o, vec![0.0; 3]);
        assert_eq!(merged.lse, f32::NEG_INFINITY);
    }

    #[test]
    fn scores_sum_to_one() {
        let (q, k, _) = setup(40, 8, 9);
        let s = scores(&q, &k, 0.35);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
    }
}
