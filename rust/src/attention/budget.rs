//! Per-layer retrieval budget allocation (Appendix F).
//!
//! The paper's default gives every layer the same retrieval top-k. The
//! PyramidKV-style variant allocates more budget to lower layers and less
//! to higher ones (lower layers attend more broadly; upper layers are
//! sharper), keeping the *total* budget constant.

/// How the per-layer retrieval top-k is chosen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BudgetPolicy {
    /// Same top-k for every layer (the paper's default).
    Uniform { k: usize },
    /// PyramidKV-style linear decay from `2k·β/(β+1)` at layer 0 down to
    /// `2k/(β+1)` at the top layer, preserving the mean k.
    Pyramid { k: usize, beta: f32 },
}

impl BudgetPolicy {
    /// Retrieval top-k for `layer` out of `n_layers`.
    pub fn k_for_layer(&self, layer: usize, n_layers: usize) -> usize {
        match *self {
            BudgetPolicy::Uniform { k } => k,
            BudgetPolicy::Pyramid { k, beta } => {
                if n_layers <= 1 {
                    return k;
                }
                let top = 2.0 * k as f32 * beta / (beta + 1.0);
                let bottom = 2.0 * k as f32 / (beta + 1.0);
                let frac = layer as f32 / (n_layers - 1) as f32;
                let v = top + (bottom - top) * frac;
                v.round().max(1.0) as usize
            }
        }
    }

    /// Total budget across all layers.
    pub fn total(&self, n_layers: usize) -> usize {
        (0..n_layers).map(|l| self.k_for_layer(l, n_layers)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_flat() {
        let p = BudgetPolicy::Uniform { k: 100 };
        assert_eq!(p.k_for_layer(0, 8), 100);
        assert_eq!(p.k_for_layer(7, 8), 100);
        assert_eq!(p.total(8), 800);
    }

    #[test]
    fn pyramid_decays_and_preserves_total() {
        let p = BudgetPolicy::Pyramid { k: 100, beta: 3.0 };
        let first = p.k_for_layer(0, 8);
        let last = p.k_for_layer(7, 8);
        assert!(first > last, "lower layers must get more budget");
        let total = p.total(8);
        // Rounding slack of one token per layer.
        assert!((total as i64 - 800).unsigned_abs() as usize <= 8, "total {total}");
    }

    #[test]
    fn single_layer_degenerate() {
        let p = BudgetPolicy::Pyramid { k: 64, beta: 2.0 };
        assert_eq!(p.k_for_layer(0, 1), 64);
    }
}
