//! Minimal dense f32 linear algebra for the host-side code paths.
//!
//! The coordinator's hot loops (index search, CPU-side sparse attention)
//! operate on contiguous row-major matrices. We deliberately avoid a BLAS
//! dependency: the kernels here are small, cache-friendly and fast enough
//! for head-dim-64 workloads, and keeping them in-crate lets the perf pass
//! tune them (see EXPERIMENTS.md §Perf).

use std::ops::{Index, IndexMut};

/// Row-major dense matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major buffer. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length {} != {rows}x{cols}", data.len());
        Matrix { rows, cols, data }
    }

    /// Build row-by-row from a closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Flat row-major view of the whole buffer.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Append a row (amortised O(cols)). Panics on width mismatch.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols, "row width {} != {}", row.len(), self.cols);
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// `self @ other` — naive blocked matmul, good enough off the hot path.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |r, c| self[(c, r)])
    }

    /// Cap to at most `cap` rows by striding (statistically uniform
    /// subsample, used for training-query sets). No-op clone when the
    /// matrix already fits.
    pub fn subsample_strided(&self, cap: usize) -> Matrix {
        if self.rows <= cap {
            return self.clone();
        }
        let step = self.rows / cap;
        Matrix::from_fn(cap, self.cols, |r, c| self[(r * step, c)])
    }

    /// Drop every row at index >= `n` in place (session truncation).
    pub fn truncate_rows(&mut self, n: usize) {
        if n < self.rows {
            self.data.truncate(n * self.cols);
            self.rows = n;
        }
    }

    /// Cap to at most `cap` rows by keeping the most recent (last) rows,
    /// used for recency-windowed query rings.
    pub fn keep_last_rows(&self, cap: usize) -> Matrix {
        if self.rows <= cap {
            return self.clone();
        }
        let skip = self.rows - cap;
        Matrix::from_fn(cap, self.cols, |r, c| self[(r + skip, c)])
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

/// Dot product; the single hottest kernel in the crate (every index
/// traversal and every CPU attention score goes through here). Routed
/// through the runtime-dispatched kernel subsystem: AVX2+FMA / NEON when
/// the CPU has them, a bit-identical 8-way-unrolled scalar otherwise
/// (`RA_KERNEL=scalar` forces the fallback). Batch consumers should call
/// [`crate::kernel::dot_rows`] / [`crate::kernel::dot_gather`] instead of
/// looping this.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::kernel::dot(a, b)
}

/// Squared Euclidean distance (backs IVF/k-means centroid assignment).
/// Same dispatch and 8-way lane structure as [`dot`]; batch consumers
/// should call [`crate::kernel::l2_rows`].
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    crate::kernel::l2_sq(a, b)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// `out += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o += alpha * v;
    }
}

/// In-place numerically-stable softmax. Returns the log-sum-exp.
pub fn softmax_inplace(x: &mut [f32]) -> f32 {
    if x.is_empty() {
        return f32::NEG_INFINITY;
    }
    let m = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
    m + sum.ln()
}

/// Indices of the `k` largest values (ties broken by lower index), sorted by
/// value descending. O(n log k) via a bounded binary min-heap.
pub fn argtopk(x: &[f32], k: usize) -> Vec<usize> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    /// Min-heap entry: reversed comparison on (value, reversed index).
    struct Entry(f32, usize);
    impl PartialEq for Entry {
        fn eq(&self, o: &Self) -> bool {
            self.cmp(o) == Ordering::Equal
        }
    }
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> Ordering {
            // Reverse so BinaryHeap (a max-heap) behaves as a min-heap on value;
            // for equal values the larger index is "smaller" so it is evicted
            // first, keeping the earliest indices.
            o.0.total_cmp(&self.0).then(self.1.cmp(&o.1))
        }
    }

    let k = k.min(x.len());
    if k == 0 {
        return vec![];
    }
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &v) in x.iter().enumerate() {
        if heap.len() < k {
            heap.push(Entry(v, i));
        } else if let Some(top) = heap.peek() {
            if v > top.0 || (v == top.0 && i < top.1) {
                heap.pop();
                heap.push(Entry(v, i));
            }
        }
    }
    let mut out: Vec<(f32, usize)> = heap.into_iter().map(|e| (e.0, e.1)).collect();
    out.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    out.into_iter().map(|(_, i)| i).collect()
}

/// Mean of each column.
pub fn col_mean(m: &Matrix) -> Vec<f32> {
    let mut mean = vec![0.0f32; m.cols()];
    for r in 0..m.rows() {
        axpy(1.0, m.row(r), &mut mean);
    }
    let inv = 1.0 / m.rows().max(1) as f32;
    for v in &mut mean {
        *v *= inv;
    }
    mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Matrix::from_fn(2, 2, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..67).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..67).map(|i| (66 - i) as f32 * 0.25).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2 * naive.abs().max(1.0));
    }

    #[test]
    fn softmax_sums_to_one_and_lse() {
        let mut x = vec![1.0f32, 2.0, 3.0];
        let lse = softmax_inplace(&mut x);
        let sum: f32 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // lse = log(e^1 + e^2 + e^3)
        let expect = (1f64.exp() + 2f64.exp() + 3f64.exp()).ln() as f32;
        assert!((lse - expect).abs() < 1e-5);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut x = vec![1000.0f32, 1000.0, 1000.0];
        softmax_inplace(&mut x);
        for v in x {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn argtopk_basic() {
        let x = vec![0.1f32, 5.0, 3.0, 4.0, -1.0];
        assert_eq!(argtopk(&x, 3), vec![1, 3, 2]);
    }

    #[test]
    fn argtopk_k_larger_than_len() {
        let x = vec![2.0f32, 1.0];
        assert_eq!(argtopk(&x, 10), vec![0, 1]);
    }

    #[test]
    fn argtopk_ties_prefer_lower_index() {
        let x = vec![1.0f32, 1.0, 1.0, 1.0];
        assert_eq!(argtopk(&x, 2), vec![0, 1]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::from_fn(3, 5, |r, c| (r * 5 + c) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn row_capping_helpers() {
        let m = Matrix::from_fn(10, 2, |r, _| r as f32);
        let s = m.subsample_strided(5);
        assert_eq!(s.rows(), 5);
        assert_eq!(s[(1, 0)], 2.0, "stride-2 subsample");
        let t = m.keep_last_rows(3);
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(0, 0)], 7.0, "keeps the tail");
        // Fits already: plain clone.
        assert_eq!(m.subsample_strided(100), m);
        assert_eq!(m.keep_last_rows(10), m);
    }

    #[test]
    fn push_row_grows() {
        let mut m = Matrix::zeros(0, 3);
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn col_mean_known() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 3.0, 3.0, 5.0]);
        assert_eq!(col_mean(&m), vec![2.0, 4.0]);
    }
}
