//! Network front-end: a json-lines TCP server over the router.
//!
//! Wire protocol (one JSON object per line):
//!
//! ```text
//! → {"prompt": [1,2,3], "max_tokens": 8}
//! → {"prompt": [1,2,3], "max_tokens": 8, "session": "open", "session_id": 7}
//! → {"prompt": [4,5],   "max_tokens": 8, "session": "continue", "session_id": 7}
//! → {"session": "close", "session_id": 7}
//! → {"stats": true}
//! ← {"event": "token", "id": 1, "token": 42}          (streamed)
//! ← {"event": "done", "id": 1, "tokens": [...], "ttft_s": ..., "tpot_s": ...}
//! ← {"event": "stats", "registry": {...}, "router": {...}}
//! ← {"event": "error", "id": 1, "message": "..."}
//! ```
//!
//! Session verbs drive the multi-turn registry: `open` retains the
//! finished session under `session_id`; `continue` resumes it (resident
//! in RAM or parked on disk — either way **without re-prefill and without
//! index rebuild**) and extends it with the new prompt tokens; `close`
//! drops it. The done event reports the resume provenance
//! (`resumed_from_disk`, `resume_s`, `snapshot_bytes`) and the replica's
//! cumulative park/resume counters.
//!
//! Implemented on std::net + threads (the vendored crate set has no async
//! runtime); one handler thread per connection, which is plenty for the
//! single-digit-replica deployments this repo targets.

use crate::coordinator::{router::Router, Event, Request, SessionMode, SessionSpec};
use crate::util::json::{self, Value};
use crate::util::sync::{mpsc, Arc, AtomicBool, Ordering};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

/// A running server (drops = stops accepting; existing connections drain).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn start(router: Arc<Router>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).context("bind")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_c = stop.clone();
        let handle = std::thread::spawn(move || {
            loop {
                // Acquire pairs with the Release store in Drop: when the
                // accept loop observes the stop signal it also observes
                // everything the stopping thread wrote before raising it.
                // (Relaxed would "work" for the bool alone but leaves the
                // shutdown unordered against surrounding teardown.)
                if stop_c.load(Ordering::Acquire) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let router = router.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, &router);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => return,
                }
            }
        });
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Release: cross-thread shutdown signal (see Acquire load above).
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, router: &Router) -> Result<()> {
    let peer = stream.try_clone()?;
    let mut reader = BufReader::new(peer);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        // The `stats` verb is a registry read, not a generation request:
        // answer it inline with one `{"event":"stats", ...}` line carrying
        // the full process metrics-registry snapshot plus router state.
        if json::parse(trimmed).ok().and_then(|v| v.get("stats").and_then(Value::as_bool))
            == Some(true)
        {
            let mut o = Value::obj();
            let mut rt = Value::obj();
            rt.set("replicas", router.replica_count())
                .set("outstanding", router.total_outstanding())
                .set("respawns", router.total_respawns() as u64);
            o.set("event", "stats")
                .set("registry", crate::telemetry::registry().snapshot())
                .set("router", rt)
                .set("flightrec_len", crate::telemetry::flightrec_len());
            writeln!(out, "{}", o.to_string())?;
            continue;
        }
        match parse_request(trimmed, router.next_request_id()) {
            Ok(req) => {
                let id = req.id;
                let events = router.submit(req);
                stream_events(&mut out, id, events, router.request_deadline_ms())?;
            }
            Err(e) => {
                let mut o = Value::obj();
                o.set("event", "error").set("id", 0u64).set("message", e.to_string());
                writeln!(out, "{}", o.to_string())?;
            }
        }
    }
}

fn parse_request(line: &str, id: u64) -> Result<Request> {
    let v = json::parse(line)?;
    let session = match v.get("session").and_then(Value::as_str) {
        None => None,
        Some(verb) => {
            let mode = SessionMode::parse(verb)
                .ok_or_else(|| anyhow::anyhow!("unknown session verb `{verb}`"))?;
            let session_id = v
                .get("session_id")
                .and_then(Value::as_u64)
                .context("session verb requires a numeric session_id")?;
            Some(SessionSpec { session_id, mode })
        }
    };
    let close = matches!(session, Some(SessionSpec { mode: SessionMode::Close, .. }));
    let prompt = match v.get("prompt").and_then(Value::as_arr) {
        Some(arr) => arr
            .iter()
            .map(|t| t.as_usize().map(|x| x as u32).context("non-numeric token"))
            .collect::<Result<Vec<u32>>>()?,
        // `close` is a registry operation: no prompt to decode.
        None if close => Vec::new(),
        None => anyhow::bail!("missing prompt array"),
    };
    let max_tokens =
        v.get("max_tokens").and_then(Value::as_usize).unwrap_or(if close { 0 } else { 16 });
    Ok(Request { id, prompt, max_tokens, session })
}

/// Stream one request's events onto the wire. `deadline_ms > 0` bounds
/// the gap between consecutive events (`serving.request_deadline_ms`): a
/// replica that stops making progress — dead but connected — surfaces as
/// a clean error event instead of a connection that hangs forever.
fn stream_events(
    out: &mut TcpStream,
    id: u64,
    events: mpsc::Receiver<Event>,
    deadline_ms: u64,
) -> Result<()> {
    let mut tokens: Vec<u32> = Vec::new();
    loop {
        let next = if deadline_ms == 0 {
            events.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected)
        } else {
            events.recv_timeout(std::time::Duration::from_millis(deadline_ms))
        };
        match next {
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let mut o = Value::obj();
                o.set("event", "error").set("id", id).set(
                    "message",
                    format!("request deadline exceeded ({deadline_ms} ms without progress)"),
                );
                writeln!(out, "{}", o.to_string())?;
                return Ok(());
            }
            Ok(Event::Token(_, t)) => {
                tokens.push(t);
                let mut o = Value::obj();
                o.set("event", "token").set("id", id).set("token", t);
                writeln!(out, "{}", o.to_string())?;
            }
            Ok(Event::Done(_, m)) => {
                let mut o = Value::obj();
                o.set("event", "done")
                    .set("id", id)
                    .set("tokens", tokens.clone())
                    .set("prefill_s", m.prefill_s)
                    .set("ttft_s", m.ttft_s)
                    .set("tpot_s", m.tpot_s)
                    .set("search_share", m.breakdown.search_share())
                    .set("maintenance_share", m.breakdown.maintenance_share())
                    .set("drained_tokens", m.drained_tokens)
                    .set("drains", m.drains)
                    .set("evicted_tokens", m.evicted_tokens)
                    .set("reclaims", m.reclaims)
                    .set("reclaimed_rows", m.reclaimed_rows)
                    .set("maint_swaps", m.maint_swaps)
                    .set("maint_swap_s_mean", m.maint_swap_s_mean)
                    .set("maint_queue_peak", m.maint_queue_peak)
                    .set("tombstone_ratio", m.tombstone_ratio)
                    .set("resumed_from_disk", m.resumed_from_disk)
                    .set("resume_s", m.resume_s)
                    .set("snapshot_bytes", m.snapshot_bytes)
                    .set("session_parks", m.session_parks)
                    .set("session_resumes", m.session_resumes)
                    .set("queue_depth_peak", m.queue_depth_peak)
                    .set("wave_occupancy_mean", m.wave_occupancy_mean)
                    .set("max_gap_waves", m.max_gap_waves)
                    .set("replica_tokens_per_s", m.replica_tokens_per_s)
                    .set("streaming_head_fraction", m.streaming_head_fraction)
                    .set("index_bytes_avoided", m.index_bytes_avoided)
                    .set("sessions_recovered", m.sessions_recovered)
                    .set("snapshots_quarantined", m.snapshots_quarantined);
                // The span tree is present only when spans were recorded
                // (the `serving.telemetry.spans` knob): an absent key, not
                // an all-zero subtree, when tracing is off.
                if !m.spans.is_empty() {
                    o.set("spans", m.spans.to_json());
                }
                writeln!(out, "{}", o.to_string())?;
                return Ok(());
            }
            Ok(Event::Failed(_, msg)) => {
                let mut o = Value::obj();
                o.set("event", "error").set("id", id).set("message", msg);
                writeln!(out, "{}", o.to_string())?;
                return Ok(());
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                let mut o = Value::obj();
                o.set("event", "error").set("id", id).set("message", "replica dropped");
                writeln!(out, "{}", o.to_string())?;
                return Ok(());
            }
        }
    }
}

/// Minimal blocking client for the json-lines protocol (used by examples
/// and integration tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Client-side per-read deadline: if the server goes `deadline_ms`
    /// without sending a line, `roundtrip` fails with a clean deadline
    /// error instead of blocking forever on a dead-but-connected server.
    /// `0` clears the deadline.
    pub fn set_deadline(&mut self, deadline_ms: u64) -> Result<()> {
        let t = (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms));
        self.reader.get_ref().set_read_timeout(t).context("set client read deadline")?;
        Ok(())
    }

    /// Send one request and block until done; returns (tokens, done-object).
    pub fn generate(&mut self, prompt: &[u32], max_tokens: usize) -> Result<(Vec<u32>, Value)> {
        let mut o = Value::obj();
        o.set("prompt", prompt.iter().map(|&t| t as usize).collect::<Vec<usize>>())
            .set("max_tokens", max_tokens);
        self.roundtrip(o)
    }

    /// First turn of a multi-turn session: prefill + generate, then the
    /// server retains the session under `session_id`.
    pub fn open_session(
        &mut self,
        session_id: u64,
        prompt: &[u32],
        max_tokens: usize,
    ) -> Result<(Vec<u32>, Value)> {
        let mut o = Value::obj();
        o.set("prompt", prompt.iter().map(|&t| t as usize).collect::<Vec<usize>>())
            .set("max_tokens", max_tokens)
            .set("session", "open")
            .set("session_id", session_id);
        self.roundtrip(o)
    }

    /// Later turn: the server resumes the retained session (resident or
    /// parked on disk) and decode-extends it with `prompt` — no prefill.
    pub fn continue_session(
        &mut self,
        session_id: u64,
        prompt: &[u32],
        max_tokens: usize,
    ) -> Result<(Vec<u32>, Value)> {
        let mut o = Value::obj();
        o.set("prompt", prompt.iter().map(|&t| t as usize).collect::<Vec<usize>>())
            .set("max_tokens", max_tokens)
            .set("session", "continue")
            .set("session_id", session_id);
        self.roundtrip(o)
    }

    /// Drop a retained session from the server's RAM and disk.
    pub fn close_session(&mut self, session_id: u64) -> Result<Value> {
        let mut o = Value::obj();
        o.set("session", "close").set("session_id", session_id);
        Ok(self.roundtrip(o)?.1)
    }

    /// Fetch the server's observability snapshot (the `stats` verb): the
    /// full process metrics registry (counters / gauges / histograms /
    /// labels) plus router state, as one `{"event":"stats", ...}` object.
    pub fn stats(&mut self) -> Result<Value> {
        let mut o = Value::obj();
        o.set("stats", true);
        writeln!(self.writer, "{}", o.to_string())?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed connection");
        let v = json::parse(line.trim())?;
        anyhow::ensure!(v.req_str("event")? == "stats", "expected a stats event");
        Ok(v)
    }

    fn roundtrip(&mut self, req: Value) -> Result<(Vec<u32>, Value)> {
        writeln!(self.writer, "{}", req.to_string())?;
        let mut tokens = Vec::new();
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.reader.read_line(&mut line).map_err(|e| {
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) {
                    anyhow::anyhow!("client deadline exceeded waiting for server")
                } else {
                    anyhow::Error::from(e)
                }
            })?;
            if n == 0 {
                anyhow::bail!("server closed connection");
            }
            let v = json::parse(line.trim())?;
            match v.req_str("event")? {
                "token" => tokens.push(v.req_f64("token")? as u32),
                "done" => return Ok((tokens, v)),
                "error" => anyhow::bail!("server error: {}", v.req_str("message")?),
                other => anyhow::bail!("unknown event {other}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_roundtrip() {
        let r = parse_request(r#"{"prompt": [1, 2, 3], "max_tokens": 4}"#, 7).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_tokens, 4);
    }

    #[test]
    fn parse_request_defaults_max_tokens() {
        let r = parse_request(r#"{"prompt": [9]}"#, 1).unwrap();
        assert_eq!(r.max_tokens, 16);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("{}", 1).is_err());
        assert!(parse_request("not json", 1).is_err());
    }

    #[test]
    fn parse_session_verbs() {
        let r = parse_request(
            r#"{"prompt": [1], "max_tokens": 2, "session": "open", "session_id": 9}"#,
            1,
        )
        .unwrap();
        assert_eq!(r.session, Some(SessionSpec { session_id: 9, mode: SessionMode::Open }));
        let r = parse_request(
            r#"{"prompt": [2], "session": "continue", "session_id": 9}"#,
            2,
        )
        .unwrap();
        assert_eq!(r.session.unwrap().mode, SessionMode::Continue);
        // Close needs no prompt; defaults to zero generated tokens.
        let r = parse_request(r#"{"session": "close", "session_id": 9}"#, 3).unwrap();
        assert_eq!(r.session.unwrap().mode, SessionMode::Close);
        assert!(r.prompt.is_empty());
        assert_eq!(r.max_tokens, 0);
        // Verb without id, and unknown verbs, are rejected.
        assert!(parse_request(r#"{"prompt": [1], "session": "open"}"#, 4).is_err());
        assert!(
            parse_request(r#"{"prompt": [1], "session": "fork", "session_id": 1}"#, 5).is_err()
        );
        // A non-session request without a prompt is still rejected.
        assert!(parse_request(r#"{"max_tokens": 4}"#, 6).is_err());
    }
}
