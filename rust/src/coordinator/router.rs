//! Multi-replica router: least-outstanding-requests dispatch.
//!
//! Mirrors the vLLM router's default policy: each replica worker owns one
//! engine; the router picks the replica with the fewest in-flight
//! requests (ties broken round-robin).

use super::{Event, Replica, Request};
use crate::config::ServeConfig;
use crate::util::sync::{mpsc::Receiver, AtomicU64, Ordering};

/// A fleet of replicas behind one submit() entry point.
pub struct Router {
    replicas: Vec<Replica>,
    /// The fleet's shared config (deadline and supervision knobs are
    /// read back out by the server front-end).
    cfg: ServeConfig,
    // Relaxed (allowlisted counters): `rr` only spreads tie-breaks and
    // `next_id` only needs uniqueness; neither guards any other memory.
    rr: AtomicU64,
    next_id: AtomicU64,
}

impl Router {
    /// Spawn `n` replicas of the same config.
    pub fn spawn(cfg: ServeConfig, n: usize) -> Router {
        assert!(n >= 1);
        let replicas = (0..n).map(|_| Replica::spawn(cfg.clone())).collect();
        Router { replicas, cfg, rr: AtomicU64::new(0), next_id: AtomicU64::new(1) }
    }

    /// Number of replicas.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Per-request progress deadline (ms between events; 0 = none). The
    /// server's event-streaming loop enforces this so a wedged replica
    /// surfaces as a clean timeout failure instead of a hung connection.
    pub fn request_deadline_ms(&self) -> u64 {
        self.cfg.serving.request_deadline_ms
    }

    /// Worker respawns consumed across the fleet (supervision telemetry).
    pub fn total_respawns(&self) -> u32 {
        self.replicas.iter().map(|r| r.respawn_count()).sum()
    }

    /// Allocate a request id.
    pub fn next_request_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Route to the least-loaded replica — except session-tracked
    /// requests, which pin to `session_id % replicas`: a session's
    /// retained KV/indexes (and its disk snapshots) live on exactly one
    /// replica worker, so every turn of a session must land there.
    /// (Cross-replica session migration is a named ROADMAP follow-up on
    /// top of the snapshot format.)
    pub fn submit(&self, req: Request) -> Receiver<Event> {
        if let Some(spec) = req.session {
            let idx = (spec.session_id % self.replicas.len() as u64) as usize;
            return self.replicas[idx].submit(req);
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) as usize;
        let n = self.replicas.len();
        let mut best = start % n;
        let mut best_load = usize::MAX;
        for i in 0..n {
            let idx = (start + i) % n;
            let load = self.replicas[idx].outstanding();
            if load < best_load {
                best_load = load;
                best = idx;
            }
        }
        self.replicas[best].submit(req)
    }

    /// Total in-flight requests across the fleet.
    pub fn total_outstanding(&self) -> usize {
        self.replicas.iter().map(|r| r.outstanding()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Router logic that doesn't need a live engine is covered here; full
    // end-to-end routing runs in rust/tests/serving.rs.

    #[test]
    fn request_ids_monotone() {
        // Construct a router without engines by using replica stubs is not
        // possible (Replica::spawn builds a real engine); so only test the
        // id allocator against a zero-replica-free constructor surrogate.
        let ids = AtomicU64::new(1);
        let a = ids.fetch_add(1, Ordering::Relaxed);
        let b = ids.fetch_add(1, Ordering::Relaxed);
        assert!(b > a);
    }
}
