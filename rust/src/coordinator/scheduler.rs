//! Replica-level continuous-batching primitives: the **slot board** that
//! tracks in-flight work across wave boundaries, and the **wave-pick**
//! policy that decides which resident sessions decode this wave.
//!
//! The slot board replaces the raw `outstanding` counter the replica used
//! to carry. Its contract is the exactly-once invariant the serving tests
//! lock in: every job `enter()`s the board once (in `Replica::submit`,
//! before the channel send) and `retire()`s once — on exactly one of the
//! terminal paths (done, failed, rejected, drained-at-shutdown) — so
//! `in_flight()` never double-counts a session that stays resident across
//! wave boundaries and never goes negative.
//!
//! Memory ordering: this file is deliberately **not** on the
//! `Ordering::Relaxed` allowlist (`xtask lint`). The counters are part of
//! a cross-thread protocol — a client observing `in_flight() == 0` must
//! also observe the effects of the retirements that got it there — so all
//! writes are `Release` and all reads `Acquire`. The loom model in
//! `tests/loom_models.rs` (`slot_protocol_model`) checks the protocol:
//! publish-the-result *before* retiring the slot, observers that see the
//! count drain must see every published result.

use crate::util::sync::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Shared admission/retirement board for one replica worker.
#[derive(Debug, Default)]
pub struct SlotBoard {
    /// Jobs ever admitted to the replica (monotone).
    admitted: AtomicU64,
    /// Jobs fully retired (monotone; `retired <= admitted`).
    retired: AtomicU64,
    /// Jobs sitting in the worker's waiting queue (gauge, worker-owned).
    queued: AtomicUsize,
    /// Raised when the replica is shutting down; `submit` fast-fails.
    stop: AtomicBool,
}

impl SlotBoard {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one job entering the replica. Called by the submitter
    /// *before* the channel send so the job is never invisible.
    pub fn enter(&self) {
        self.admitted.fetch_add(1, Ordering::Release);
    }

    /// Record one job leaving the replica. Must be called exactly once
    /// per entered job, *after* its results have been published (tokens
    /// streamed, session retained) and *before* its terminal Done/Failed
    /// event — a client acting on the event must observe the freed slot.
    pub fn retire(&self) {
        self.retired.fetch_add(1, Ordering::Release);
    }

    /// Jobs entered but not yet retired. Reads `retired` first so a
    /// concurrent `enter`/`retire` pair can only make the result
    /// conservatively high, never negative.
    pub fn in_flight(&self) -> usize {
        let retired = self.retired.load(Ordering::Acquire);
        let admitted = self.admitted.load(Ordering::Acquire);
        admitted.saturating_sub(retired) as usize
    }

    /// Worker-side gauge: jobs currently parked in the waiting queue.
    pub fn set_queued(&self, n: usize) {
        self.queued.store(n, Ordering::Release);
    }

    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Signal shutdown: submitters observing this refuse new work.
    pub fn raise_stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }
}

/// Pick which resident sessions decode this wave.
///
/// `waited[i]` is how many consecutive waves session `i` has sat
/// unscheduled; `seq[i]` is its admission sequence number (FIFO
/// tiebreak). `wave_size == 0` means unthrottled: every resident session
/// decodes every wave. Otherwise the `wave_size` longest-waiting
/// sessions are picked, and — the fairness bound — any session that
/// would otherwise reach `fairness_waves` consecutive unscheduled waves
/// is force-included, so no admitted session's inter-token gap ever
/// exceeds `fairness_waves` waves even under saturation.
pub fn pick_wave(
    wave_size: usize,
    fairness_waves: usize,
    waited: &[u64],
    seq: &[u64],
) -> Vec<usize> {
    let n = waited.len();
    debug_assert_eq!(seq.len(), n);
    if wave_size == 0 || n <= wave_size {
        return (0..n).collect();
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| waited[b].cmp(&waited[a]).then(seq[a].cmp(&seq[b])));
    let mut picked: Vec<usize> = order[..wave_size].to_vec();
    // Hard fairness floor: a session skipped this wave would enter the
    // next pick with waited+1; force it in before it crosses the bound.
    if fairness_waves > 0 {
        for &i in &order[wave_size..] {
            if waited[i] + 1 >= fairness_waves as u64 {
                picked.push(i);
            }
        }
    }
    picked.sort_unstable();
    picked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_board_counts_exactly_once() {
        let b = SlotBoard::new();
        assert_eq!(b.in_flight(), 0);
        b.enter();
        b.enter();
        assert_eq!(b.in_flight(), 2);
        // A session staying resident across many waves is still one job.
        b.retire();
        assert_eq!(b.in_flight(), 1);
        b.retire();
        assert_eq!(b.in_flight(), 0);
        assert!(!b.stopped());
        b.raise_stop();
        assert!(b.stopped());
    }

    #[test]
    fn queued_gauge_tracks_worker_queue() {
        let b = SlotBoard::new();
        assert_eq!(b.queued(), 0);
        b.set_queued(7);
        assert_eq!(b.queued(), 7);
        b.set_queued(0);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn unthrottled_wave_schedules_everyone() {
        let waited = [0u64, 3, 1];
        let seq = [0u64, 1, 2];
        assert_eq!(pick_wave(0, 4, &waited, &seq), vec![0, 1, 2]);
        assert_eq!(pick_wave(8, 4, &waited, &seq), vec![0, 1, 2]);
    }

    #[test]
    fn bounded_wave_prefers_longest_waiting_fifo_tiebreak() {
        let waited = [0u64, 2, 2, 0];
        let seq = [0u64, 1, 2, 3];
        // Two slots: both waited=2 sessions win; FIFO among equals.
        assert_eq!(pick_wave(2, 8, &waited, &seq), vec![1, 2]);
        // One slot: the earlier-admitted of the starved pair.
        assert_eq!(pick_wave(1, 8, &waited, &seq), vec![1]);
    }

    #[test]
    fn fairness_bound_force_includes_starved_sessions() {
        // Four sessions all about to cross a fairness bound of 3 waves:
        // a wave_size of 1 must still include every one of them.
        let waited = [2u64, 2, 2, 2];
        let seq = [0u64, 1, 2, 3];
        assert_eq!(pick_wave(1, 3, &waited, &seq), vec![0, 1, 2, 3]);
        // Below the bound the throttle applies.
        let waited = [1u64, 1, 1, 1];
        assert_eq!(pick_wave(1, 3, &waited, &seq), vec![0]);
    }
}
