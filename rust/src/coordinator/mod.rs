//! The serving coordinator: admission, scheduling, batching, routing.
//!
//! Architecture (DESIGN.md §7):
//!
//! ```text
//! client → router → replica worker (owns the Engine, which is !Send:
//!            |        PJRT handles live on one thread)
//!            |        ├─ admission: bounded queue (backpressure)
//!            |        ├─ prefill: FCFS
//!            |        └─ decode: continuous batching — every active
//!            |             session advances one token per engine round,
//!            |             up to `max_batch` sessions interleaved
//!            └─ least-outstanding-requests replica choice
//! ```
//!
//! Requests stream tokens back over a channel as they decode (the TTFT /
//! TPOT split every serving paper reports).

pub mod router;

use crate::config::ServeConfig;
use crate::metrics::PhaseBreakdown;
use crate::model::{Engine, Session};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
}

/// Streaming events for one request.
#[derive(Clone, Debug)]
pub enum Event {
    /// One generated token.
    Token(u64, u32),
    /// Generation finished.
    Done(u64, RequestMetrics),
    /// The request failed (e.g. device OOM for the vLLM baseline).
    Failed(u64, String),
}

/// Per-request serving metrics.
#[derive(Clone, Debug, Default)]
pub struct RequestMetrics {
    /// Prompt length.
    pub prompt_tokens: usize,
    /// Generated tokens.
    pub output_tokens: usize,
    /// Prefill wall-clock (s).
    pub prefill_s: f64,
    /// Time to first token (s).
    pub ttft_s: f64,
    /// Mean time per output token after the first (s).
    pub tpot_s: f64,
    /// Summed decode phase breakdown (includes index-maintenance time).
    pub breakdown: PhaseBreakdown,
    /// Overflow tokens drained out of the linear-scan buffer (indexed, or
    /// dropped under StreamingLLM semantics).
    pub drained_tokens: u64,
    /// Number of drain operations across the request's decode.
    pub drains: u64,
    /// Tokens retired by the indexed-tier eviction policy.
    pub evicted_tokens: u64,
    /// Reclamation epochs completed (generation-based dense-id remaps).
    pub reclaims: u64,
    /// Dense rows physically reclaimed (host memory actually freed).
    pub reclaimed_rows: u64,
    /// Completed maintenance jobs (double-buffered swaps).
    pub maint_swaps: u64,
    /// Mean worker wall-clock per job (the off-thread cost).
    pub maint_swap_s_mean: f64,
    /// Peak maintenance-queue depth observed during the request.
    pub maint_queue_peak: usize,
    /// Tombstoned fraction of the session's indexes at retirement.
    pub tombstone_ratio: f64,
}

struct Job {
    req: Request,
    reply: Sender<Event>,
    submitted: Instant,
}

struct Active {
    job: Job,
    sess: Session,
    produced: Vec<u32>,
    cur: u32,
    prefill_s: f64,
    first_token_at: Option<Instant>,
    decode_bd: PhaseBreakdown,
}

/// Handle to one replica worker (engine thread).
pub struct Replica {
    tx: Sender<Job>,
    outstanding: Arc<AtomicUsize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Replica {
    /// Spawn a replica: the engine is constructed *inside* the worker
    /// thread (PJRT handles are not Send).
    pub fn spawn(cfg: ServeConfig) -> Replica {
        let (tx, rx) = mpsc::channel::<Job>();
        let outstanding = Arc::new(AtomicUsize::new(0));
        let out_clone = outstanding.clone();
        let handle = std::thread::Builder::new()
            .name("replica-worker".into())
            .spawn(move || {
                let engine = match Engine::from_config(cfg.clone()) {
                    Ok(e) => e,
                    Err(e) => {
                        // Drain jobs with failures until the channel closes.
                        while let Ok(job) = rx.recv() {
                            let _ = job
                                .reply
                                .send(Event::Failed(job.req.id, format!("engine init: {e}")));
                            out_clone.fetch_sub(1, Ordering::Relaxed);
                        }
                        return;
                    }
                };
                worker_loop(&engine, &cfg, rx, &out_clone);
            })
            .expect("spawn replica worker");
        Replica { tx, outstanding, handle: Some(handle) }
    }

    /// Submit a request; events stream on the returned receiver.
    pub fn submit(&self, req: Request) -> Receiver<Event> {
        let (reply, events) = mpsc::channel();
        self.outstanding.fetch_add(1, Ordering::Relaxed);
        let job = Job { req, reply, submitted: Instant::now() };
        if self.tx.send(job).is_err() {
            self.outstanding.fetch_sub(1, Ordering::Relaxed);
        }
        events
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::Relaxed)
    }
}

impl Drop for Replica {
    fn drop(&mut self) {
        // Closing the channel stops the worker after the current round.
        let (dummy_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The replica scheduling loop: FCFS prefill + continuous decode batching.
fn worker_loop(
    engine: &Engine,
    cfg: &ServeConfig,
    rx: Receiver<Job>,
    outstanding: &AtomicUsize,
) {
    let mut waiting: VecDeque<Job> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();

    loop {
        // Pull new jobs. Block only when fully idle.
        loop {
            match rx.try_recv() {
                Ok(job) => {
                    if waiting.len() >= cfg.scheduler.max_queue {
                        outstanding.fetch_sub(1, Ordering::Relaxed);
                        let _ = job.reply.send(Event::Failed(
                            job.req.id,
                            "queue full (backpressure)".into(),
                        ));
                    } else {
                        waiting.push_back(job);
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if waiting.is_empty() && active.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        if waiting.is_empty() && active.is_empty() {
            match rx.recv() {
                Ok(job) => waiting.push_back(job),
                Err(_) => return,
            }
        }

        // Admit prefills while there is decode capacity.
        while active.len() < cfg.scheduler.max_batch {
            let Some(job) = waiting.pop_front() else { break };
            let t = Instant::now();
            match admit(engine, &job) {
                Ok(sess) => {
                    let prefill_s = t.elapsed().as_secs_f64();
                    active.push(Active {
                        job,
                        sess,
                        produced: Vec::new(),
                        cur: 0,
                        prefill_s,
                        first_token_at: None,
                        decode_bd: PhaseBreakdown::default(),
                    });
                }
                Err(e) => {
                    outstanding.fetch_sub(1, Ordering::Relaxed);
                    let _ = job.reply.send(Event::Failed(job.req.id, e.to_string()));
                }
            }
        }

        // One decode round: every active session advances one token.
        let mut finished: Vec<usize> = Vec::new();
        for (idx, a) in active.iter_mut().enumerate() {
            let step = if a.produced.is_empty() {
                engine.first_token(&a.sess).map(|t| (t, PhaseBreakdown::default()))
            } else {
                engine.decode_step(&mut a.sess, a.cur).map(|o| (o.token, o.breakdown))
            };
            match step {
                Ok((tok, bd)) => {
                    a.decode_bd.add(&bd);
                    a.produced.push(tok);
                    a.cur = tok;
                    if a.first_token_at.is_none() {
                        a.first_token_at = Some(Instant::now());
                    }
                    let _ = a.job.reply.send(Event::Token(a.job.req.id, tok));
                    if a.produced.len() >= a.job.req.max_tokens {
                        finished.push(idx);
                    }
                }
                Err(e) => {
                    let _ = a.job.reply.send(Event::Failed(a.job.req.id, e.to_string()));
                    finished.push(idx);
                }
            }
        }
        // Retire finished sessions (reverse order keeps indices valid).
        for idx in finished.into_iter().rev() {
            let mut a = active.swap_remove(idx);
            // Quiesce the background maintenance worker so the drain/evict
            // counters below are exact, not racing in-flight jobs.
            a.sess.shutdown_maintenance();
            let ttft = a
                .first_token_at
                .map(|t| t.duration_since(a.job.submitted).as_secs_f64())
                .unwrap_or(0.0);
            let n_out = a.produced.len();
            let decode_total = a.decode_bd.total();
            let maint = a.sess.maint.stats;
            let metrics = RequestMetrics {
                prompt_tokens: a.job.req.prompt.len(),
                output_tokens: n_out,
                prefill_s: a.prefill_s,
                ttft_s: ttft,
                tpot_s: if n_out > 1 { decode_total / (n_out - 1) as f64 } else { 0.0 },
                breakdown: a.decode_bd,
                drained_tokens: a.sess.drained_tokens,
                drains: a.sess.drains,
                evicted_tokens: maint.evicted_tokens,
                reclaims: maint.reclaims,
                reclaimed_rows: maint.reclaimed_rows,
                maint_swaps: maint.swaps,
                maint_swap_s_mean: maint.mean_swap_s(),
                maint_queue_peak: maint.queue_peak,
                tombstone_ratio: a.sess.tombstone_ratio(),
            };
            // Decrement BEFORE the Done event so a client that reads Done
            // observes the freed capacity (load-balancing correctness).
            outstanding.fetch_sub(1, Ordering::Relaxed);
            let _ = a.job.reply.send(Event::Done(a.job.req.id, metrics));
        }
    }
}

/// Admission: enforce device-memory limits for the vLLM-like baseline
/// (full KV on device ⇒ OOM past the budget), then prefill.
fn admit(engine: &Engine, job: &Job) -> Result<Session> {
    if engine.cfg.method == crate::config::Method::VllmLike {
        if let Some(hw) = crate::hw::HwProfile::by_name(&engine.cfg.hw) {
            let spec = engine.spec();
            let geom = crate::hw::ModelGeometry {
                layers: spec.layers,
                q_heads: spec.q_heads,
                kv_heads: spec.kv_heads,
                head_dim: spec.head_dim,
                elt_size: 2,
            };
            // Full-model weights claim their share of device memory first.
            let weight_bytes = engine.weights.param_count() * 2;
            let budget = hw.device_mem_bytes.saturating_sub(weight_bytes);
            let need = geom.kv_bytes(job.req.prompt.len() + job.req.max_tokens);
            anyhow::ensure!(
                need <= budget,
                "device OOM: KV needs {:.1} GiB, {:.1} GiB free",
                need as f64 / (1u64 << 30) as f64,
                budget as f64 / (1u64 << 30) as f64
            );
        }
    }
    engine.prefill(&job.req.prompt)
}

/// Collect a full generation from an event stream (blocking helper).
pub fn collect(events: &Receiver<Event>) -> Result<(Vec<u32>, RequestMetrics)> {
    let mut tokens = Vec::new();
    loop {
        match events.recv() {
            Ok(Event::Token(_, t)) => tokens.push(t),
            Ok(Event::Done(_, m)) => return Ok((tokens, m)),
            Ok(Event::Failed(_, e)) => anyhow::bail!("request failed: {e}"),
            Err(_) => anyhow::bail!("replica dropped the request"),
        }
    }
}
