//! The serving coordinator: admission, scheduling, batching, routing.
//!
//! Architecture (DESIGN.md §7):
//!
//! ```text
//! client → router → replica worker (owns the Engine, which is !Send:
//!            |        PJRT handles live on one thread)
//!            |        ├─ admission: bounded queue (backpressure)
//!            |        ├─ prefill: FCFS
//!            |        └─ decode: continuous batching — each loop turn is
//!            |             one WAVE: a fairness-bounded pick of resident
//!            |             sessions advances one token in a single fused
//!            |             engine dispatch (`Engine::decode_wave`), with
//!            |             admit/join mid-stream and retire on completion
//!            └─ least-outstanding-requests replica choice
//! ```
//!
//! Requests stream tokens back over a channel as they decode (the TTFT /
//! TPOT split every serving paper reports). The wave loop's headline
//! invariant: batched decode is **bit-identical** to stepping each
//! session alone (`tests/scheduler.rs` locks this in across index
//! families and quant modes).

pub mod router;
pub mod scheduler;

use crate::config::ServeConfig;
use crate::metrics::{PhaseBreakdown, WaveTelemetry};
use crate::model::{Engine, Session, WaveItem};
use crate::store::SessionCache;
use crate::telemetry::{self, SpanAcc};
use crate::util::contain::contained;
use crate::util::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use crate::util::sync::{Arc, Mutex, PoisonError};
use anyhow::Result;
use scheduler::{pick_wave, SlotBoard};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// What a request wants done with its session (the multi-turn lifecycle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionMode {
    /// First turn: prefill, then retain the session under `session_id`.
    Open,
    /// Later turn: resume the retained session (resident or parked on
    /// disk) and extend it by decoding the new prompt tokens — **no
    /// prefill and no index rebuild**.
    Continue,
    /// Drop the session from RAM and disk.
    Close,
}

impl SessionMode {
    pub fn label(&self) -> &'static str {
        match self {
            SessionMode::Open => "open",
            SessionMode::Continue => "continue",
            SessionMode::Close => "close",
        }
    }

    pub fn parse(s: &str) -> Option<SessionMode> {
        [SessionMode::Open, SessionMode::Continue, SessionMode::Close]
            .into_iter()
            .find(|m| m.label().eq_ignore_ascii_case(s))
    }
}

/// Session directive riding a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionSpec {
    pub session_id: u64,
    pub mode: SessionMode,
}

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
    /// Multi-turn session directive; `None` = one-shot (the session is
    /// dropped when the request finishes, the pre-registry behaviour).
    pub session: Option<SessionSpec>,
}

/// Streaming events for one request.
#[derive(Clone, Debug)]
pub enum Event {
    /// One generated token.
    Token(u64, u32),
    /// Generation finished.
    Done(u64, RequestMetrics),
    /// The request failed (e.g. device OOM for the vLLM baseline).
    Failed(u64, String),
}

/// Per-request serving metrics.
#[derive(Clone, Debug, Default)]
pub struct RequestMetrics {
    /// Prompt length.
    pub prompt_tokens: usize,
    /// Generated tokens.
    pub output_tokens: usize,
    /// Prefill wall-clock (s).
    pub prefill_s: f64,
    /// Time to first token (s).
    pub ttft_s: f64,
    /// Mean time per output token after the first (s).
    pub tpot_s: f64,
    /// Summed decode phase breakdown (includes index-maintenance time).
    pub breakdown: PhaseBreakdown,
    /// Overflow tokens drained out of the linear-scan buffer (indexed, or
    /// dropped under StreamingLLM semantics).
    pub drained_tokens: u64,
    /// Number of drain operations across the request's decode.
    pub drains: u64,
    /// Tokens retired by the indexed-tier eviction policy.
    pub evicted_tokens: u64,
    /// Reclamation epochs completed (generation-based dense-id remaps).
    pub reclaims: u64,
    /// Dense rows physically reclaimed (host memory actually freed).
    pub reclaimed_rows: u64,
    /// Completed maintenance jobs (double-buffered swaps).
    pub maint_swaps: u64,
    /// Mean worker wall-clock per job (the off-thread cost).
    pub maint_swap_s_mean: f64,
    /// Peak maintenance-queue depth observed during the request.
    pub maint_queue_peak: usize,
    /// Tombstoned fraction of the session's indexes at retirement.
    pub tombstone_ratio: f64,
    /// True when this turn resumed its session from a disk snapshot
    /// (parked → resumed); false for resident hits and fresh prefills.
    pub resumed_from_disk: bool,
    /// Wall-clock of the snapshot restore for this turn (0 otherwise).
    pub resume_s: f64,
    /// On-disk snapshot bytes this turn was restored from (0 otherwise).
    pub snapshot_bytes: u64,
    /// Cumulative sessions this replica has parked to disk.
    pub session_parks: u64,
    /// Cumulative sessions this replica has resumed from disk.
    pub session_resumes: u64,
    /// Peak admission-queue depth observed while this request was active.
    pub queue_depth_peak: usize,
    /// Mean sessions scheduled per wave while this request was resident
    /// (replica wave occupancy, the batching win the scheduler realizes).
    pub wave_occupancy_mean: f64,
    /// Largest inter-token gap this request saw, in waves (1 = scheduled
    /// every wave; bounded by `scheduler.fairness_waves` under saturation).
    pub max_gap_waves: u64,
    /// Replica-wide token throughput (tokens/s across ALL sessions)
    /// over this request's residency window.
    pub replica_tokens_per_s: f64,
    /// Fraction of the session's query heads on the streaming tier
    /// (sink+window, index-free) at retirement.
    pub streaming_head_fraction: f64,
    /// Host index bytes released by streaming-head specialization over
    /// the session's lifetime (0 when the policy layer is off).
    pub index_bytes_avoided: u64,
    /// Cumulative sessions this replica recovered from durable snapshots
    /// at boot scan (crash recovery provenance, PR 9).
    pub sessions_recovered: u64,
    /// Cumulative snapshots this replica quarantined (failed restores
    /// moved aside rather than deleted).
    pub snapshots_quarantined: u64,
    /// Per-request span tree (phase counts + wall seconds), all-zero
    /// unless the `serving.telemetry.spans` knob is on.
    pub spans: SpanAcc,
}

struct Job {
    req: Request,
    reply: Sender<Event>,
    submitted: Instant,
}

struct Active {
    job: Job,
    sess: Session,
    produced: Vec<u32>,
    cur: u32,
    prefill_s: f64,
    first_token_at: Option<Instant>,
    decode_bd: PhaseBreakdown,
    /// Session-resume provenance for the done event.
    resumed_from_disk: bool,
    resume_s: f64,
    snapshot_bytes: u64,
    /// A failed step poisons the session: it is never retained.
    failed: bool,
    /// Admission sequence number (FIFO tiebreak in the wave pick).
    seq: u64,
    /// Consecutive waves this session has sat eligible-but-unscheduled.
    waited: u64,
    /// Largest inter-token gap seen, in waves.
    max_gap_waves: u64,
    /// Peak admission-queue depth observed during residency.
    queue_peak: usize,
    /// Telemetry snapshots at admission, differenced at retirement.
    admitted_at: Instant,
    waves_at_admit: u64,
    sched_at_admit: u64,
    tokens_at_admit: u64,
}

/// Admission outcome: the decode-ready session plus, for continuations,
/// the first generated token (the decode of the last prompt token).
struct Admitted {
    sess: Session,
    first: Option<(u32, PhaseBreakdown)>,
    resumed_from_disk: bool,
    resume_s: f64,
    snapshot_bytes: u64,
}

/// One generation of a replica worker: channel, slot board, thread.
/// Replaced wholesale on a supervised respawn.
struct WorkerGen {
    tx: Sender<Job>,
    /// The slot protocol: exactly-once in-flight accounting, the
    /// queue-depth gauge, and the stop flag ([`scheduler::SlotBoard`];
    /// loom-modeled in `tests/loom_models.rs`).
    board: Arc<SlotBoard>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl WorkerGen {
    fn spawn(cfg: ServeConfig) -> WorkerGen {
        let (tx, rx) = mpsc::channel::<Job>();
        let board = Arc::new(SlotBoard::new());
        let board_clone = board.clone();
        let handle = std::thread::Builder::new()
            .name("replica-worker".into())
            .spawn(move || {
                let engine = match Engine::from_config(cfg.clone()) {
                    Ok(e) => e,
                    Err(e) => {
                        // Drain jobs with failures until the channel closes.
                        // Nothing was published for these jobs, so retire
                        // straight away — before the terminal event, as on
                        // every other path.
                        while let Ok(job) = rx.recv() {
                            board_clone.retire();
                            let _ = job
                                .reply
                                .send(Event::Failed(job.req.id, format!("engine init: {e}")));
                        }
                        return;
                    }
                };
                worker_loop(&engine, &cfg, rx, &board_clone);
            })
            // A failed OS-thread spawn must not panic the caller: with
            // `handle` empty the closure (and `rx`) is dropped, so every
            // submit fails over the closed channel into an explicit
            // Event::Failed("replica worker is gone").
            .ok();
        WorkerGen { tx, board, handle }
    }

    /// Whether the worker thread has exited. A worker only returns when
    /// its channel closes (orderly shutdown) — any other exit is a crash
    /// (a panic that escaped the per-session containment).
    fn dead(&self) -> bool {
        self.handle.as_ref().map(|h| h.is_finished()).unwrap_or(true)
    }

    fn shutdown(&mut self) {
        // Refuse new submissions, then close the channel: the worker
        // drains its resident set and exits after the current wave.
        self.board.raise_stop();
        let (dummy_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Handle to one **supervised** replica worker (engine thread).
///
/// If the worker thread dies — a panic that escaped per-session
/// containment — the next `submit` respawns it, up to
/// `serving.max_respawns` times. Crash semantics: requests in flight at
/// the crash fail (their reply channels disconnect, which `collect`
/// reports cleanly), but **parked sessions survive** — the respawned
/// worker's `SessionCache` boot-scans the same configured `spill_dir`
/// and re-registers every durable snapshot, so `continue` turns keep
/// working across the crash. The respawn allocates a fresh slot board:
/// the dead generation's in-flight count dies with it.
pub struct Replica {
    cfg: ServeConfig,
    gen: Mutex<WorkerGen>,
    /// Respawns consumed (`<= cfg.serving.max_respawns`).
    respawns: Mutex<u32>,
}

impl Replica {
    /// Spawn a replica: the engine is constructed *inside* the worker
    /// thread (PJRT handles are not Send).
    pub fn spawn(cfg: ServeConfig) -> Replica {
        let gen = Mutex::new(WorkerGen::spawn(cfg.clone()));
        Replica { cfg, gen, respawns: Mutex::new(0) }
    }

    fn lock_gen(&self) -> crate::util::sync::MutexGuard<'_, WorkerGen> {
        // Poison recovery: a panicking submitter cannot brick the replica
        // handle — the guarded state is a plain handle triple, valid at
        // every step, so the poisoned payload is safe to adopt.
        self.gen.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Supervision: if the current worker generation crashed, respawn it
    /// (bounded by `serving.max_respawns`). Returns false when the
    /// replica is dead for good.
    fn ensure_alive(&self, gen: &mut WorkerGen) -> bool {
        if gen.board.stopped() {
            return false; // orderly shutdown, not a crash
        }
        if !gen.dead() {
            return true;
        }
        let mut used = self.respawns.lock().unwrap_or_else(PoisonError::into_inner);
        if *used >= self.cfg.serving.max_respawns {
            return false;
        }
        *used += 1;
        telemetry::registry().counter("coordinator.respawns_total").inc();
        // Record the respawn, THEN dump the flight recorder: the tail of
        // the dumped JSONL is the event history leading up to the crash,
        // closed by this respawn marker. The dump lands next to the
        // durable snapshots the new worker will boot-scan.
        telemetry::flightrec(
            "respawn",
            format!(
                "replica worker died; respawn {} of {}",
                *used, self.cfg.serving.max_respawns
            ),
        );
        let dir = if self.cfg.serving.session_cache.spill_dir.is_empty() {
            std::env::temp_dir()
        } else {
            std::path::PathBuf::from(&self.cfg.serving.session_cache.spill_dir)
        };
        let _ = telemetry::flightrec_dump(&dir);
        // Reap the dead generation (join is immediate: the thread has
        // exited), then replace it wholesale. Jobs queued to the dead
        // worker fail by disconnect; parked sessions come back via the
        // new worker's spill-dir boot scan. The fresh generation owns a
        // fresh `WaveTelemetry` AND a fresh resident set, so admission
        // snapshots can never straddle a respawn (see the retirement
        // deltas below, which saturate anyway as a second line of
        // defense).
        if let Some(h) = gen.handle.take() {
            let _ = h.join();
        }
        *gen = WorkerGen::spawn(self.cfg.clone());
        true
    }

    /// Submit a request; events stream on the returned receiver. If the
    /// worker is gone (orderly shutdown, or crashed with the respawn
    /// budget exhausted) the receiver carries an explicit
    /// [`Event::Failed`] — not a bare disconnect that `collect` would
    /// report as "replica dropped the request" without ever seeing a
    /// failure event.
    pub fn submit(&self, req: Request) -> Receiver<Event> {
        let (reply, events) = mpsc::channel();
        let mut gen = self.lock_gen();
        if !self.ensure_alive(&mut gen) {
            let _ = reply.send(Event::Failed(req.id, "replica worker is gone".into()));
            return events;
        }
        // Enter the board BEFORE the send so the job is never in flight
        // yet invisible to `outstanding()`.
        gen.board.enter();
        let job = Job { req, reply, submitted: Instant::now() };
        if let Err(send_err) = gen.tx.send(job) {
            gen.board.retire();
            let job = send_err.0;
            let _ = job
                .reply
                .send(Event::Failed(job.req.id, "replica worker is gone".into()));
        }
        events
    }

    /// Submitted-but-unfinished requests, counted exactly once no matter
    /// how many waves a session stays resident (the slot board's
    /// enter-once/retire-once contract).
    pub fn outstanding(&self) -> usize {
        self.lock_gen().board.in_flight()
    }

    /// Jobs parked in the worker's admission queue (the backlog behind
    /// the resident set).
    pub fn queue_depth(&self) -> usize {
        self.lock_gen().board.queued()
    }

    /// Worker respawns consumed so far (supervision telemetry).
    pub fn respawn_count(&self) -> u32 {
        *self.respawns.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

impl Drop for Replica {
    fn drop(&mut self) {
        self.lock_gen().shutdown();
    }
}

/// Disjoint mutable borrows of `active` at strictly increasing indices
/// (the wave's scheduled subset, handed to `Engine::decode_wave`).
fn select_mut<'a>(active: &'a mut [Active], idxs: &[usize]) -> Vec<&'a mut Active> {
    let mut out = Vec::with_capacity(idxs.len());
    let mut rest = active;
    let mut base = 0usize;
    for &i in idxs {
        let (_, tail) = rest.split_at_mut(i - base);
        let Some((item, tail)) = tail.split_first_mut() else { break };
        out.push(item);
        rest = tail;
        base = i + 1;
    }
    out
}

/// Cached process-registry handles for the worker loop: one name lookup
/// per worker generation, plain atomic updates per wave after that.
struct WorkerTele {
    queue_depth: Arc<telemetry::Gauge>,
    wave_occupancy: Arc<telemetry::Gauge>,
    waves: Arc<telemetry::Counter>,
    admitted: Arc<telemetry::Counter>,
    retired: Arc<telemetry::Counter>,
    failed: Arc<telemetry::Counter>,
    sched_gap_s: Arc<telemetry::Histogram>,
    resident: Arc<telemetry::Gauge>,
    parked: Arc<telemetry::Gauge>,
    disk_bytes: Arc<telemetry::Gauge>,
    recovered: Arc<telemetry::Gauge>,
    quarantined: Arc<telemetry::Gauge>,
    tombstone_ratio: Arc<telemetry::Gauge>,
}

impl WorkerTele {
    fn new() -> WorkerTele {
        let reg = telemetry::registry();
        WorkerTele {
            queue_depth: reg.gauge("coordinator.queue_depth"),
            wave_occupancy: reg.gauge("coordinator.wave_occupancy"),
            waves: reg.counter("coordinator.waves_total"),
            admitted: reg.counter("coordinator.admitted_total"),
            retired: reg.counter("coordinator.retired_total"),
            failed: reg.counter("coordinator.failed_total"),
            sched_gap_s: reg.histogram("coordinator.sched_gap_s"),
            resident: reg.gauge("store.resident_sessions"),
            parked: reg.gauge("store.parked_sessions"),
            disk_bytes: reg.gauge("store.disk_bytes"),
            recovered: reg.gauge("store.sessions_recovered"),
            quarantined: reg.gauge("store.snapshots_quarantined"),
            tombstone_ratio: reg.gauge("maintenance.tombstone_ratio"),
        }
    }

    /// Refresh the store-family gauges from the replica's registry state
    /// (called after every operation that can move a session between
    /// tiers: admission resume, retirement retention, close).
    fn sync_store(&self, sessions: &SessionCache) {
        self.resident.set_u64(sessions.resident_count() as u64);
        self.parked.set_u64(sessions.parked_count() as u64);
        self.disk_bytes.set_u64(sessions.disk_bytes());
        self.recovered.set_u64(sessions.stats.recovered);
        self.quarantined.set_u64(sessions.stats.quarantines);
    }
}

/// Apply one decode-step outcome to an active session: stream the token
/// (or the failure) and mark the session finished when its budget is met.
fn apply_step(
    a: &mut Active,
    step: Result<(u32, PhaseBreakdown)>,
    wave: &mut WaveTelemetry,
    tele: &WorkerTele,
    finished: &mut Vec<usize>,
    idx: usize,
) {
    match step {
        Ok((tok, bd)) => {
            a.decode_bd.add(&bd);
            a.produced.push(tok);
            a.cur = tok;
            if a.first_token_at.is_none() {
                a.first_token_at = Some(Instant::now());
            }
            let _ = a.job.reply.send(Event::Token(a.job.req.id, tok));
            wave.tokens_emitted += 1;
            if a.produced.len() >= a.job.req.max_tokens {
                finished.push(idx);
            }
        }
        Err(e) => {
            // `{:#}` keeps the full context chain: "parking LRU victim
            // session N: ... (backpressure)" must survive to the client,
            // not just the outermost context line.
            let _ = a.job.reply.send(Event::Failed(a.job.req.id, format!("{e:#}")));
            tele.failed.inc();
            telemetry::flightrec("request.fail", format!("req={}: {e:#}", a.job.req.id));
            a.failed = true;
            finished.push(idx);
        }
    }
}

/// The replica scheduling loop: FCFS prefill admission + wave-style
/// continuous decode batching + the per-replica session registry
/// (open/continue/close).
///
/// Each loop turn is one **wave**: intake new jobs, admit up to
/// `scheduler.max_batch` resident sessions, pick a fairness-bounded
/// subset of up to `scheduler.wave_size` of them
/// ([`scheduler::pick_wave`]), then advance every picked session one
/// token in a single fused dispatch ([`Engine::decode_wave`]) —
/// candidate scoring and host attention batched across sessions,
/// bit-identical to stepping each session alone.
fn worker_loop(engine: &Engine, cfg: &ServeConfig, rx: Receiver<Job>, board: &SlotBoard) {
    let mut waiting: VecDeque<Job> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    // The session registry: finished sessions stay resident up to the RAM
    // budget, LRU-park to disk through the snapshot format, and resume on
    // the next turn. Owned by this thread — sessions never cross replicas
    // (the router pins session ids).
    let mut sessions = SessionCache::new(cfg.serving.session_cache.clone());
    // Replica-wide wave telemetry + admission sequence numbers.
    let mut wave = WaveTelemetry::default();
    let mut next_seq = 0u64;
    let tele = WorkerTele::new();
    tele.sync_store(&sessions);
    if sessions.stats.recovered > 0 {
        telemetry::flightrec(
            "store.recovered",
            format!("boot scan re-registered {} parked session(s)", sessions.stats.recovered),
        );
    }
    // End of the previous wave's dispatch: the gap until the next wave
    // starts is scheduler overhead (intake + admission + pick), the
    // "wave scheduling gap" the trace file makes visible.
    let mut wave_ended_at: Option<Instant> = None;

    loop {
        // Supervision kill switch (panic-only site, test builds only): a
        // Panic action here kills the worker thread mid-service, which is
        // how tests exercise the router-side respawn + durable-recovery
        // path. Error actions are ignored — there is no job to fail here.
        let _ = crate::util::failpoint::trigger("worker.step");

        // Pull new jobs. Block only when fully idle.
        loop {
            match rx.try_recv() {
                Ok(job) => {
                    if waiting.len() >= cfg.scheduler.max_queue {
                        board.retire();
                        let _ = job.reply.send(Event::Failed(
                            job.req.id,
                            "queue full (backpressure)".into(),
                        ));
                    } else {
                        waiting.push_back(job);
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if waiting.is_empty() && active.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        if waiting.is_empty() && active.is_empty() {
            match rx.recv() {
                Ok(job) => waiting.push_back(job),
                Err(_) => return,
            }
        }
        board.set_queued(waiting.len());
        tele.queue_depth.set_u64(waiting.len() as u64);

        // Admit work while there is resident capacity. Close verbs are
        // registry operations, not decodes: handled inline.
        while active.len() < cfg.scheduler.max_batch {
            let Some(job) = waiting.pop_front() else { break };
            // A session verb whose PREVIOUS turn is still decoding must
            // wait for it to retire (the registry only holds finished
            // turns): defer it rather than mis-report "unknown session"
            // to a client that pipelined its turns. Admission is FCFS, so
            // stop admitting behind it; the waves below always make
            // progress, so the deferral cannot deadlock and cannot stall
            // the sessions already resident.
            if let Some(spec) = job.req.session {
                let busy = active.iter().any(|a| {
                    a.job.req.session.map(|s| s.session_id == spec.session_id).unwrap_or(false)
                });
                if busy {
                    waiting.push_front(job);
                    break;
                }
            }
            if let Some(spec @ SessionSpec { mode: SessionMode::Close, .. }) = job.req.session {
                let known = sessions.close(spec.session_id);
                tele.sync_store(&sessions);
                // Registry op done: free the slot before the client hears
                // the outcome (a client acting on Done must observe the
                // freed capacity — the exactly-once accounting contract).
                board.retire();
                if known {
                    let metrics = RequestMetrics {
                        session_parks: sessions.stats.parks,
                        session_resumes: sessions.stats.resumes,
                        ..RequestMetrics::default()
                    };
                    let _ = job.reply.send(Event::Done(job.req.id, metrics));
                } else {
                    let _ = job.reply.send(Event::Failed(
                        job.req.id,
                        format!("unknown session {}", spec.session_id),
                    ));
                }
                continue;
            }
            let t = Instant::now();
            // Containment: a panic during admission (prefill, resume,
            // decode-extend) fails THIS request — the worker, its resident
            // sessions, and its registry all survive. The admitted-or-not
            // state is unambiguous: a panicking admission never returned a
            // session, so there is nothing half-built to poison.
            match contained("session admission", || admit(engine, &mut sessions, &job)) {
                Ok(adm) => {
                    // Continuations skip prefill entirely: their admission
                    // cost is the resume (reported as resume_s) plus the
                    // decode-extend steps (already summed into the decode
                    // breakdown below) — reporting the wall time here too
                    // would double-count it as a phantom prefill.
                    let prefill_s =
                        if adm.first.is_some() { 0.0 } else { t.elapsed().as_secs_f64() };
                    let mut a = Active {
                        job,
                        sess: adm.sess,
                        produced: Vec::new(),
                        cur: 0,
                        prefill_s,
                        first_token_at: None,
                        decode_bd: PhaseBreakdown::default(),
                        resumed_from_disk: adm.resumed_from_disk,
                        resume_s: adm.resume_s,
                        snapshot_bytes: adm.snapshot_bytes,
                        failed: false,
                        seq: next_seq,
                        waited: 0,
                        max_gap_waves: 0,
                        queue_peak: waiting.len(),
                        admitted_at: Instant::now(),
                        waves_at_admit: wave.waves,
                        sched_at_admit: wave.scheduled_total,
                        tokens_at_admit: wave.tokens_emitted,
                    };
                    next_seq += 1;
                    tele.admitted.inc();
                    tele.sync_store(&sessions);
                    telemetry::flightrec(
                        "admit",
                        format!(
                            "req={} mode={} prompt={} max_tokens={}",
                            a.job.req.id,
                            a.job.req.session.map(|s| s.mode.label()).unwrap_or("oneshot"),
                            a.job.req.prompt.len(),
                            a.job.req.max_tokens
                        ),
                    );
                    // A continuation already decoded its first token (the
                    // last prompt token's decode step). With max_tokens=0
                    // the token is discarded un-emitted — the KV grew
                    // (that is what the turn asked for) but the client
                    // gets zero tokens, same as a fresh max_tokens=0.
                    if let Some((tok, bd)) = adm.first {
                        a.decode_bd.add(&bd);
                        if a.job.req.max_tokens > 0 {
                            a.produced.push(tok);
                            a.cur = tok;
                            a.first_token_at = Some(Instant::now());
                            let _ = a.job.reply.send(Event::Token(a.job.req.id, tok));
                            wave.tokens_emitted += 1;
                        }
                    }
                    active.push(a);
                }
                Err(e) => {
                    board.retire();
                    tele.failed.inc();
                    telemetry::flightrec(
                        "admit.fail",
                        format!("req={}: {e:#}", job.req.id),
                    );
                    let _ = job.reply.send(Event::Failed(job.req.id, format!("{e:#}")));
                }
            }
        }
        board.set_queued(waiting.len());
        tele.queue_depth.set_u64(waiting.len() as u64);

        // Pre-pass: already-satisfied sessions (continuation whose first
        // token filled the budget, or max_tokens == 0) retire without
        // stepping; everyone else is eligible for this wave.
        let mut finished: Vec<usize> = Vec::new();
        let mut eligible: Vec<usize> = Vec::new();
        for (idx, a) in active.iter_mut().enumerate() {
            a.queue_peak = a.queue_peak.max(waiting.len());
            if a.produced.len() >= a.job.req.max_tokens {
                finished.push(idx);
            } else {
                eligible.push(idx);
            }
        }

        // Wave pick + fused decode step.
        if !eligible.is_empty() {
            let waited: Vec<u64> = eligible.iter().map(|&i| active[i].waited).collect();
            let seqs: Vec<u64> = eligible.iter().map(|&i| active[i].seq).collect();
            let picked: Vec<usize> =
                pick_wave(cfg.scheduler.wave_size, cfg.scheduler.fairness_waves, &waited, &seqs)
                    .into_iter()
                    .map(|j| eligible[j])
                    .collect();
            wave.waves += 1;
            wave.scheduled_total += picked.len() as u64;
            tele.waves.inc();
            tele.wave_occupancy.set(picked.len() as f64);
            // Scheduling gap: time between the previous wave's dispatch
            // finishing and this one starting (intake/admission overhead).
            if let Some(prev) = wave_ended_at {
                let gap = prev.elapsed().as_secs_f64();
                tele.sched_gap_s.record(gap);
                telemetry::trace_emit("wave_gap", prev, gap, 0);
            }
            // Cadence accounting: a scheduled session's inter-token gap is
            // its skipped waves plus this one; a skipped session ages.
            let mut picked_set = vec![false; active.len()];
            for &i in &picked {
                picked_set[i] = true;
            }
            for &i in &eligible {
                let a = &mut active[i];
                if picked_set[i] {
                    a.max_gap_waves = a.max_gap_waves.max(a.waited + 1);
                    a.waited = 0;
                } else {
                    a.waited += 1;
                }
            }
            // First-token steps (fresh prefills) are a bare lm_head over
            // the prefill activations — no KV append, nothing to fuse.
            let (firsts, steps): (Vec<usize>, Vec<usize>) =
                picked.iter().copied().partition(|&i| active[i].produced.is_empty());
            for i in firsts {
                let a = &mut active[i];
                let step = contained("first-token step", || engine.first_token(&a.sess))
                    .map(|t| (t, PhaseBreakdown::default()));
                apply_step(a, step, &mut wave, &tele, &mut finished, i);
            }
            // The fused wave step: every remaining picked session advances
            // one token in a single multi-session engine dispatch. The
            // engine contains per-session panics itself (the panicking
            // slot fails, survivors' tokens stay bit-identical); this
            // outer wrap is the backstop for a panic in the fused/shared
            // phases, where no per-slot attribution exists — the whole
            // wave fails, every picked session is poisoned-and-failed,
            // and the worker keeps serving everything else.
            if !steps.is_empty() {
                let mut selected = select_mut(&mut active, &steps);
                let mut items: Vec<WaveItem> = selected
                    .iter_mut()
                    .map(|a| WaveItem { sess: &mut a.sess, token: a.cur })
                    .collect();
                let results = match contained("fused wave step", || Ok(engine.decode_wave(&mut items)))
                {
                    Ok(r) => r,
                    Err(e) => {
                        let msg = format!("{e:#}");
                        (0..items.len()).map(|_| Err(anyhow::anyhow!("{msg}"))).collect()
                    }
                };
                drop(items);
                for ((a, res), &i) in selected.into_iter().zip(results).zip(steps.iter()) {
                    apply_step(
                        a,
                        res.map(|o| (o.token, o.breakdown)),
                        &mut wave,
                        &tele,
                        &mut finished,
                        i,
                    );
                }
            }
            wave_ended_at = Some(Instant::now());
        }

        // Retire finished sessions (reverse order keeps indices valid).
        finished.sort_unstable();
        for idx in finished.into_iter().rev() {
            let mut a = active.swap_remove(idx);
            // Quiesce the background maintenance worker so the drain/evict
            // counters below are exact, not racing in-flight jobs (and so
            // a retained session snapshots replay-free).
            a.sess.shutdown_maintenance();
            let ttft = a
                .first_token_at
                .map(|t| t.duration_since(a.job.submitted).as_secs_f64())
                .unwrap_or(0.0);
            let n_out = a.produced.len();
            let decode_total = a.decode_bd.total();
            let maint = a.sess.maint.stats;
            // Per-request span tree: everything recorded since this
            // turn's admission (prefill or restore + decode). Taking it
            // here (rather than copying) resets the accumulator for the
            // session's NEXT turn, so retained sessions report per-turn
            // spans, not lifetime ones.
            let spans = std::mem::take(&mut a.sess.spans);
            // Wave telemetry deltas over this request's residency window.
            // Saturating on purpose (satellite of ISSUE 10): admission
            // snapshots and the `wave` counters are both generation-local
            // — a respawned worker starts BOTH at zero, so a snapshot can
            // never legitimately exceed the live counter — but a
            // wraparound/ordering bug must clamp to 0, not produce a
            // negative-garbage occupancy or throughput.
            let waves_delta = wave.waves.saturating_sub(a.waves_at_admit);
            let sched_delta = wave.scheduled_total.saturating_sub(a.sched_at_admit);
            let tokens_delta = wave.tokens_emitted.saturating_sub(a.tokens_at_admit);
            let resident_s = a.admitted_at.elapsed().as_secs_f64();
            let mut metrics = RequestMetrics {
                prompt_tokens: a.job.req.prompt.len(),
                output_tokens: n_out,
                prefill_s: a.prefill_s,
                ttft_s: ttft,
                tpot_s: if n_out > 1 { decode_total / (n_out - 1) as f64 } else { 0.0 },
                breakdown: a.decode_bd,
                drained_tokens: a.sess.drained_tokens,
                drains: a.sess.drains,
                evicted_tokens: maint.evicted_tokens,
                reclaims: maint.reclaims,
                reclaimed_rows: maint.reclaimed_rows,
                maint_swaps: maint.swaps,
                maint_swap_s_mean: maint.mean_swap_s(),
                maint_queue_peak: maint.queue_peak,
                tombstone_ratio: a.sess.tombstone_ratio(),
                resumed_from_disk: a.resumed_from_disk,
                resume_s: a.resume_s,
                snapshot_bytes: a.snapshot_bytes,
                session_parks: sessions.stats.parks,
                session_resumes: sessions.stats.resumes,
                queue_depth_peak: a.queue_peak,
                wave_occupancy_mean: if waves_delta > 0 {
                    sched_delta as f64 / waves_delta as f64
                } else {
                    0.0
                },
                max_gap_waves: a.max_gap_waves,
                replica_tokens_per_s: if resident_s > 0.0 {
                    tokens_delta as f64 / resident_s
                } else {
                    0.0
                },
                streaming_head_fraction: a.sess.streaming_fraction(),
                index_bytes_avoided: a.sess.index_bytes_avoided,
                sessions_recovered: sessions.stats.recovered,
                snapshots_quarantined: sessions.stats.quarantines,
                spans,
            };
            let tombstone_ratio = metrics.tombstone_ratio;
            // Session-tracked turns retain their session for the next one
            // (a failed step poisons it — never retain half-decoded
            // state). Retention may LRU-park colder sessions to disk; if
            // the disk budget is exhausted the registry refuses, and that
            // backpressure surfaces as this request's failure.
            let retain = if a.failed { None } else { a.job.req.session };
            let event = match retain {
                // Containment: retention may LRU-park victims through the
                // snapshot codec — a panic there fails this request (its
                // session is dropped, never half-registered) while the
                // registry and every other resident session survive.
                Some(spec) => {
                    match contained("session retention", || {
                        sessions.insert(engine, spec.session_id, a.sess)
                    }) {
                        Ok(()) => {
                            metrics.session_parks = sessions.stats.parks;
                            metrics.session_resumes = sessions.stats.resumes;
                            Event::Done(a.job.req.id, metrics)
                        }
                        Err(e) => Event::Failed(a.job.req.id, format!("{e:#}")),
                    }
                }
                None => Event::Done(a.job.req.id, metrics),
            };
            tele.retired.inc();
            tele.sync_store(&sessions);
            tele.tombstone_ratio.set(tombstone_ratio);
            telemetry::flightrec(
                "retire",
                format!(
                    "req={} tokens={} failed={}",
                    a.job.req.id,
                    n_out,
                    a.failed
                ),
            );
            // Retire AFTER the session's results are published (tokens
            // streamed, registry updated) and BEFORE the client hears the
            // terminal event, so a client acting on Done observes the
            // freed capacity (load-balancing + exactly-once accounting).
            board.retire();
            let _ = a.job.reply.send(event);
        }
    }
}

/// Admission. Fresh requests (and `open` turns) enforce the vLLM-like
/// device-memory limit then prefill; `continue` turns resume the retained
/// session — resident or parked — and extend it by decoding the new
/// prompt tokens, skipping prefill entirely.
fn admit(engine: &Engine, sessions: &mut SessionCache, job: &Job) -> Result<Admitted> {
    if let Some(SessionSpec { session_id, mode: SessionMode::Continue }) = job.req.session {
        anyhow::ensure!(!job.req.prompt.is_empty(), "empty prompt");
        let resumed = sessions
            .take(engine, session_id)?
            .ok_or_else(|| anyhow::anyhow!("unknown session {session_id}"))?;
        let mut sess = resumed.sess;
        // The vLLM-like device budget covers the CUMULATIVE session
        // length: a session grown turn by turn must OOM exactly where a
        // fresh request of the same total length would. On rejection the
        // session goes back into the registry — the turn failed, the
        // session did not.
        if let Err(e) =
            vllm_device_check(engine, sess.len + job.req.prompt.len() + job.req.max_tokens)
        {
            let _ = sessions.insert(engine, session_id, sess);
            return Err(e);
        }
        // Decode-extend: each new prompt token is one decode step over the
        // resumed KV + indexes; the last step's output is the turn's first
        // generated token. Zero prefill, zero index rebuild.
        let mut bd = PhaseBreakdown::default();
        let mut first = 0u32;
        for &tok in &job.req.prompt {
            let out = engine.decode_step(&mut sess, tok)?;
            bd.add(&out.breakdown);
            first = out.token;
        }
        return Ok(Admitted {
            sess,
            first: Some((first, bd)),
            resumed_from_disk: resumed.from_disk,
            resume_s: resumed.resume_s,
            snapshot_bytes: resumed.snapshot_bytes,
        });
    }
    vllm_device_check(engine, job.req.prompt.len() + job.req.max_tokens)?;
    let sess = engine.prefill(&job.req.prompt)?;
    Ok(Admitted {
        sess,
        first: None,
        resumed_from_disk: false,
        resume_s: 0.0,
        snapshot_bytes: 0,
    })
}

/// The vLLM-like baseline's admission limit: full KV on device ⇒ reject
/// once the modeled KV for `total_tokens` exceeds the hardware profile's
/// free device memory. A no-op for every other method.
fn vllm_device_check(engine: &Engine, total_tokens: usize) -> Result<()> {
    if engine.cfg.method != crate::config::Method::VllmLike {
        return Ok(());
    }
    if let Some(hw) = crate::hw::HwProfile::by_name(&engine.cfg.hw) {
        let spec = engine.spec();
        let geom = crate::hw::ModelGeometry {
            layers: spec.layers,
            q_heads: spec.q_heads,
            kv_heads: spec.kv_heads,
            head_dim: spec.head_dim,
            elt_size: 2,
        };
        // Full-model weights claim their share of device memory first.
        let weight_bytes = engine.weights.param_count() * 2;
        let budget = hw.device_mem_bytes.saturating_sub(weight_bytes);
        let need = geom.kv_bytes(total_tokens);
        anyhow::ensure!(
            need <= budget,
            "device OOM: KV needs {:.1} GiB, {:.1} GiB free",
            need as f64 / (1u64 << 30) as f64,
            budget as f64 / (1u64 << 30) as f64
        );
    }
    Ok(())
}

/// Collect a full generation from an event stream (blocking helper).
pub fn collect(events: &Receiver<Event>) -> Result<(Vec<u32>, RequestMetrics)> {
    collect_deadline(events, 0)
}

/// [`collect`] with a per-event-gap deadline: if more than `deadline_ms`
/// elapses between consecutive events the request fails with a clean
/// timeout error instead of blocking forever on a wedged replica.
/// `deadline_ms == 0` means no deadline (plain blocking collect). The
/// deadline is per GAP, not end-to-end: a long generation that keeps
/// streaming tokens never times out, while a replica that stops making
/// progress surfaces within one deadline.
pub fn collect_deadline(
    events: &Receiver<Event>,
    deadline_ms: u64,
) -> Result<(Vec<u32>, RequestMetrics)> {
    let mut tokens = Vec::new();
    loop {
        let next = if deadline_ms == 0 {
            events.recv().map_err(|_| RecvTimeoutError::Disconnected)
        } else {
            events.recv_timeout(Duration::from_millis(deadline_ms))
        };
        match next {
            Ok(Event::Token(_, t)) => tokens.push(t),
            Ok(Event::Done(_, m)) => return Ok((tokens, m)),
            Ok(Event::Failed(_, e)) => anyhow::bail!("request failed: {e}"),
            Err(RecvTimeoutError::Disconnected) => anyhow::bail!("replica dropped the request"),
            Err(RecvTimeoutError::Timeout) => {
                anyhow::bail!("request deadline exceeded ({deadline_ms} ms without progress)")
            }
        }
    }
}
