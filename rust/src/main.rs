//! The `retrieval-attention` launcher.
//!
//! ```text
//! retrieval-attention serve      [--config cfg.json] [--addr 127.0.0.1:8041]
//!                                [--replicas N] [--model P] [--method M]
//! retrieval-attention generate   [--config cfg.json] --prompt-task passkey
//!                                [--len N] [--max-tokens T] [--method M]
//! retrieval-attention experiment <id>|all|list [--full] [--out results/]
//! retrieval-attention stats      [--addr 127.0.0.1:8041] [--json]
//! retrieval-attention info       [--artifacts artifacts/]
//! ```
//!
//! CLI parsing is hand-rolled (no clap in the vendored crate set).

use anyhow::{Context, Result};
use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::coordinator::{collect, router::Router, Request};
use retrieval_attention::experiments::{self, ExpCtx};
use retrieval_attention::server::{Client, Server};
use retrieval_attention::util::json::Value;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;
use std::sync::Arc;

/// Tiny flag parser: `--key value` pairs plus positionals.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                // Boolean flags: --full; valued flags: --out dir.
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn load_config(args: &Args) -> Result<ServeConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ServeConfig::from_file(path)?,
        None => ServeConfig::default(),
    };
    if let Some(model) = args.get("model") {
        cfg.model = model.to_string();
    }
    if let Some(m) = args.get("method") {
        cfg.method = Method::parse(m).with_context(|| format!("unknown method `{m}`"))?;
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if let Some(k) = args.get("top-k") {
        cfg.retrieval.top_k = k.parse()?;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..]);
    match cmd {
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "experiment" => cmd_experiment(&args),
        "stats" => cmd_stats(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown command `{other}`")
        }
    }
}

fn print_usage() {
    eprintln!(
        "retrieval-attention — long-context LLM serving via attention-aware vector retrieval\n\
         \n\
         commands:\n\
         \x20 serve       start the json-lines TCP server\n\
         \x20 generate    run one synthetic prompt through the engine\n\
         \x20 experiment  regenerate a paper table/figure (or `all`, `list`)\n\
         \x20 stats       dump a running server's telemetry registry\n\
         \x20 info        show artifact manifest / presets\n\
         \n\
         common flags: --config cfg.json --model PRESET --method METHOD\n\
         \x20            --artifacts DIR --top-k K\n\
         serve flags:  --addr HOST:PORT --replicas N\n\
         generate:     --prompt-task passkey|kv|vt --len N --max-tokens T --depth D\n\
         experiment:   --full --out DIR\n\
         stats:        --addr HOST:PORT [--json]"
    );
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let replicas: usize = args.get("replicas").unwrap_or("1").parse()?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:8041");
    eprintln!(
        "starting {} replica(s) of {} ({}) ...",
        replicas,
        cfg.model,
        cfg.method.label()
    );
    let router = Arc::new(Router::spawn(cfg, replicas));
    let server = Server::start(router, addr)?;
    eprintln!("listening on {} (json-lines; see README quickstart)", server.addr);
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let len: usize = args.get("len").unwrap_or("2048").parse()?;
    let max_tokens: usize = args.get("max-tokens").unwrap_or("4").parse()?;
    let depth: f32 = args.get("depth").unwrap_or("0.5").parse()?;
    let task = args.get("prompt-task").unwrap_or("passkey");
    let mut rng = Rng::seed_from(cfg.seed ^ 0x9E);
    let sample = match task {
        "passkey" => tasks::passkey(&mut rng, len, depth),
        "kv" => tasks::kv_retrieval(&mut rng, len, len / 16),
        "vt" => tasks::ruler_variable_tracking(&mut rng, len, 2),
        other => anyhow::bail!("unknown prompt task `{other}` (passkey|kv|vt)"),
    };
    eprintln!(
        "model={} method={} task={task} len={len} expect={:?}",
        cfg.model,
        cfg.method.label(),
        sample.expect
    );
    let replica = retrieval_attention::coordinator::Replica::spawn(cfg);
    let events =
        replica.submit(Request { id: 1, prompt: sample.prompt.clone(), max_tokens, session: None });
    let (tokens, metrics) = collect(&events)?;
    println!("generated: {tokens:?}");
    println!(
        "grade: {:.0}% | prefill {:.2}s | ttft {:.3}s | tpot {:.4}s | search share {:.0}% \
         | index drains {} ({} tokens, {:.0}% of step time)",
        sample.grade(&tokens) * 100.0,
        metrics.prefill_s,
        metrics.ttft_s,
        metrics.tpot_s,
        metrics.breakdown.search_share() * 100.0,
        metrics.drains,
        metrics.drained_tokens,
        metrics.breakdown.maintenance_share() * 100.0
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args.positional.first().map(|s| s.as_str()).unwrap_or("list");
    if id == "list" {
        println!("available experiments:");
        for (name, _, desc) in experiments::REGISTRY {
            println!("  {name:<9} {desc}");
        }
        return Ok(());
    }
    let out = args.get("out").unwrap_or("results");
    let mut ctx = ExpCtx::new(out, args.has("full"));
    if let Some(a) = args.get("artifacts") {
        ctx.artifacts_dir = a.to_string();
    }
    experiments::run(id, &ctx)
}

/// Fetch and pretty-print a running server's telemetry registry
/// snapshot (`--json` dumps the raw wire object for scripting).
fn cmd_stats(args: &Args) -> Result<()> {
    let addr: std::net::SocketAddr = args
        .get("addr")
        .unwrap_or("127.0.0.1:8041")
        .parse()
        .context("--addr must be HOST:PORT")?;
    let mut client = Client::connect(addr)?;
    let v = client.stats()?;
    if args.has("json") {
        println!("{}", v.to_string());
        return Ok(());
    }
    // Section helper: iterate an object field of the registry snapshot in
    // sorted key order (Value objects are BTreeMaps).
    let section = |snapshot: &Value, kind: &str| -> Vec<(String, Value)> {
        match snapshot.get(kind) {
            Some(Value::Obj(m)) => m.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
            _ => Vec::new(),
        }
    };
    if let Some(router) = v.get("router") {
        println!(
            "router: replicas={} outstanding={} respawns={}",
            router.get("replicas").and_then(Value::as_u64).unwrap_or(0),
            router.get("outstanding").and_then(Value::as_u64).unwrap_or(0),
            router.get("respawns").and_then(Value::as_u64).unwrap_or(0),
        );
    }
    if let Some(n) = v.get("flightrec_len").and_then(Value::as_u64) {
        println!("flight recorder: {n} buffered event(s)");
    }
    let reg = v.get("registry").cloned().unwrap_or_else(Value::obj);
    let labels = section(&reg, "labels");
    if !labels.is_empty() {
        println!("labels:");
        for (k, val) in labels {
            println!("  {k} = {}", val.as_str().unwrap_or("?"));
        }
    }
    let counters = section(&reg, "counters");
    if !counters.is_empty() {
        println!("counters:");
        for (k, val) in counters {
            println!("  {k:<42} {}", val.as_u64().unwrap_or(0));
        }
    }
    let gauges = section(&reg, "gauges");
    if !gauges.is_empty() {
        println!("gauges:");
        for (k, val) in gauges {
            println!("  {k:<42} {}", val.as_f64().unwrap_or(0.0));
        }
    }
    let hists = section(&reg, "histograms");
    if !hists.is_empty() {
        println!("histograms:");
        println!("  {:<42} {:>8} {:>10} {:>10} {:>10} {:>10}", "name", "count", "mean", "p50", "p99", "max");
        for (k, h) in hists {
            let f = |field: &str| h.get(field).and_then(Value::as_f64).unwrap_or(0.0);
            println!(
                "  {k:<42} {:>8} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
                h.get("count").and_then(Value::as_u64).unwrap_or(0),
                f("mean"),
                f("p50"),
                f("p99"),
                f("max"),
            );
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    use retrieval_attention::runtime::manifest::{Manifest, PresetMeta, SpecMeta};
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let print_preset = |name: &str, preset: &PresetMeta| {
        let s = &preset.spec;
        println!(
            "  {name}: {} layers, {}q/{}kv heads, d_head {}, d_model {}, vocab {}, norm {}, {} artifacts",
            s.layers, s.q_heads, s.kv_heads, s.head_dim, s.d_model, s.vocab, s.norm,
            preset.artifacts.len()
        );
    };
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        // A present-but-unparseable manifest is an error the user needs to
        // see, not a reason to silently fall back to built-in presets.
        let manifest = Manifest::load(format!("{dir}/manifest.json"))?;
        println!("artifacts: {dir}");
        for (name, preset) in &manifest.presets {
            print_preset(name, preset);
        }
    } else {
        println!("artifacts: {dir} missing — native backend presets:");
        for name in SpecMeta::builtin_names() {
            let preset = PresetMeta::builtin(name).expect("builtin preset");
            print_preset(name, &preset);
        }
    }
    Ok(())
}
