//! Weight containers + random initialisation.
//!
//! Shapes mirror `python/compile/model.py` exactly (asserted against the
//! manifest in `Weights::validate`). Random presets use scaled-gaussian
//! init — they are never expected to produce meaningful text, only the
//! *geometry* of real attention (distinct Q/K projections of a shared
//! hidden state ⇒ the paper's OOD phenomenon).

use crate::runtime::manifest::SpecMeta;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// One transformer layer's weights.
#[derive(Clone)]
pub struct LayerWeights {
    /// Pre-attention RMSNorm gain `[d]`.
    pub g: Vec<f32>,
    /// Query projection `[d, H*dh]`.
    pub wq: Matrix,
    /// Key projection `[d, KV*dh]`.
    pub wk: Matrix,
    /// Value projection `[d, KV*dh]`.
    pub wv: Matrix,
    /// Output projection `[H*dh, d]`.
    pub wo: Matrix,
    /// Pre-FFN RMSNorm gain `[d]`.
    pub g2: Vec<f32>,
    /// SwiGLU gate `[d, f]`.
    pub w1: Matrix,
    /// SwiGLU linear `[d, f]`.
    pub w3: Matrix,
    /// SwiGLU down `[f, d]`.
    pub w2: Matrix,
}

/// Full model weights.
#[derive(Clone)]
pub struct Weights {
    /// Embedding table `[vocab, d]`.
    pub table: Matrix,
    pub layers: Vec<LayerWeights>,
    /// Final norm gain `[d]`.
    pub gf: Vec<f32>,
    /// Unembedding `[d, vocab]`.
    pub wu: Matrix,
}

impl Weights {
    /// Scaled-gaussian random weights for a geometry preset.
    pub fn random(spec: &SpecMeta, seed: u64) -> Weights {
        let mut rng = Rng::seed_from(seed);
        let d = spec.d_model;
        let (h, kv, dh, f) = (spec.q_heads, spec.kv_heads, spec.head_dim, spec.ffn_dim);
        let mut mat = |rows: usize, cols: usize, scale: f32| {
            let mut r = rng.fork(rows as u64 * 31 + cols as u64);
            Matrix::from_fn(rows, cols, |_, _| r.normal() * scale)
        };
        let proj = 1.0 / (d as f32).sqrt();
        let layers = (0..spec.layers)
            .map(|_| LayerWeights {
                g: vec![1.0; d],
                wq: mat(d, h * dh, proj),
                wk: mat(d, kv * dh, proj),
                wv: mat(d, kv * dh, proj),
                wo: mat(h * dh, d, 1.0 / ((h * dh) as f32).sqrt()),
                g2: vec![1.0; d],
                w1: mat(d, f, proj),
                w3: mat(d, f, proj),
                w2: mat(f, d, 1.0 / (f as f32).sqrt()),
            })
            .collect();
        Weights {
            table: mat(spec.vocab, d, 1.0),
            layers,
            gf: vec![1.0; d],
            wu: mat(d, spec.vocab, proj),
        }
    }

    /// All-zero weights with the right shapes (construction scaffold).
    pub fn zeros(spec: &SpecMeta) -> Weights {
        let d = spec.d_model;
        let (h, kv, dh, f) = (spec.q_heads, spec.kv_heads, spec.head_dim, spec.ffn_dim);
        let layers = (0..spec.layers)
            .map(|_| LayerWeights {
                g: vec![1.0; d],
                wq: Matrix::zeros(d, h * dh),
                wk: Matrix::zeros(d, kv * dh),
                wv: Matrix::zeros(d, kv * dh),
                wo: Matrix::zeros(h * dh, d),
                g2: vec![1.0; d],
                w1: Matrix::zeros(d, f),
                w3: Matrix::zeros(d, f),
                w2: Matrix::zeros(f, d),
            })
            .collect();
        Weights {
            table: Matrix::zeros(spec.vocab, d),
            layers,
            gf: vec![1.0; d],
            wu: Matrix::zeros(d, spec.vocab),
        }
    }

    /// Check every tensor against the manifest spec; returns a description of the
    /// first mismatch.
    pub fn validate(&self, spec: &SpecMeta) -> Result<(), String> {
        let d = spec.d_model;
        let (h, kv, dh, f) = (spec.q_heads, spec.kv_heads, spec.head_dim, spec.ffn_dim);
        let check = |name: &str, m: &Matrix, rows: usize, cols: usize| {
            if m.rows() != rows || m.cols() != cols {
                Err(format!("{name}: got {}x{}, want {rows}x{cols}", m.rows(), m.cols()))
            } else {
                Ok(())
            }
        };
        check("table", &self.table, spec.vocab, d)?;
        check("wu", &self.wu, d, spec.vocab)?;
        if self.layers.len() != spec.layers {
            return Err(format!("layers: got {}, want {}", self.layers.len(), spec.layers));
        }
        for (i, l) in self.layers.iter().enumerate() {
            check(&format!("l{i}.wq"), &l.wq, d, h * dh)?;
            check(&format!("l{i}.wk"), &l.wk, d, kv * dh)?;
            check(&format!("l{i}.wv"), &l.wv, d, kv * dh)?;
            check(&format!("l{i}.wo"), &l.wo, h * dh, d)?;
            check(&format!("l{i}.w1"), &l.w1, d, f)?;
            check(&format!("l{i}.w3"), &l.w3, d, f)?;
            check(&format!("l{i}.w2"), &l.w2, f, d)?;
            if l.g.len() != d || l.g2.len() != d {
                return Err(format!("l{i}: norm gain length"));
            }
        }
        Ok(())
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        let mut n = self.table.as_slice().len() + self.wu.as_slice().len() + self.gf.len();
        for l in &self.layers {
            n += l.wq.as_slice().len()
                + l.wk.as_slice().len()
                + l.wv.as_slice().len()
                + l.wo.as_slice().len()
                + l.w1.as_slice().len()
                + l.w3.as_slice().len()
                + l.w2.as_slice().len()
                + l.g.len()
                + l.g2.len();
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SpecMeta {
        SpecMeta {
            layers: 2,
            d_model: 32,
            q_heads: 4,
            kv_heads: 2,
            head_dim: 8,
            vocab: 64,
            norm: true,
            ffn_dim: 48,
            static_len: 128,
        }
    }

    #[test]
    fn random_weights_validate() {
        let s = spec();
        let w = Weights::random(&s, 7);
        assert!(w.validate(&s).is_ok());
    }

    #[test]
    fn zeros_validate() {
        let s = spec();
        assert!(Weights::zeros(&s).validate(&s).is_ok());
    }

    #[test]
    fn validate_catches_wrong_shape() {
        let s = spec();
        let mut w = Weights::random(&s, 7);
        w.layers[1].wq = Matrix::zeros(3, 3);
        assert!(w.validate(&s).unwrap_err().contains("l1.wq"));
    }

    #[test]
    fn deterministic_by_seed() {
        let s = spec();
        let a = Weights::random(&s, 9);
        let b = Weights::random(&s, 9);
        assert_eq!(a.layers[0].wq, b.layers[0].wq);
        let c = Weights::random(&s, 10);
        assert_ne!(a.layers[0].wq, c.layers[0].wq);
    }

    #[test]
    fn param_count_positive() {
        let s = spec();
        let w = Weights::random(&s, 1);
        assert!(w.param_count() > 10_000);
    }
}
