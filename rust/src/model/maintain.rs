//! The background index-maintenance worker.
//!
//! PR 1 ran the overflow→index drain as an end-of-step parallel fan-out —
//! off the attention path but still inside the token step, so a slow
//! graph insert stretched that token's latency. This module moves the
//! whole drain **off-thread**: the engine snapshots the overflow batch
//! (key rows + absolute ids + per-head recent queries), enqueues a
//! [`DrainJob`], and keeps decoding. The worker grows the group's shared
//! segmented store and id map, inserts into every query head's *back*
//! index buffer, and publishes each with a generation-counted swap
//! (`baselines::IndexRetriever`); decode reads the front the whole time.
//! Completions flow back over a channel and the engine applies them at
//! the start of the next maintenance phase (advancing the cache's
//! indexed boundary so the brute-force overflow scan drops those tokens).
//!
//! Eviction ([`EvictJob`]) rides the same queue: retired token ids are
//! tombstoned in every head's index. The engine retires the ids from the
//! attention set *synchronously* (a boundary bump) so correctness never
//! waits on the worker — the index tombstone is just reclamation.
//!
//! Reclamation epochs ([`CompactJob`]) ride it too: once a group's
//! tombstones exceed `retrieval.eviction.reclaim_ratio` × live rows, the
//! worker builds a compacted store + id map, bumps the group's **store
//! generation**, and remaps dense ids in every head's index
//! ([`crate::index::RemapPlan`]) — the step that makes eviction free
//! memory *physically*, not just logically.
//!
//! The quantized scan tier rides these jobs for free: the group store
//! carries its `retrieval.quant` mode, so the `extend` inside a drain
//! (append + LSM tail merge) and the `compact_select` inside a
//! reclamation epoch build/reshare the per-chunk mirrors right where the
//! chunks are born — quantization cost lands on this worker thread, never
//! on the decode token path.
//!
//! One worker thread per session keeps the design deadlock-free by
//! construction: the decode thread never blocks on the worker (completions
//! are polled), and the worker only blocks reclaiming a back buffer whose
//! readers are short-lived searches. Jobs for one group are serialized by
//! the engine's in-flight set, so the store-sync contract of
//! `insert_batch` can never be violated mid-queue.

use crate::baselines::{GroupShared, HostRetriever};
use crate::index::{InsertContext, RemapPlan};
use crate::tensor::Matrix;
use crate::util::parallel;
use crate::util::sync::mpsc::{self, Receiver, Sender};
use crate::util::sync::{Arc, AtomicUsize, Ordering};
use std::collections::HashSet;
use std::time::Instant;

/// One group's overflow batch, snapshotted by the engine.
pub struct DrainJob {
    pub layer: usize,
    pub kvh: usize,
    /// Overflow key rows (empty when no head reads the store).
    pub rows: Matrix,
    /// Absolute token ids of the batch (ascending).
    pub ids: Vec<u32>,
    /// New indexed boundary to report back (one past the last drained id).
    pub upto: usize,
    /// Whether any head actually reads the grown store.
    pub grow_store: bool,
    /// Every query head of the group (insert fan-out).
    pub heads: Vec<Arc<dyn HostRetriever>>,
    /// Per-head recent decode queries (RoarGraph's attention-aware wiring
    /// context), already capped to the configured budget.
    pub queries: Vec<Option<Matrix>>,
    pub group: Arc<GroupShared>,
}

/// Tombstone a batch of retired absolute ids in every head of a group.
pub struct EvictJob {
    pub layer: usize,
    pub kvh: usize,
    pub ids: Vec<u32>,
    pub heads: Vec<Arc<dyn HostRetriever>>,
    /// The group state: absolute→dense resolution runs ONCE here for the
    /// whole group, not once per query head.
    pub group: Arc<GroupShared>,
}

/// One group's reclamation epoch (the tentpole `Job::Compact`): build a
/// compacted store + id map from the group's tombstone set, bump the
/// store generation, and remap every head's index. The plan is built at
/// *execution* time (not snapshot time) so evictions already in the queue
/// are folded in, and the engine's in-flight set serializes it against
/// drain snapshots for the same group.
pub struct CompactJob {
    pub layer: usize,
    pub kvh: usize,
    /// Every query head of the group (remap fan-out).
    pub heads: Vec<Arc<dyn HostRetriever>>,
    pub group: Arc<GroupShared>,
}

pub enum Job {
    Drain(DrainJob),
    Evict(EvictJob),
    Compact(CompactJob),
    /// Replies once every job enqueued before it has executed (flush).
    Barrier(Sender<()>),
}

#[derive(Clone, Copy, Debug)]
pub enum DoneKind {
    Drained { upto: usize, count: u64 },
    Evicted { count: u64 },
    /// Rows physically reclaimed by a `Job::Compact` epoch.
    Compacted { dropped: u64 },
}

/// A completed job, reported back to the session.
#[derive(Clone, Copy, Debug)]
pub struct Done {
    pub layer: usize,
    pub kvh: usize,
    pub kind: DoneKind,
    /// Wall-clock from job start to the last head's buffer swap.
    pub swap_s: f64,
    pub ok: bool,
}

/// Execute one drain (shared by the worker thread and the synchronous
/// `async_worker = false` path).
pub fn run_drain(j: &DrainJob) -> Done {
    let t = Instant::now();
    let count = j.ids.len() as u64;
    // Pre-validate BEFORE publishing anything: the first indexed head's
    // dense slot count (live + tombstoned) must match the group map, or
    // the insert contract would be violated. Refusing here mutates
    // nothing, so the engine simply retries on a later step — the PR-1
    // "first head refused ⇒ nothing mutated yet, skip the group" drain
    // invariant, preserved across the move off-thread. (Unreachable with
    // per-group job serialization; this is graceful degradation, so no
    // assert — a panic here would kill the worker on the one path that is
    // explicitly documented as retryable.)
    let map_len = j.group.id_map().len();
    // Representative = the first head that HAS an indexed tier: streaming
    // window heads report `indexed_len() == None` and must neither vouch
    // for nor veto their indexed siblings. A group with no indexed head
    // at all is vacuously in sync (nothing holds dense state).
    let first_in_sync = j
        .heads
        .iter()
        .find_map(|h| h.indexed_len().map(|live| live + h.tombstones() == map_len))
        .unwrap_or(true);
    if !first_in_sync {
        return Done {
            layer: j.layer,
            kvh: j.kvh,
            kind: DoneKind::Drained { upto: j.upto, count },
            swap_s: t.elapsed().as_secs_f64(),
            ok: false,
        };
    }
    // Fault-injection site: an injected failure here lands BEFORE the
    // publish below, so it exercises exactly the documented clean-retry
    // path (ok: false, nothing mutated, engine retries on a later step).
    if crate::util::failpoint::trigger("maint.drain.publish").is_err() {
        return Done {
            layer: j.layer,
            kvh: j.kvh,
            kind: DoneKind::Drained { upto: j.upto, count },
            swap_s: t.elapsed().as_secs_f64(),
            ok: false,
        };
    }
    // Publish the id map first, then the grown store, then the per-head
    // index fronts: a decode reader that observes a swapped index always
    // finds every dense id mapped (snapshot order is the reverse).
    let store = j.group.extend(j.rows.clone(), &j.ids, j.grow_store);
    let heads: Vec<usize> = (0..j.heads.len()).collect();
    let oks: Vec<bool> = parallel::par_map(&heads, |&h| {
        let ctx = InsertContext { recent_queries: j.queries[h].as_ref() };
        j.heads[h].insert_batch(&store, &j.ids, &ctx)
    });
    // Heads of one group share the store, the id stream and the index
    // family, so a later indexed head cannot diverge from the first. If
    // one somehow did, committing is still the safe direction (PR-1
    // semantics): that head merely misses the new keys, whereas refusing
    // after the publish above would wedge the group's store-sync check
    // forever. The verdict comes from the first INDEXED head — a
    // streaming head's unconditional `true` must not mask a refusal.
    let ok = j
        .heads
        .iter()
        .zip(&oks)
        .find_map(|(h, &o)| h.indexed_len().map(|_| o))
        .or_else(|| oks.first().copied())
        .unwrap_or(true);
    debug_assert!(
        oks.iter().all(|&o| o),
        "GQA group diverged during drain (layer {} kvh {})",
        j.layer,
        j.kvh
    );
    Done {
        layer: j.layer,
        kvh: j.kvh,
        kind: DoneKind::Drained { upto: j.upto, count },
        swap_s: t.elapsed().as_secs_f64(),
        ok,
    }
}

/// Execute one eviction (tombstone fan-out across the group's heads).
pub fn run_evict(j: &EvictJob) -> Done {
    let t = Instant::now();
    let count = j.ids.len() as u64;
    // One reverse-map pass per group; heads get pre-resolved dense slots.
    let dense = j.group.dense_ids_for(&j.ids);
    let heads: Vec<usize> = (0..j.heads.len()).collect();
    let oks: Vec<bool> = parallel::par_map(&heads, |&h| j.heads[h].remove_dense(&dense));
    let ok = oks.iter().all(|&o| o);
    Done {
        layer: j.layer,
        kvh: j.kvh,
        kind: DoneKind::Evicted { count },
        swap_s: t.elapsed().as_secs_f64(),
        ok,
    }
}

/// Execute one reclamation epoch. Publish order is the PR-2 snapshot
/// order extended across a generation bump: the new map is published
/// first (with the previous generation's map retained), then the
/// compacted store, then every head's index front (each stamped with the
/// new generation), and only then is the old map released — a decode
/// reader holding ANY front can always pair it with a same-generation map
/// and therefore never observes an unmapped or misnumbered dense id.
pub fn run_compact(j: &CompactJob) -> Done {
    let t = Instant::now();
    let fail = |t: Instant| Done {
        layer: j.layer,
        kvh: j.kvh,
        kind: DoneKind::Compacted { dropped: 0 },
        swap_s: t.elapsed().as_secs_f64(),
        ok: false,
    };
    if j.heads.is_empty() || !j.heads.iter().all(|h| h.supports_reclaim()) {
        return fail(t);
    }
    // Plan from the first DENSE head's tombstone set: every indexed head
    // of a group receives the identical remove stream, so any one of them
    // is representative (per-head deadness is still carried through each
    // family's remap, so a diverged head degrades to extra tombstones,
    // never resurrections). Streaming window heads hold no dense ids —
    // they are skipped here, and a group made entirely of them has
    // nothing to reclaim.
    let Some(dense_rep) = j.heads.iter().find(|h| h.reclaim_counts().is_some()) else {
        return fail(t);
    };
    let dead = dense_rep.dense_dead_ids();
    let old_map = j.group.id_map();
    let old_store = j.group.keys();
    let old_len = old_map.len();
    if dead.is_empty() || old_store.rows() != old_len {
        return fail(t);
    }
    // Pre-validate EVERY head BEFORE publishing anything (the run_drain
    // discipline): a head whose dense slot count disagrees with the group
    // map (the drain-divergence degradation path) would refuse its remap
    // *after* the map had already moved to the new generation, stranding
    // that head on a generation the next epoch would garbage-collect.
    // Refusing here mutates nothing; the engine retries on a later step.
    // Heads without dense state (`reclaim_counts() == None` — streaming
    // windows) are vacuously in sync: their remap is the map publish
    // itself.
    let all_in_sync = j
        .heads
        .iter()
        .all(|h| h.reclaim_counts().map(|(live, dead)| live + dead == old_len).unwrap_or(true));
    if !all_in_sync {
        return fail(t);
    }
    let gen = old_map.store_gen + 1;
    // `None` ⇒ nothing to drop or nothing would survive (graph families
    // need ≥ 1 node); skip the epoch — the next eviction/drain changes
    // the live set and re-triggers.
    let Some((plan, keep)) = RemapPlan::from_dead(&dead, &old_store, gen) else {
        return fail(t);
    };
    let dropped = (old_len - keep.len()) as u64;
    let new_ids: Vec<u32> = keep.iter().map(|&o| old_map.ids[o as usize]).collect();
    let new_store = plan.store.clone();
    let plan = Arc::new(plan);
    // Fault-injection site: fires before `publish_remap`, the epoch's
    // first mutation — an injected failure is a clean skipped epoch
    // (ok: false), re-triggered by the next eviction/drain.
    if crate::util::failpoint::trigger("maint.compact.publish").is_err() {
        return fail(t);
    }
    j.group.publish_remap(new_ids, new_store, gen);
    let heads: Vec<usize> = (0..j.heads.len()).collect();
    let oks: Vec<bool> = parallel::par_map(&heads, |&h| j.heads[h].apply_remap(&plan));
    let ok = oks.iter().all(|&o| o);
    debug_assert!(ok, "GQA group diverged during compact (layer {} kvh {})", j.layer, j.kvh);
    if ok {
        // Release the previous generation's map only when every front
        // carries the new one; a (unreachable) diverged head keeps its
        // pre-remap pairing alive instead of stranding its readers.
        j.group.finish_remap();
    }
    Done {
        layer: j.layer,
        kvh: j.kvh,
        kind: DoneKind::Compacted { dropped },
        swap_s: t.elapsed().as_secs_f64(),
        ok,
    }
}

/// Process-registry + flight-recorder accounting for one completed
/// maintenance job. Sits on the `run_job` choke point so the async
/// worker and the inline (`async_worker = false`) path both land here;
/// per-session `MaintStats` stay the authoritative per-request numbers,
/// these are the fleet-wide monotone view.
fn record_done(d: Done) -> Done {
    let reg = crate::telemetry::registry();
    let detail = match d.kind {
        DoneKind::Drained { upto, count } => {
            if d.ok {
                reg.counter("maintenance.drains_total").inc();
                reg.counter("maintenance.drained_tokens_total").add(count);
            }
            format!(
                "drain layer={} kvh={} upto={upto} count={count} ok={}",
                d.layer, d.kvh, d.ok
            )
        }
        DoneKind::Evicted { count } => {
            if d.ok {
                reg.counter("maintenance.evictions_total").inc();
                reg.counter("maintenance.evicted_tokens_total").add(count);
            }
            format!("evict layer={} kvh={} count={count} ok={}", d.layer, d.kvh, d.ok)
        }
        DoneKind::Compacted { dropped } => {
            if d.ok {
                reg.counter("maintenance.reclaims_total").inc();
                reg.counter("maintenance.reclaimed_rows_total").add(dropped);
            }
            format!("compact layer={} kvh={} dropped={dropped} ok={}", d.layer, d.kvh, d.ok)
        }
    };
    reg.histogram("maintenance.publish_s").record(d.swap_s);
    crate::telemetry::flightrec("maint", detail);
    d
}

fn run_job(job: &Job) -> Option<Done> {
    match job {
        Job::Drain(j) => Some(record_done(run_drain(j))),
        Job::Evict(j) => Some(record_done(run_evict(j))),
        Job::Compact(j) => Some(record_done(run_compact(j))),
        Job::Barrier(tx) => {
            let _ = tx.send(());
            None
        }
    }
}

/// [`run_job`] with panic containment: a panic inside a maintenance job
/// must not kill the worker thread (stranding every later job of the
/// session in the queue) or unwind into the token path (the inline
/// fallback runs on the decode thread). The panicked job is reported as
/// its own `ok: false` completion — the documented clean-retry shape —
/// synthesized from the job's metadata, so depth accounting and the
/// engine's in-flight-group bookkeeping stay exact. (The publish
/// operations inside the jobs are generation-counted atomic swaps with
/// validate-before-publish discipline, so "retry later" is safe even for
/// a panic that fired mid-job.) A barrier cannot panic, but the arm
/// still answers it — a lost flush reply would deadlock `shutdown`.
fn run_job_contained(job: &Job) -> Option<Done> {
    match crate::util::contain::contained("maintenance job", || Ok(run_job(job))) {
        Ok(done) => done,
        Err(_) => {
            let (layer, kvh, kind) = match job {
                Job::Drain(j) => (
                    j.layer,
                    j.kvh,
                    DoneKind::Drained { upto: j.upto, count: j.ids.len() as u64 },
                ),
                Job::Evict(j) => {
                    (j.layer, j.kvh, DoneKind::Evicted { count: j.ids.len() as u64 })
                }
                Job::Compact(j) => (j.layer, j.kvh, DoneKind::Compacted { dropped: 0 }),
                Job::Barrier(tx) => {
                    let _ = tx.send(());
                    return None;
                }
            };
            Some(record_done(Done { layer, kvh, kind, swap_s: 0.0, ok: false }))
        }
    }
}

/// Handle to one session's maintenance thread.
struct WorkerHandle {
    tx: Option<Sender<Job>>,
    done_rx: Receiver<Done>,
    /// Kept for the no-thread fallback: when the OS refuses to spawn the
    /// worker, `submit` executes jobs inline and completions still flow
    /// through the same channel the poll/flush paths already read.
    done_tx: Sender<Done>,
    depth: Arc<AtomicUsize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl WorkerHandle {
    fn spawn() -> WorkerHandle {
        let (tx, rx) = mpsc::channel::<Job>();
        let (done_tx, done_rx) = mpsc::channel::<Done>();
        let depth = Arc::new(AtomicUsize::new(0));
        let depth_w = depth.clone();
        let done_w = done_tx.clone();
        let spawned = std::thread::Builder::new().name("kv-maintenance".into()).spawn(move || {
            while let Ok(job) = rx.recv() {
                let counted = !matches!(job, Job::Barrier(_));
                let done = run_job_contained(&job);
                if counted {
                    // SeqCst pairs with the submit-side fetch_add: the
                    // decrement happens only after the job fully executed,
                    // so a sampled depth can over-count in-flight work but
                    // never under-count it (queue_peak stays conservative).
                    depth_w.fetch_sub(1, Ordering::SeqCst);
                }
                if let Some(done) = done {
                    if done_w.send(done).is_err() {
                        return;
                    }
                }
            }
        });
        match spawned {
            Ok(h) => WorkerHandle { tx: Some(tx), done_rx, done_tx, depth, handle: Some(h) },
            // The OS refused a thread (resource exhaustion). Degrade to
            // executing jobs inline on the submitting thread instead of
            // panicking the session: maintenance still happens, merely back
            // on the token path (the PR-1 arrangement) — a latency
            // regression, never a correctness one.
            Err(_) => WorkerHandle { tx: None, done_rx, done_tx, depth, handle: None },
        }
    }

    fn submit(&self, job: Job) {
        let Some(tx) = &self.tx else {
            // No worker thread (spawn refused at construction): run the
            // job synchronously. Nothing is ever queued on this path, so
            // depth accounting stays at zero by construction.
            if let Some(done) = run_job_contained(&job) {
                let _ = self.done_tx.send(done);
            }
            return;
        };
        // Barriers are flush markers, not work: excluding them from
        // depth accounting keeps `queue_peak` from reporting a phantom
        // job on every flush()/shutdown().
        let counted = !matches!(job, Job::Barrier(_));
        if counted {
            self.depth.fetch_add(1, Ordering::SeqCst);
        }
        if tx.send(job).is_err() && counted {
            self.depth.fetch_sub(1, Ordering::SeqCst);
        }
    }

    fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// Non-blocking: completions reported so far.
    fn poll(&self) -> Vec<Done> {
        let mut out = Vec::new();
        while let Ok(d) = self.done_rx.try_recv() {
            out.push(d);
        }
        out
    }

    /// Block until every previously-enqueued job has executed, then
    /// collect all completions (FIFO ordering makes the barrier exact).
    fn flush(&self) -> Vec<Done> {
        let (btx, brx) = mpsc::channel();
        self.submit(Job::Barrier(btx));
        let _ = brx.recv();
        self.poll()
    }

    /// Flush, stop the thread, and return any final completions.
    fn shutdown(&mut self) -> Vec<Done> {
        let mut out = if self.tx.is_some() { self.flush() } else { Vec::new() };
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        while let Ok(d) = self.done_rx.try_recv() {
            out.push(d);
        }
        out
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Aggregate maintenance counters (exported through `RequestMetrics` and
/// the server's `done` event).
#[derive(Clone, Copy, Debug, Default)]
pub struct MaintStats {
    /// Completed jobs (drains + evictions + compactions).
    pub swaps: u64,
    /// Summed wall-clock of job execution (buffer build + swap), i.e. the
    /// off-thread time that PR 1 used to spend on the token path.
    pub swap_s_total: f64,
    /// Peak worker queue depth observed at submit time (barrier flush
    /// markers excluded — they are not work).
    pub queue_peak: usize,
    /// Tokens retired by the eviction policy.
    pub evicted_tokens: u64,
    /// Reclamation epochs completed (store + index dense-id remaps).
    pub reclaims: u64,
    /// Dense rows physically reclaimed across all epochs.
    pub reclaimed_rows: u64,
}

impl MaintStats {
    pub fn mean_swap_s(&self) -> f64 {
        if self.swaps == 0 {
            0.0
        } else {
            self.swap_s_total / self.swaps as f64
        }
    }
}

/// Per-session maintenance state: the (lazily spawned) worker, the set of
/// groups with an in-flight drain, and the aggregate stats.
pub struct MaintenanceState {
    worker: Option<WorkerHandle>,
    pub inflight: HashSet<(usize, usize)>,
    pub stats: MaintStats,
}

impl Default for MaintenanceState {
    fn default() -> Self {
        MaintenanceState::new()
    }
}

impl MaintenanceState {
    pub fn new() -> MaintenanceState {
        MaintenanceState { worker: None, inflight: HashSet::new(), stats: MaintStats::default() }
    }

    /// Enqueue a job, spawning the worker on first use.
    pub fn submit(&mut self, job: Job) {
        let w = self.worker.get_or_insert_with(WorkerHandle::spawn);
        w.submit(job);
        let depth = w.queue_depth();
        if depth > self.stats.queue_peak {
            self.stats.queue_peak = depth;
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.worker.as_ref().map(|w| w.queue_depth()).unwrap_or(0)
    }

    pub fn poll(&mut self) -> Vec<Done> {
        self.worker.as_ref().map(|w| w.poll()).unwrap_or_default()
    }

    pub fn flush(&mut self) -> Vec<Done> {
        self.worker.as_ref().map(|w| w.flush()).unwrap_or_default()
    }

    /// Flush + join the worker. A later `submit` spawns a fresh one.
    pub fn shutdown(&mut self) -> Vec<Done> {
        let out = self.worker.as_mut().map(|w| w.shutdown()).unwrap_or_default();
        self.worker = None;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{build_retriever, RetrieverInputs};
    use crate::config::{Method, RetrievalConfig};
    use crate::index::KeyStore;
    use crate::util::rng::Rng;

    fn group_setup(n: usize, d: usize, seed: u64) -> (Arc<GroupShared>, Matrix) {
        let mut rng = Rng::seed_from(seed);
        let keys = KeyStore::from_matrix(Matrix::from_fn(n, d, |_, _| rng.normal()));
        let ids: Vec<u32> = (0..n as u32).collect();
        let queries = Matrix::from_fn(32, d, |_, _| rng.normal());
        (GroupShared::new(keys, ids), queries)
    }

    #[test]
    fn worker_executes_drain_and_reports_done() {
        let (group, queries) = group_setup(64, 8, 1);
        let cfg = RetrievalConfig::default();
        let inp = RetrieverInputs {
            group: group.clone(),
            prefill_queries: &queries,
            scale: 0.35,
            cfg: &cfg,
            seed: 1,
        };
        let head: Arc<dyn HostRetriever> = Arc::from(build_retriever(Method::Flat, inp));
        let mut state = MaintenanceState::new();
        let mut rng = Rng::seed_from(2);
        let rows = Matrix::from_fn(8, 8, |_, _| rng.normal());
        let ids: Vec<u32> = (64..72).collect();
        state.submit(Job::Drain(DrainJob {
            layer: 0,
            kvh: 0,
            rows,
            ids,
            upto: 72,
            grow_store: true,
            heads: vec![head.clone()],
            queries: vec![None],
            group: group.clone(),
        }));
        let dones = state.flush();
        assert_eq!(dones.len(), 1);
        assert!(dones[0].ok);
        assert!(matches!(dones[0].kind, DoneKind::Drained { upto: 72, count: 8 }));
        assert!(dones[0].swap_s >= 0.0);
        assert_eq!(head.index_generation(), 1);
        assert_eq!(group.id_map().len(), 72);
        assert_eq!(group.keys().rows(), 72);
        // Evict through the same queue.
        state.submit(Job::Evict(EvictJob {
            layer: 0,
            kvh: 0,
            ids: vec![0, 1, 2],
            heads: vec![head.clone()],
            group: group.clone(),
        }));
        let dones = state.shutdown();
        assert_eq!(dones.len(), 1);
        assert!(matches!(dones[0].kind, DoneKind::Evicted { count: 3 }));
        assert_eq!(head.tombstones(), 3);
        assert_eq!(state.queue_depth(), 0);
    }

    #[test]
    fn compact_job_reclaims_through_the_worker() {
        let (group, queries) = group_setup(48, 8, 5);
        let cfg = RetrievalConfig::default();
        let inp = RetrieverInputs {
            group: group.clone(),
            prefill_queries: &queries,
            scale: 0.35,
            cfg: &cfg,
            seed: 5,
        };
        let head: Arc<dyn HostRetriever> = Arc::from(build_retriever(Method::Flat, inp));
        let mut state = MaintenanceState::new();
        state.submit(Job::Evict(EvictJob {
            layer: 0,
            kvh: 0,
            ids: (0..12).collect(),
            heads: vec![head.clone()],
            group: group.clone(),
        }));
        state.submit(Job::Compact(CompactJob {
            layer: 0,
            kvh: 0,
            heads: vec![head.clone()],
            group: group.clone(),
        }));
        let dones = state.shutdown();
        assert_eq!(dones.len(), 2);
        assert!(dones.iter().all(|d| d.ok));
        assert!(matches!(dones[1].kind, DoneKind::Compacted { dropped: 12 }));
        // The queue-ordered evictions were folded into the epoch's plan.
        assert_eq!(group.id_map().len(), 36);
        assert_eq!(group.keys().rows(), 36);
        assert_eq!(group.store_generation(), 1);
        assert_eq!(head.tombstones(), 0);
        assert_eq!(head.indexed_len(), Some(36));
        // An epoch with no tombstones is refused without mutating state.
        let mut state = MaintenanceState::new();
        state.submit(Job::Compact(CompactJob {
            layer: 0,
            kvh: 0,
            heads: vec![head.clone()],
            group: group.clone(),
        }));
        let dones = state.shutdown();
        assert_eq!(dones.len(), 1);
        assert!(!dones[0].ok);
        assert_eq!(group.store_generation(), 1);
    }

    #[test]
    fn mixed_policy_group_drains_evicts_and_compacts() {
        // A GQA group with a streaming head FIRST (the representative-pick
        // regression): drains must validate against the indexed sibling,
        // evictions must tombstone it, and the reclamation epoch must plan
        // from it — the streaming head rides along holding no dense state.
        use crate::baselines::StreamingRetriever;
        let (group, queries) = group_setup(48, 8, 21);
        let cfg = RetrievalConfig::default();
        let inp = RetrieverInputs {
            group: group.clone(),
            prefill_queries: &queries,
            scale: 0.35,
            cfg: &cfg,
            seed: 21,
        };
        let indexed: Arc<dyn HostRetriever> = Arc::from(build_retriever(Method::Flat, inp));
        let streaming: Arc<dyn HostRetriever> =
            Arc::new(StreamingRetriever::new(group.clone(), 4, 8));
        let heads = vec![streaming.clone(), indexed.clone()];
        let mut state = MaintenanceState::new();
        let mut rng = Rng::seed_from(22);
        state.submit(Job::Drain(DrainJob {
            layer: 0,
            kvh: 0,
            rows: Matrix::from_fn(8, 8, |_, _| rng.normal()),
            ids: (48..56).collect(),
            upto: 56,
            grow_store: true,
            heads: heads.clone(),
            queries: vec![None, None],
            group: group.clone(),
        }));
        let dones = state.flush();
        assert_eq!(dones.len(), 1);
        assert!(dones[0].ok);
        assert_eq!(group.id_map().len(), 56);
        assert_eq!(indexed.indexed_len(), Some(56));
        // The streaming head's recent window covers the drained tail
        // without having participated in the insert.
        let out = streaming.retrieve(&[0.0; 8], 16);
        assert!(out.ids.ends_with(&[54, 55]));
        assert_eq!(out.scanned, 0);
        state.submit(Job::Evict(EvictJob {
            layer: 0,
            kvh: 0,
            ids: (0..12).collect(),
            heads: heads.clone(),
            group: group.clone(),
        }));
        state.submit(Job::Compact(CompactJob {
            layer: 0,
            kvh: 0,
            heads: heads.clone(),
            group: group.clone(),
        }));
        let dones = state.shutdown();
        assert_eq!(dones.len(), 2);
        assert!(dones.iter().all(|d| d.ok), "mixed group wedged maintenance");
        assert!(matches!(dones[1].kind, DoneKind::Compacted { dropped: 12 }));
        assert_eq!(group.id_map().len(), 44);
        assert_eq!(group.store_generation(), 1);
        assert_eq!(indexed.indexed_len(), Some(44));
        // The streaming head reads the compacted map transparently.
        let out = streaming.retrieve(&[0.0; 8], 16);
        assert!(!out.ids.contains(&0), "reclaimed id surfaced in window");
        assert!(out.ids.contains(&12));
    }

    #[test]
    fn barriers_excluded_from_queue_depth_accounting() {
        // Regression: flush()/shutdown() used to bump the depth counter
        // for their barrier marker, inflating `queue_peak` by one phantom
        // job on every quiesce.
        let (group, _queries) = group_setup(8, 4, 9);
        let mut state = MaintenanceState::new();
        assert!(state.flush().is_empty());
        assert_eq!(state.stats.queue_peak, 0);
        // A flush on a live-but-idle worker must record no depth either.
        state.submit(Job::Evict(EvictJob {
            layer: 0,
            kvh: 0,
            ids: vec![0],
            heads: Vec::new(),
            group: group.clone(),
        }));
        // The worker may or may not have drained the job before the peak
        // was sampled; either way a real job is the only thing that can
        // ever raise it.
        let peak = state.stats.queue_peak;
        assert!(peak <= 1);
        let dones = state.flush();
        assert_eq!(dones.len(), 1);
        assert_eq!(state.stats.queue_peak, peak, "flush barrier inflated the peak");
        let _ = state.flush();
        let _ = state.flush();
        assert_eq!(state.stats.queue_peak, peak, "repeated flushes inflated the peak");
        let _ = state.shutdown();
        assert_eq!(state.stats.queue_peak, peak, "shutdown barrier inflated the peak");
    }
}
