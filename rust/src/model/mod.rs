//! Model layer: weight construction and the decode/prefill engine.
//!
//! Weights are *runtime inputs* to the AOT artifacts (the compute graph is
//! weight-agnostic), so this module owns them entirely on the Rust side:
//!
//! * [`weights`] — weight containers + random initialisation for the
//!   geometry-scaled presets (`llama3-mini`, `yi6-mini`, `yi9-mini`);
//! * [`induction`] — the hand-constructed 2-layer induction-head model
//!   (preset `induction-mini`) that provably solves associative recall,
//!   turning retrieval recall into measurable task accuracy;
//! * [`engine`] — the serving engine: chunked prefill, index construction,
//!   and the Algorithm-1 decode step (device W-attention via the Pallas
//!   artifact, host Ω-attention via the retrieval policy, γ-combine);
//! * [`maintain`] — the background maintenance worker: overflow drains and
//!   eviction tombstones run off the token path, publishing each head's
//!   index with a double-buffered generation-counted swap.

pub mod engine;
pub mod induction;
pub mod maintain;
pub mod weights;

pub use engine::{DecodeOutput, Engine, Session, WaveItem};
pub use weights::{LayerWeights, Weights};

use crate::runtime::manifest::SpecMeta;

/// Positional code for absolute position `pos`, matching the model preset.
///
/// * Induction preset: sinusoidal planes in the last `P` dims (the
///   construction's layer-1 shift operator is a rotation on these planes).
/// * Random presets: zeros (the geometry experiments don't need positions,
///   and content-based attention keeps Q/K statistics stationary).
pub fn position_code(spec: &SpecMeta, pos: usize) -> Vec<f32> {
    let d = spec.d_model;
    let mut code = vec![0.0f32; d];
    if !induction::is_induction(spec) {
        return code;
    }
    let planes = induction::POS_PLANES;
    let base = d - 2 * planes; // position planes occupy the last 2*planes dims
    let amp = 1.0 / (planes as f32).sqrt();
    for m in 0..planes {
        let theta = induction::plane_freq(m);
        let angle = pos as f32 * theta;
        code[base + 2 * m] = angle.cos() * amp;
        code[base + 2 * m + 1] = angle.sin() * amp;
    }
    code
}

#[cfg(test)]
mod tests {
    use super::*;

    fn induction_spec() -> SpecMeta {
        SpecMeta {
            layers: 2,
            d_model: 192,
            q_heads: 1,
            kv_heads: 1,
            head_dim: 192,
            vocab: 4096,
            norm: false,
            ffn_dim: 8,
            static_len: 640,
        }
    }

    #[test]
    fn position_codes_unit_norm() {
        let spec = induction_spec();
        for pos in [0usize, 1, 100, 10_000] {
            let c = position_code(&spec, pos);
            let n = crate::tensor::norm(&c);
            assert!((n - 1.0).abs() < 1e-5, "pos {pos} norm {n}");
        }
    }

    #[test]
    fn position_codes_peak_only_at_self() {
        // The induction code uses *high* random frequencies: every shifted
        // position must be well-separated from the peak (DESIGN.md:
        // max off-peak rho ≈ 0.56), including the adjacent one.
        let spec = induction_spec();
        let a = position_code(&spec, 5000);
        assert!((crate::tensor::dot(&a, &a) - 1.0).abs() < 1e-5);
        for other in [4999usize, 5001, 5002, 6000, 9000, 100_000] {
            let sim = crate::tensor::dot(&a, &position_code(&spec, other));
            assert!(sim < 0.7, "pos {other} too similar: {sim}");
        }
    }
}
