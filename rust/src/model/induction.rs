//! Hand-constructed 2-layer induction-head transformer.
//!
//! The paper measures *task accuracy* of retrieval methods on real LLMs.
//! With no checkpoints available (repro band 0/5), we substitute a model
//! whose task behaviour is **provable**: the classic induction-head
//! construction (Elhage et al. style), built so that it answers
//! associative-recall prompts ("... k v ... k → ?") correctly **iff**
//! attention at layer 2 reaches the token following the earlier
//! occurrence of the cue. That makes method accuracy a direct function of
//! retrieval quality — exactly the causal chain Tables 2/3 measure.
//!
//! Residual stream layout (`d_model = 3·64 = 192`):
//!
//! ```text
//!   [ 0 ..  64)  CUR   — current-token code e(t)           (embedding)
//!   [64 .. 128)  PREV  — previous-token code e(t_{i-1})    (written by L1)
//!   [128.. 192)  POS   — 32 sinusoidal position planes     (embedding)
//! ```
//!
//! * **Layer 1** ("attend to the previous position"): queries rotate the
//!   POS planes by −θ_m (position shift is a *linear* operator on
//!   sinusoidal codes), keys read POS unrotated, so the score peaks at
//!   j = i−1. Values copy CUR → PREV through the output projection.
//! * **Layer 2** ("induction"): queries emit the CUR code into the PREV
//!   channel, keys read PREV — so position j scores high iff
//!   t_{j−1} == t_i. Values copy CUR, and `W_O` writes it back into CUR
//!   with gain λ, dominating the logits of the unembedding.
//!
//! Token codes are ±1/√64 pseudo-random (deterministic per id), giving
//! near-orthogonality for a 4096-token vocabulary; β-scales make softmax
//! effectively argmax over 100K+ positions (margins are asserted in
//! tests and the construction is validated end-to-end in
//! `rust/tests/engine_e2e.rs`).

use super::weights::Weights;
use crate::runtime::manifest::SpecMeta;
use crate::util::rng::Rng;

/// Number of sinusoidal position planes (2 dims each).
pub const POS_PLANES: usize = 32;
/// Width of each token-code subspace.
pub const TOKEN_DIMS: usize = 64;
/// Sharpness of the layer-1 previous-position head (pre-softmax-scale).
pub const BETA1: f32 = 60.0;
/// Sharpness of the layer-2 induction head.
pub const BETA2: f32 = 60.0;
/// Output gain of layer 2 (must beat the CUR code's own logit).
pub const LAMBDA: f32 = 4.0;
/// Separator token: embedded like any token (so it participates in
/// attention) but its unembedding column is zeroed, so it can never win
/// the argmax. Workloads use it to terminate induction chains without
/// creating ambiguous matches (e.g. RULER variable tracking).
pub const SEP_TOKEN: u32 = 4095;

/// True iff this spec is the induction construction's geometry.
pub fn is_induction(spec: &SpecMeta) -> bool {
    !spec.norm
        && spec.q_heads == 1
        && spec.kv_heads == 1
        && spec.head_dim == spec.d_model
        && spec.d_model == 2 * TOKEN_DIMS + 2 * POS_PLANES
}

/// Frequency of position plane `m`: pseudo-random in [0.5, π]
/// (deterministic per plane). Log-spaced RoPE-style frequencies keep
/// ρ(1) ≈ 0.85 (the low-frequency planes barely move per step), which is
/// far too weak a margin for a 100K–1M-position softmax. Random *high*
/// frequencies make ρ(Δ) a quasi-random cosine sum: ρ(1) ≈ −0.18 and
/// max_{Δ≠0 ≤ 1M} ρ(Δ) ≈ 0.56 (measured; asserted in tests), so the
/// layer-1 head's margin is ≈ 0.44·β₁ ≫ ln(1M).
pub fn plane_freq(m: usize) -> f32 {
    let mut rng = Rng::seed_from(0xA0_5E ^ (m as u64).wrapping_mul(0x2545F4914F6CDD1D));
    0.5 + rng.f32() * (std::f32::consts::PI - 0.5)
}

/// Pseudo-random ±1/√T code for a token id (deterministic).
pub fn token_code(id: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from(0x70C0DE ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let amp = 1.0 / (TOKEN_DIMS as f32).sqrt();
    (0..TOKEN_DIMS).map(|_| if rng.f32() < 0.5 { -amp } else { amp }).collect()
}

/// Build the induction model's weights for the given spec.
pub fn build(spec: &SpecMeta) -> Weights {
    assert!(is_induction(spec), "spec is not the induction geometry");
    let d = spec.d_model;
    let pos_base = 2 * TOKEN_DIMS;
    let sqrt_dh = (spec.head_dim as f32).sqrt();
    let mut w = Weights::zeros(spec);

    // Embedding: CUR token code; POS is added at runtime via position_code.
    for t in 0..spec.vocab {
        let code = token_code(t);
        let row = w.table.row_mut(t);
        row[..TOKEN_DIMS].copy_from_slice(&code);
    }

    // ---- Layer 1: previous-position head ----
    {
        let l = &mut w.layers[0];
        // W_Q: POS planes rotated by -theta_m, scaled so the post-1/sqrt(dh)
        // logit is BETA1 * rho(i-1-j). Projection matrices are applied as
        // x @ W, so W[(in, out)].
        let c1 = BETA1 * sqrt_dh;
        for m in 0..POS_PLANES {
            let (cos_t, sin_t) = (plane_freq(m).cos(), plane_freq(m).sin());
            let a = pos_base + 2 * m; // cos dim
            let b = a + 1; // sin dim
            // p(i-1) components from p(i): rotate by -theta.
            //   cos((i-1)t) =  cos(it)cos(t) + sin(it)sin(t)
            //   sin((i-1)t) = -cos(it)sin(t) + sin(it)cos(t)
            l.wq[(a, a)] = c1 * cos_t;
            l.wq[(b, a)] = c1 * sin_t;
            l.wq[(a, b)] = -c1 * sin_t;
            l.wq[(b, b)] = c1 * cos_t;
            // W_K: identity on POS.
            l.wk[(a, a)] = 1.0;
            l.wk[(b, b)] = 1.0;
        }
        // W_V: copy CUR code (value carries the token identity).
        for i in 0..TOKEN_DIMS {
            l.wv[(i, i)] = 1.0;
        }
        // W_O: write the attended value's CUR code into PREV.
        for i in 0..TOKEN_DIMS {
            l.wo[(i, TOKEN_DIMS + i)] = 1.0;
        }
    }

    // ---- Layer 2: induction head ----
    {
        let l = &mut w.layers[1];
        let c2 = BETA2 * sqrt_dh;
        // W_Q: emit CUR into the PREV channel (query asks "whose previous
        // token equals my current token?").
        for i in 0..TOKEN_DIMS {
            l.wq[(i, TOKEN_DIMS + i)] = c2;
        }
        // W_K: identity on PREV.
        for i in 0..TOKEN_DIMS {
            l.wk[(TOKEN_DIMS + i, TOKEN_DIMS + i)] = 1.0;
        }
        // W_V: copy CUR (the answer token lives at the attended position).
        for i in 0..TOKEN_DIMS {
            l.wv[(i, i)] = 1.0;
        }
        // W_O: write back into CUR with gain LAMBDA.
        for i in 0..TOKEN_DIMS {
            l.wo[(i, i)] = LAMBDA;
        }
    }

    // Unembedding: logits_t = e(t) · CUR(x). The SEP token is suppressed
    // (column stays zero) so chain terminators never win the argmax.
    for t in 0..spec.vocab {
        if t as u32 == SEP_TOKEN {
            continue;
        }
        let code = token_code(t);
        for i in 0..TOKEN_DIMS {
            w.wu[(i, t)] = code[i];
        }
    }
    let _ = d;
    w
}

/// The spec of the induction preset (mirrors python PRESETS["induction-mini"]).
pub fn spec() -> SpecMeta {
    SpecMeta {
        layers: 2,
        d_model: 192,
        q_heads: 1,
        kv_heads: 1,
        head_dim: 192,
        vocab: 4096,
        norm: false,
        ffn_dim: 8,
        static_len: 640,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dot, Matrix};

    #[test]
    fn token_codes_near_orthogonal() {
        let a = token_code(1);
        let b = token_code(2);
        assert!((dot(&a, &a) - 1.0).abs() < 1e-5);
        assert!(dot(&a, &b).abs() < 0.5, "cross-talk too high: {}", dot(&a, &b));
        // Deterministic.
        assert_eq!(token_code(1), token_code(1));
    }

    #[test]
    fn position_margin_over_long_range() {
        // rho(0) = 1 must dominate rho(delta) for all delta != 0 up to
        // 100K: this keeps the layer-1 head locked on j = i-1 (its query
        // is p(i-1), so the match is at shift 0).
        let rho = |delta: usize| -> f32 {
            (0..POS_PLANES).map(|m| (delta as f32 * plane_freq(m)).cos()).sum::<f32>()
                / POS_PLANES as f32
        };
        let mut worst = f32::NEG_INFINITY;
        for delta in 1..2000 {
            worst = worst.max(rho(delta));
        }
        for delta in (2000..100_000).step_by(97) {
            worst = worst.max(rho(delta));
        }
        // BETA1 * margin must beat ln(100K) ≈ 11.5 comfortably.
        let margin = (1.0 - worst) * BETA1;
        assert!(margin > 20.0, "margin {margin} (worst off-peak rho = {worst})");
    }

    #[test]
    fn builds_and_validates() {
        let s = spec();
        let w = build(&s);
        assert!(w.validate(&s).is_ok());
        assert!(is_induction(&s));
    }

    #[test]
    fn layer2_query_key_algebra() {
        // q_i . k_j (for layer 2) == BETA2*sqrt(dh) * e(t_i).e(t_{j-1}).
        let s = spec();
        let w = build(&s);
        let l = &w.layers[1];
        // Build x_i with CUR = e(5); x_j with PREV = e(5) (match) or e(9).
        let mut xi = vec![0.0f32; s.d_model];
        xi[..TOKEN_DIMS].copy_from_slice(&token_code(5));
        let q = mat_vec(&l.wq, &xi);
        for (tok, expect_high) in [(5usize, true), (9usize, false)] {
            let mut xj = vec![0.0f32; s.d_model];
            xj[TOKEN_DIMS..2 * TOKEN_DIMS].copy_from_slice(&token_code(tok));
            let k = mat_vec(&l.wk, &xj);
            let score = dot(&q, &k) / (s.head_dim as f32).sqrt();
            if expect_high {
                assert!(score > BETA2 * 0.9, "match score {score}");
            } else {
                assert!(score.abs() < BETA2 * 0.5, "mismatch score {score}");
            }
        }
    }

    fn mat_vec(m: &Matrix, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; m.cols()];
        for (i, &xi) in x.iter().enumerate() {
            if xi != 0.0 {
                crate::tensor::axpy(xi, m.row(i), &mut out);
            }
        }
        out
    }
}
